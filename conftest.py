"""Allow `pytest python/tests/` from the repo root: the test modules import
the `compile` package that lives under python/."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
