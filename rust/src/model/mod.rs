//! Transformer model profiles: the planner's view of a model.
//!
//! A model is a sequence of layers (paper §III-A); each layer carries the
//! quantities the cost estimator needs: parameter count, forward FLOPs per
//! sample, and activation bytes per sample split into *boundary* (the layer
//! input, which CKPT keeps) and *intermediate* (which CKPT discards and
//! recomputes) — see paper §II-B "Activation checkpointing".
//!
//! Calibration: parameter counts and activation sizes reproduce Table I of
//! the paper (unit-tested; params within 5%, activations within 35% — the
//! paper does not publish its exact accounting, we use the Megatron-style
//! formula act_bytes = 4·(17·s·h + 2.5·a·s·s_kv) per sample, fp32).

pub mod spec;
pub mod zoo;

pub use spec::{
    BlockSpec, Dtype, EmbeddingSpec, Family, HeadSpec, ModelSpec, MoeSpec, OptimizerKind,
    PatchSpec, SpecError, TrainConfig,
};
pub use zoo::{model_by_name, model_names, spec_by_name};

/// One (composite) transformer layer as seen by the planner.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    /// Human-readable tag, e.g. "enc", "dec", "swin-s2".
    pub name: String,
    /// Hidden size of this layer.
    pub hidden: usize,
    /// Sequence length (tokens/patches) seen by this layer.
    pub seq: usize,
    /// Attention heads.
    pub heads: usize,
    /// Key/value context length for self-attention (== seq, or the window
    /// size for windowed attention like Swin).
    pub kv_seq: usize,
    /// Trainable parameters in this layer (count, not bytes).
    pub params: f64,
    /// Forward FLOPs per input sample.
    pub flops_fwd: f64,
    /// Total activation bytes stashed for backward, per sample (fp32).
    pub act_bytes: f64,
    /// Boundary (input) activation bytes per sample — what CKPT keeps.
    pub bnd_bytes: f64,
}

impl LayerProfile {
    /// Intermediate activation bytes per sample — what CKPT discards.
    pub fn int_bytes(&self) -> f64 {
        (self.act_bytes - self.bnd_bytes).max(0.0)
    }

    /// Standard encoder layer (self-attention + FFN), full attention.
    pub fn encoder(name: &str, hidden: usize, seq: usize, heads: usize) -> Self {
        Self::windowed_encoder(name, hidden, seq, heads, seq)
    }

    /// Encoder layer with windowed attention (kv context = `window`).
    pub fn windowed_encoder(name: &str, hidden: usize, seq: usize, heads: usize, window: usize) -> Self {
        let (h, s, a, w) = (hidden as f64, seq as f64, heads as f64, window as f64);
        LayerProfile {
            name: name.to_string(),
            hidden,
            seq,
            heads,
            kv_seq: window,
            params: 12.0 * h * h + 13.0 * h, // qkv+proj+2×ffn weights + biases + 2 LN
            flops_fwd: 24.0 * s * h * h + 4.0 * s * w * h,
            act_bytes: 4.0 * (17.0 * s * h + 2.5 * a * s * w),
            bnd_bytes: 4.0 * s * h,
        }
    }

    /// Decoder layer with cross-attention to an encoder of length `enc_seq`
    /// (T5-style). Self-attention is causal over `seq`.
    pub fn decoder(name: &str, hidden: usize, seq: usize, heads: usize, enc_seq: usize) -> Self {
        let (h, s, a, se) = (hidden as f64, seq as f64, heads as f64, enc_seq as f64);
        let enc_like = Self::encoder(name, hidden, seq, heads);
        LayerProfile {
            name: name.to_string(),
            hidden,
            seq,
            heads,
            kv_seq: seq,
            params: enc_like.params + 4.0 * h * h + 5.0 * h, // + cross-attn qkvo
            flops_fwd: enc_like.flops_fwd + 8.0 * s * h * h + 4.0 * s * se * h,
            act_bytes: enc_like.act_bytes + 4.0 * (6.0 * s * h + 2.5 * a * s * se),
            bnd_bytes: 4.0 * s * h,
        }
    }
}

/// A whole model: a layer sequence plus pre/post (embedding / head) params.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelProfile {
    pub name: String,
    pub layers: Vec<LayerProfile>,
    /// Embedding-side parameters, attributed to the first pipeline stage.
    pub pre_params: f64,
    /// Head-side parameters, attributed to the last pipeline stage.
    pub post_params: f64,
}

impl ModelProfile {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total trainable parameters.
    pub fn total_params(&self) -> f64 {
        self.pre_params
            + self.post_params
            + self.layers.iter().map(|l| l.params).sum::<f64>()
    }

    /// Total activation bytes per sample (the Table I column).
    pub fn total_act_bytes(&self) -> f64 {
        self.layers.iter().map(|l| l.act_bytes).sum()
    }

    /// Total forward FLOPs per sample.
    pub fn total_flops_fwd(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_fwd).sum()
    }

    /// Extra parameters attributed to layer `i` from embeddings/head.
    pub fn extra_params(&self, i: usize) -> f64 {
        let mut extra = 0.0;
        if i == 0 {
            extra += self.pre_params;
        }
        if i + 1 == self.layers.len() {
            extra += self.post_params;
        }
        extra
    }

    /// Whether layers are homogeneous (same hidden/seq everywhere).
    pub fn is_homogeneous(&self) -> bool {
        self.layers
            .windows(2)
            .all(|w| w[0].hidden == w[1].hidden && w[0].seq == w[1].seq && w[0].params == w[1].params)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn encoder_layer_sizes() {
        // BERT-Huge layer: h=1280 -> 12h^2 ~ 19.66M params.
        let l = LayerProfile::encoder("enc", 1280, 512, 20);
        assert!((l.params / 1e6 - 19.68).abs() < 0.05, "{}", l.params);
        // Activation ~97 MB/sample fp32 (Megatron formula, decimal MB).
        assert!((l.act_bytes / 1e6 - 97.0).abs() < 3.0, "{}", l.act_bytes);
        // Boundary = s*h*4 = 2.5 MiB.
        assert!((l.bnd_bytes - 4.0 * 512.0 * 1280.0).abs() < 1.0);
        assert!(l.int_bytes() > 0.0);
    }

    #[test]
    fn decoder_has_more_params_than_encoder() {
        let e = LayerProfile::encoder("e", 1024, 512, 16);
        let d = LayerProfile::decoder("d", 1024, 512, 16, 512);
        assert!(d.params > e.params);
        assert!(d.flops_fwd > e.flops_fwd);
        assert!(d.act_bytes > e.act_bytes);
    }

    #[test]
    fn windowed_attention_cheaper() {
        let full = LayerProfile::encoder("f", 640, 784, 20);
        let win = LayerProfile::windowed_encoder("w", 640, 784, 20, 49);
        assert!(win.flops_fwd < full.flops_fwd);
        assert!(win.act_bytes < full.act_bytes);
        assert_eq!(win.params, full.params);
    }
}
