//! Model zoo: every model from Table I of the paper, expressed as
//! [`ModelSpec`] values and compiled through the declarative spec path —
//! the same pipeline that serves `--model-file` specs. The historical
//! constructor functions (`bert`, `vit`, `t5`, `swin`, `gpt3`) remain as
//! thin fronts over the specs; compiled profiles are bit-identical to the
//! pre-spec hand-written formulas (pinned by `spec_tests`).
//!
//! | Model        | Layers          | Hidden            | Params  | Act/sample |
//! |--------------|-----------------|-------------------|---------|------------|
//! | BERT-Huge-32 | 32              | 1280              | 672M    | 3149.39MB  |
//! | BERT-Huge-48 | 48              | 1280              | 987M    | 4657.51MB  |
//! | BERT-xHuge   | 128             | 2560              | 10.2B   | 24210.05MB |
//! | ViT-Huge-32  | 32              | 1280              | 632M    | 646.5MB    |
//! | ViT-Huge-48  | 48              | 1280              | 947M    | 968.59MB   |
//! | ViT-xHuge    | 128             | 2560              | 10.1B   | 5313.9MB   |
//! | T5-Large-32  | 16 Enc.+16 Dec. | 1024              | 502M    | 4119.66MB  |
//! | T5-Large-48  | 24 Enc.+24 Dec. | 1024              | 737M    | 6107.75MB  |
//! | T5-512/4-32  | 16 Enc.+16 Dec. | 1024              | 502M    | 1777.06MB  |
//! | T5-512/4-48  | 24 Enc.+24 Dec. | 1024              | 737M    | 2473.10MB  |
//! | Swin-Huge-32 | 2/2/26/2        | 320/640/1280/2560 | 701M    | 726.59MB   |
//! | Swin-Huge-48 | 2/2/42/2        | 320/640/1280/2560 | 1016M   | 1016.8MB   |
//! | GPT3-15B     | 48              | 5120              | 15.4B   | 32889.04MB |
//! | GPT3-39B     | 48              | 8192              | 39.1B   | 58645.34MB |
//! | GPT3-65B     | 80              | 8192              | 64.9B   | 97557.98MB |

use super::spec::{BlockSpec, EmbeddingSpec, Family, HeadSpec, ModelSpec, PatchSpec};
use super::ModelProfile;

const BERT_VOCAB: usize = 30522;
const T5_VOCAB: usize = 32128;
const GPT_VOCAB: usize = 50257;

fn compiled(spec: ModelSpec) -> ModelProfile {
    match spec.compile() {
        Ok(m) => m,
        Err(e) => panic!("invalid zoo spec {:?}: {e}", spec.name),
    }
}

/// BERT-style encoder-only spec.
pub fn bert_spec(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelSpec {
    let h = hidden as f64;
    ModelSpec {
        name: name.to_string(),
        family: Family::EncoderOnly,
        blocks: vec![BlockSpec::dense(layers, hidden, heads, seq)],
        embedding: Some(EmbeddingSpec {
            vocab: BERT_VOCAB,
            positions: seq,
            patch: None,
            // Segment embeddings + embedding layer norm (2h + 2h).
            extra_params: 2.0 * h + 2.0 * h,
        }),
        // Pooler + MLM head transform (tied decoder not re-counted).
        head: Some(HeadSpec::MlmVocab { vocab: BERT_VOCAB }),
    }
}

/// BERT-style encoder-only model.
pub fn bert(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelProfile {
    compiled(bert_spec(name, layers, hidden, heads, seq))
}

/// ViT-style encoder-only vision spec (patch-16 front end, ImageNet head).
pub fn vit_spec(name: &str, layers: usize, hidden: usize, heads: usize, patches: usize) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        family: Family::EncoderOnly,
        blocks: vec![BlockSpec::dense(layers, hidden, heads, patches)],
        embedding: Some(EmbeddingSpec {
            vocab: 0,
            positions: patches + 1, // patches + CLS token
            patch: Some(PatchSpec { channels: 3, size: 16 }),
            extra_params: 0.0,
        }),
        head: Some(HeadSpec::Classifier { classes: 1000, bias: true }),
    }
}

/// ViT-style encoder-only vision model (patch embedding front end).
pub fn vit(name: &str, layers: usize, hidden: usize, heads: usize, patches: usize) -> ModelProfile {
    compiled(vit_spec(name, layers, hidden, heads, patches))
}

/// T5-style encoder-decoder spec; `dec_seq` may differ (T5-512/4 imbalance).
pub fn t5_spec(
    name: &str,
    enc_layers: usize,
    dec_layers: usize,
    hidden: usize,
    heads: usize,
    enc_seq: usize,
    dec_seq: usize,
) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        family: Family::EncoderDecoder,
        blocks: vec![
            BlockSpec::dense(enc_layers, hidden, heads, enc_seq),
            BlockSpec {
                cross_seq: Some(enc_seq),
                ..BlockSpec::dense(dec_layers, hidden, heads, dec_seq)
            },
        ],
        embedding: Some(EmbeddingSpec::vocab(T5_VOCAB)),
        head: None, // tied LM head
    }
}

/// T5-style encoder-decoder; `dec_seq` may differ (T5-512/4 imbalance).
pub fn t5(
    name: &str,
    enc_layers: usize,
    dec_layers: usize,
    hidden: usize,
    heads: usize,
    enc_seq: usize,
    dec_seq: usize,
) -> ModelProfile {
    compiled(t5_spec(name, enc_layers, dec_layers, hidden, heads, enc_seq, dec_seq))
}

/// Swin-style hierarchical spec: per-stage (layers, hidden, patches,
/// heads) with 7x7 = 49-token attention windows. Patch-merging
/// projections between stages are added by the windowed-family compile.
pub fn swin_spec(name: &str, stages: &[(usize, usize, usize, usize)]) -> ModelSpec {
    const WINDOW: usize = 49;
    ModelSpec {
        name: name.to_string(),
        family: Family::Windowed,
        blocks: stages
            .iter()
            .map(|&(n, hidden, patches, heads)| BlockSpec {
                window: Some(WINDOW),
                ..BlockSpec::dense(n, hidden, heads, patches)
            })
            .collect(),
        embedding: Some(EmbeddingSpec {
            vocab: 0,
            positions: 0,
            patch: Some(PatchSpec { channels: 3, size: 4 }),
            extra_params: 0.0,
        }),
        head: Some(HeadSpec::Classifier { classes: 1000, bias: false }),
    }
}

/// Swin-style hierarchical vision model.
pub fn swin(name: &str, stages: &[(usize, usize, usize, usize)]) -> ModelProfile {
    compiled(swin_spec(name, stages))
}

/// GPT-3-style decoder-only spec (causal self-attention only).
pub fn gpt3_spec(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelSpec {
    ModelSpec {
        name: name.to_string(),
        family: Family::DecoderOnly,
        blocks: vec![BlockSpec::dense(layers, hidden, heads, seq)],
        embedding: Some(EmbeddingSpec {
            vocab: GPT_VOCAB,
            positions: seq,
            patch: None,
            extra_params: 0.0,
        }),
        head: None, // tied
    }
}

/// GPT-3-style decoder-only model (causal self-attention only).
pub fn gpt3(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelProfile {
    compiled(gpt3_spec(name, layers, hidden, heads, seq))
}

/// All Table I model names accepted by `model_by_name`.
pub fn model_names() -> Vec<&'static str> {
    vec![
        "bert-huge-32",
        "bert-huge-48",
        "bert-xhuge",
        "vit-huge-32",
        "vit-huge-48",
        "vit-xhuge",
        "t5-large-32",
        "t5-large-48",
        "t5-512/4-32",
        "t5-512/4-48",
        "swin-huge-32",
        "swin-huge-48",
        "gpt3-15b",
        "gpt3-39b",
        "gpt3-65b",
    ]
}

/// Look up a Table I model's [`ModelSpec`] by (case-insensitive) name.
pub fn spec_by_name(name: &str) -> Option<ModelSpec> {
    let swin_dims = |mid: usize| {
        vec![
            (2usize, 320usize, 3136usize, 10usize),
            (2, 640, 784, 20),
            (mid, 1280, 196, 40),
            (2, 2560, 49, 80),
        ]
    };
    Some(match name.to_ascii_lowercase().as_str() {
        "bert-huge-32" => bert_spec("BERT-Huge-32", 32, 1280, 20, 512),
        "bert-huge-48" => bert_spec("BERT-Huge-48", 48, 1280, 20, 512),
        "bert-xhuge" => bert_spec("BERT-xHuge", 128, 2560, 32, 512),
        "vit-huge-32" => vit_spec("ViT-Huge-32", 32, 1280, 16, 197),
        "vit-huge-48" => vit_spec("ViT-Huge-48", 48, 1280, 16, 197),
        "vit-xhuge" => vit_spec("ViT-xHuge", 128, 2560, 32, 197),
        "t5-large-32" => t5_spec("T5-Large-32", 16, 16, 1024, 16, 512, 512),
        "t5-large-48" => t5_spec("T5-Large-48", 24, 24, 1024, 16, 512, 512),
        "t5-512/4-32" => t5_spec("T5-512/4-32", 16, 16, 1024, 16, 512, 4),
        "t5-512/4-48" => t5_spec("T5-512/4-48", 24, 24, 1024, 16, 512, 4),
        "swin-huge-32" => swin_spec("Swin-Huge-32", &swin_dims(26)),
        "swin-huge-48" => swin_spec("Swin-Huge-48", &swin_dims(42)),
        "gpt3-15b" => gpt3_spec("GPT3-15B", 48, 5120, 40, 2048),
        "gpt3-39b" => gpt3_spec("GPT3-39B", 48, 8192, 64, 2048),
        "gpt3-65b" => gpt3_spec("GPT3-65B", 80, 8192, 64, 2048),
        _ => return None,
    })
}

/// Look up a Table I model by (case-insensitive) name, compiled.
pub fn model_by_name(name: &str) -> Option<ModelProfile> {
    spec_by_name(name).map(compiled)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::MIB;

    /// (name, paper params in M, paper act MB/sample)
    const TABLE_I: &[(&str, f64, f64)] = &[
        ("bert-huge-32", 672.0, 3149.39),
        ("bert-huge-48", 987.0, 4657.51),
        ("bert-xhuge", 10200.0, 24210.05),
        ("vit-huge-32", 632.0, 646.5),
        ("vit-huge-48", 947.0, 968.59),
        ("vit-xhuge", 10100.0, 5313.9),
        ("t5-large-32", 502.0, 4119.66),
        ("t5-large-48", 737.0, 6107.75),
        ("t5-512/4-32", 502.0, 1777.06),
        ("t5-512/4-48", 737.0, 2473.10),
        ("swin-huge-32", 701.0, 726.59),
        ("swin-huge-48", 1016.0, 1016.8),
        ("gpt3-15b", 15400.0, f64::NAN),
        ("gpt3-39b", 39100.0, f64::NAN),
        ("gpt3-65b", 64900.0, f64::NAN),
    ];

    #[test]
    fn params_match_table1_within_5pct() {
        for &(name, paper_m, _) in TABLE_I {
            let m = model_by_name(name).unwrap();
            let ours_m = m.total_params() / 1e6;
            let rel = (ours_m - paper_m).abs() / paper_m;
            assert!(rel < 0.05, "{name}: ours {ours_m:.1}M vs paper {paper_m}M ({:.1}%)", rel * 100.0);
        }
    }

    #[test]
    fn activations_match_table1_within_35pct() {
        // The paper's exact accounting is unpublished; we require the same
        // order and relative ordering between models (shape preservation).
        for &(name, _, paper_mb) in TABLE_I {
            if paper_mb.is_nan() {
                continue;
            }
            let m = model_by_name(name).unwrap();
            let ours_mb = m.total_act_bytes() / MIB;
            let rel = (ours_mb - paper_mb).abs() / paper_mb;
            assert!(rel < 0.35, "{name}: ours {ours_mb:.1}MB vs paper {paper_mb}MB ({:.1}%)", rel * 100.0);
        }
    }

    #[test]
    fn nlp_has_bigger_activations_than_cv() {
        // Paper §VII-B: "NLP models have larger activation while CV models
        // have larger model parameters".
        let bert = model_by_name("bert-huge-32").unwrap();
        let vit = model_by_name("vit-huge-32").unwrap();
        assert!(bert.total_act_bytes() > 3.0 * vit.total_act_bytes());
    }

    #[test]
    fn t5_decoder_short_seq_is_imbalanced() {
        let t = model_by_name("t5-512/4-32").unwrap();
        let enc = &t.layers[0];
        let dec = &t.layers[16];
        assert!(dec.act_bytes < enc.act_bytes / 4.0, "decoder must be activation-light");
        assert!(dec.params > enc.params, "decoder must be param-heavy");
    }

    #[test]
    fn swin_is_heterogeneous() {
        let s = model_by_name("swin-huge-32").unwrap();
        assert!(!s.is_homogeneous());
        assert_eq!(s.n_layers(), 32);
        // Shallow layers: bigger activations, fewer params (paper §VII-F).
        let first = &s.layers[0];
        let last = &s.layers[31];
        assert!(first.act_bytes > last.act_bytes);
        assert!(first.params < last.params);
    }

    #[test]
    fn all_names_resolve() {
        for name in model_names() {
            assert!(model_by_name(name).is_some(), "{name}");
            assert!(spec_by_name(name).is_some(), "{name}");
        }
        assert!(model_by_name("nonexistent").is_none());
        assert!(spec_by_name("nonexistent").is_none());
    }

    #[test]
    fn bert_is_homogeneous() {
        assert!(model_by_name("bert-huge-32").unwrap().is_homogeneous());
    }

    #[test]
    fn zoo_layer_names_preserved() {
        // The spec compile reproduces the historical layer tags.
        let b = model_by_name("bert-huge-32").unwrap();
        assert_eq!(b.layers[0].name, "enc0");
        assert_eq!(b.layers[31].name, "enc31");
        let g = model_by_name("gpt3-15b").unwrap();
        assert_eq!(g.layers[0].name, "dec0");
        let t = model_by_name("t5-large-32").unwrap();
        assert_eq!(t.layers[15].name, "enc15");
        assert_eq!(t.layers[16].name, "dec0");
        let s = model_by_name("swin-huge-32").unwrap();
        assert_eq!(s.layers[0].name, "s0l0");
        assert_eq!(s.layers[31].name, "s3l1");
    }
}
