//! Model zoo: every model from Table I of the paper, plus a CLI lookup.
//!
//! | Model        | Layers          | Hidden            | Params  | Act/sample |
//! |--------------|-----------------|-------------------|---------|------------|
//! | BERT-Huge-32 | 32              | 1280              | 672M    | 3149.39MB  |
//! | BERT-Huge-48 | 48              | 1280              | 987M    | 4657.51MB  |
//! | BERT-xHuge   | 128             | 2560              | 10.2B   | 24210.05MB |
//! | ViT-Huge-32  | 32              | 1280              | 632M    | 646.5MB    |
//! | ViT-Huge-48  | 48              | 1280              | 947M    | 968.59MB   |
//! | ViT-xHuge    | 128             | 2560              | 10.1B   | 5313.9MB   |
//! | T5-Large-32  | 16 Enc.+16 Dec. | 1024              | 502M    | 4119.66MB  |
//! | T5-Large-48  | 24 Enc.+24 Dec. | 1024              | 737M    | 6107.75MB  |
//! | T5-512/4-32  | 16 Enc.+16 Dec. | 1024              | 502M    | 1777.06MB  |
//! | T5-512/4-48  | 24 Enc.+24 Dec. | 1024              | 737M    | 2473.10MB  |
//! | Swin-Huge-32 | 2/2/26/2        | 320/640/1280/2560 | 701M    | 726.59MB   |
//! | Swin-Huge-48 | 2/2/42/2        | 320/640/1280/2560 | 1016M   | 1016.8MB   |
//! | GPT3-15B     | 48              | 5120              | 15.4B   | 32889.04MB |
//! | GPT3-39B     | 48              | 8192              | 39.1B   | 58645.34MB |
//! | GPT3-65B     | 80              | 8192              | 64.9B   | 97557.98MB |

use super::{LayerProfile, ModelProfile};

const BERT_VOCAB: f64 = 30522.0;
const T5_VOCAB: f64 = 32128.0;
const GPT_VOCAB: f64 = 50257.0;

/// BERT-style encoder-only model.
pub fn bert(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelProfile {
    let h = hidden as f64;
    ModelProfile {
        name: name.to_string(),
        layers: (0..layers)
            .map(|i| LayerProfile::encoder(&format!("enc{i}"), hidden, seq, heads))
            .collect(),
        // token + position + segment embeddings + LN
        pre_params: BERT_VOCAB * h + (seq as f64) * h + 2.0 * h + 2.0 * h,
        // pooler + MLM head transform (tied decoder not re-counted)
        post_params: h * h + 3.0 * h + BERT_VOCAB,
    }
}

/// ViT-style encoder-only vision model (patch embedding front end).
pub fn vit(name: &str, layers: usize, hidden: usize, heads: usize, patches: usize) -> ModelProfile {
    let h = hidden as f64;
    ModelProfile {
        name: name.to_string(),
        layers: (0..layers)
            .map(|i| LayerProfile::encoder(&format!("enc{i}"), hidden, patches, heads))
            .collect(),
        pre_params: 3.0 * 16.0 * 16.0 * h + (patches as f64 + 1.0) * h, // patch16 conv + pos
        post_params: h * 1000.0 + 1000.0,                               // ImageNet-1K head
    }
}

/// T5-style encoder-decoder; `dec_seq` may differ (T5-512/4 imbalance).
pub fn t5(
    name: &str,
    enc_layers: usize,
    dec_layers: usize,
    hidden: usize,
    heads: usize,
    enc_seq: usize,
    dec_seq: usize,
) -> ModelProfile {
    let h = hidden as f64;
    let mut layers = Vec::new();
    for i in 0..enc_layers {
        layers.push(LayerProfile::encoder(&format!("enc{i}"), hidden, enc_seq, heads));
    }
    for i in 0..dec_layers {
        layers.push(LayerProfile::decoder(&format!("dec{i}"), hidden, dec_seq, heads, enc_seq));
    }
    ModelProfile {
        name: name.to_string(),
        layers,
        pre_params: T5_VOCAB * h,
        post_params: 0.0, // tied LM head
    }
}

/// Swin-style hierarchical vision model: per-stage (layers, hidden, patches,
/// heads) with 7x7 = 49-token attention windows.
pub fn swin(name: &str, stages: &[(usize, usize, usize, usize)]) -> ModelProfile {
    const WINDOW: usize = 49;
    let mut layers = Vec::new();
    let mut pre = 0.0;
    for (si, &(n, hidden, patches, heads)) in stages.iter().enumerate() {
        for i in 0..n {
            layers.push(LayerProfile::windowed_encoder(
                &format!("s{si}l{i}"),
                hidden,
                patches,
                heads,
                WINDOW,
            ));
        }
        // Patch-merging projection into the next stage.
        if si + 1 < stages.len() {
            let h_next = stages[si + 1].1 as f64;
            pre += 2.0 * h_next * h_next; // 4C -> 2C linear merge
        }
    }
    let h0 = stages[0].1 as f64;
    let h_last = stages.last().unwrap().1 as f64;
    ModelProfile {
        name: name.to_string(),
        layers,
        pre_params: pre + 3.0 * 4.0 * 4.0 * h0, // patch4 embed + merges
        post_params: h_last * 1000.0,
    }
}

/// GPT-3-style decoder-only model (causal self-attention only).
pub fn gpt3(name: &str, layers: usize, hidden: usize, heads: usize, seq: usize) -> ModelProfile {
    let h = hidden as f64;
    ModelProfile {
        name: name.to_string(),
        layers: (0..layers)
            .map(|i| LayerProfile::encoder(&format!("dec{i}"), hidden, seq, heads))
            .collect(),
        pre_params: GPT_VOCAB * h + (seq as f64) * h,
        post_params: 0.0, // tied
    }
}

/// All Table I model names accepted by `model_by_name`.
pub fn model_names() -> Vec<&'static str> {
    vec![
        "bert-huge-32",
        "bert-huge-48",
        "bert-xhuge",
        "vit-huge-32",
        "vit-huge-48",
        "vit-xhuge",
        "t5-large-32",
        "t5-large-48",
        "t5-512/4-32",
        "t5-512/4-48",
        "swin-huge-32",
        "swin-huge-48",
        "gpt3-15b",
        "gpt3-39b",
        "gpt3-65b",
    ]
}

/// Look up a Table I model by (case-insensitive) name.
pub fn model_by_name(name: &str) -> Option<ModelProfile> {
    let swin_dims = |mid: usize| {
        vec![
            (2usize, 320usize, 3136usize, 10usize),
            (2, 640, 784, 20),
            (mid, 1280, 196, 40),
            (2, 2560, 49, 80),
        ]
    };
    Some(match name.to_ascii_lowercase().as_str() {
        "bert-huge-32" => bert("BERT-Huge-32", 32, 1280, 20, 512),
        "bert-huge-48" => bert("BERT-Huge-48", 48, 1280, 20, 512),
        "bert-xhuge" => bert("BERT-xHuge", 128, 2560, 32, 512),
        "vit-huge-32" => vit("ViT-Huge-32", 32, 1280, 16, 197),
        "vit-huge-48" => vit("ViT-Huge-48", 48, 1280, 16, 197),
        "vit-xhuge" => vit("ViT-xHuge", 128, 2560, 32, 197),
        "t5-large-32" => t5("T5-Large-32", 16, 16, 1024, 16, 512, 512),
        "t5-large-48" => t5("T5-Large-48", 24, 24, 1024, 16, 512, 512),
        "t5-512/4-32" => t5("T5-512/4-32", 16, 16, 1024, 16, 512, 4),
        "t5-512/4-48" => t5("T5-512/4-48", 24, 24, 1024, 16, 512, 4),
        "swin-huge-32" => swin("Swin-Huge-32", &swin_dims(26)),
        "swin-huge-48" => swin("Swin-Huge-48", &swin_dims(42)),
        "gpt3-15b" => gpt3("GPT3-15B", 48, 5120, 40, 2048),
        "gpt3-39b" => gpt3("GPT3-39B", 48, 8192, 64, 2048),
        "gpt3-65b" => gpt3("GPT3-65B", 80, 8192, 64, 2048),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    /// (name, paper params in M, paper act MB/sample)
    const TABLE_I: &[(&str, f64, f64)] = &[
        ("bert-huge-32", 672.0, 3149.39),
        ("bert-huge-48", 987.0, 4657.51),
        ("bert-xhuge", 10200.0, 24210.05),
        ("vit-huge-32", 632.0, 646.5),
        ("vit-huge-48", 947.0, 968.59),
        ("vit-xhuge", 10100.0, 5313.9),
        ("t5-large-32", 502.0, 4119.66),
        ("t5-large-48", 737.0, 6107.75),
        ("t5-512/4-32", 502.0, 1777.06),
        ("t5-512/4-48", 737.0, 2473.10),
        ("swin-huge-32", 701.0, 726.59),
        ("swin-huge-48", 1016.0, 1016.8),
        ("gpt3-15b", 15400.0, f64::NAN),
        ("gpt3-39b", 39100.0, f64::NAN),
        ("gpt3-65b", 64900.0, f64::NAN),
    ];

    #[test]
    fn params_match_table1_within_5pct() {
        for &(name, paper_m, _) in TABLE_I {
            let m = model_by_name(name).unwrap();
            let ours_m = m.total_params() / 1e6;
            let rel = (ours_m - paper_m).abs() / paper_m;
            assert!(rel < 0.05, "{name}: ours {ours_m:.1}M vs paper {paper_m}M ({:.1}%)", rel * 100.0);
        }
    }

    #[test]
    fn activations_match_table1_within_35pct() {
        // The paper's exact accounting is unpublished; we require the same
        // order and relative ordering between models (shape preservation).
        for &(name, _, paper_mb) in TABLE_I {
            if paper_mb.is_nan() {
                continue;
            }
            let m = model_by_name(name).unwrap();
            let ours_mb = m.total_act_bytes() / MIB;
            let rel = (ours_mb - paper_mb).abs() / paper_mb;
            assert!(rel < 0.35, "{name}: ours {ours_mb:.1}MB vs paper {paper_mb}MB ({:.1}%)", rel * 100.0);
        }
    }

    #[test]
    fn nlp_has_bigger_activations_than_cv() {
        // Paper §VII-B: "NLP models have larger activation while CV models
        // have larger model parameters".
        let bert = model_by_name("bert-huge-32").unwrap();
        let vit = model_by_name("vit-huge-32").unwrap();
        assert!(bert.total_act_bytes() > 3.0 * vit.total_act_bytes());
    }

    #[test]
    fn t5_decoder_short_seq_is_imbalanced() {
        let t = model_by_name("t5-512/4-32").unwrap();
        let enc = &t.layers[0];
        let dec = &t.layers[16];
        assert!(dec.act_bytes < enc.act_bytes / 4.0, "decoder must be activation-light");
        assert!(dec.params > enc.params, "decoder must be param-heavy");
    }

    #[test]
    fn swin_is_heterogeneous() {
        let s = model_by_name("swin-huge-32").unwrap();
        assert!(!s.is_homogeneous());
        assert_eq!(s.n_layers(), 32);
        // Shallow layers: bigger activations, fewer params (paper §VII-F).
        let first = &s.layers[0];
        let last = &s.layers[31];
        assert!(first.act_bytes > last.act_bytes);
        assert!(first.params < last.params);
    }

    #[test]
    fn all_names_resolve() {
        for name in model_names() {
            assert!(model_by_name(name).is_some(), "{name}");
        }
        assert!(model_by_name("nonexistent").is_none());
    }

    #[test]
    fn bert_is_homogeneous() {
        assert!(model_by_name("bert-huge-32").unwrap().is_homogeneous());
    }
}
