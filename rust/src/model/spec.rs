//! Declarative model + training-configuration specs: the typed,
//! JSON-loadable front door of the planner.
//!
//! A [`ModelSpec`] describes a model the way a user thinks about it —
//! architecture family, runs of identical transformer blocks, embedding
//! and head layers — and *compiles* to the [`ModelProfile`] layer sequence
//! the search engine consumes (paper §III-A). The Table I zoo is itself
//! expressed as `ModelSpec`s (`model::zoo`), so a spec loaded from
//! `--model-file my-model.json` travels the exact same path as the
//! built-in models.
//!
//! A [`TrainConfig`] describes the numerics of the training run — the
//! parameter/activation dtype (with fp32 master weights under mixed
//! precision), the optimizer (SGD or Adam), and optional ZeRO-style
//! sharding of the optimizer state over the data-parallel degree. Its
//! byte-per-parameter and activation-scale accounting replaces the
//! hardwired fp32/Adam constants in the memory model; the default
//! (fp32 + Adam, unsharded) reproduces those constants bit-for-bit, so
//! plans and artifacts produced without an explicit train config are
//! byte-identical to the pre-spec planner.
//!
//! Supported block features beyond the plain transformer layer:
//!   * windowed attention (Swin-style kv context),
//!   * grouped-query attention (`kv_heads` < `heads`),
//!   * cross-attention decoder blocks (encoder-decoder family),
//!   * MoE feed-forward blocks (`experts` routed `top_k` ways).

use std::fmt;
use std::path::Path;

use crate::util::json::Json;

use super::{LayerProfile, ModelProfile};

/// A model spec failed to parse, validate, or compile.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecError {
    pub reason: String,
}

impl SpecError {
    fn new(reason: impl Into<String>) -> SpecError {
        SpecError { reason: reason.into() }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.reason)
    }
}

impl std::error::Error for SpecError {}

/// Strict-key validation ([`crate::util::json::check_object_keys`]: a
/// misspelled optional key like `"kv_head"`, `"windows"`, `"zer0"` must
/// error, not silently describe a different model or training run),
/// surfaced as a spec error.
fn check_keys(v: &Json, allowed: &[&str], ctx: &str) -> Result<(), SpecError> {
    crate::util::json::check_object_keys(v, allowed, ctx).map_err(SpecError::new)
}

// ---------------------------------------------------------------------------
// TrainConfig
// ---------------------------------------------------------------------------

/// Numeric format of parameters and activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    Fp32,
    Fp16,
    Bf16,
}

impl Dtype {
    /// Bytes per element.
    pub fn bytes(self) -> f64 {
        match self {
            Dtype::Fp32 => 4.0,
            Dtype::Fp16 | Dtype::Bf16 => 2.0,
        }
    }

    pub fn key(self) -> &'static str {
        match self {
            Dtype::Fp32 => "fp32",
            Dtype::Fp16 => "fp16",
            Dtype::Bf16 => "bf16",
        }
    }
}

impl std::str::FromStr for Dtype {
    type Err = SpecError;

    fn from_str(s: &str) -> Result<Dtype, SpecError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fp32" | "float32" => Ok(Dtype::Fp32),
            "fp16" | "float16" | "half" => Ok(Dtype::Fp16),
            "bf16" | "bfloat16" => Ok(Dtype::Bf16),
            other => Err(SpecError::new(format!(
                "unknown dtype {other:?}; expected \"fp32\", \"fp16\" or \"bf16\""
            ))),
        }
    }
}

/// Optimizer whose per-parameter state the memory model accounts for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain SGD: no per-parameter optimizer state.
    Sgd,
    /// Adam: two fp32 moments (8 bytes/param).
    Adam,
}

impl OptimizerKind {
    pub fn key(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }
}

impl std::str::FromStr for OptimizerKind {
    type Err = SpecError;

    fn from_str(s: &str) -> Result<OptimizerKind, SpecError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" | "adamw" => Ok(OptimizerKind::Adam),
            other => Err(SpecError::new(format!(
                "unknown optimizer {other:?}; expected \"sgd\" or \"adam\""
            ))),
        }
    }
}

/// Training numerics: dtype, optimizer, and optional ZeRO-style sharding of
/// the optimizer state over the data-parallel degree.
///
/// Memory accounting per parameter:
///   * parameter + gradient in `dtype` (never sharded beyond TP/SDP),
///   * fp32 master weights when `dtype` is not fp32 (4 bytes),
///   * optimizer moments (Adam: 8 bytes fp32; SGD: none),
/// with the master + moment bytes divided by the strategy's DP degree when
/// `zero` is set (ZeRO-1; SDP already shards everything, so `zero` only
/// matters for replicated-DP strategies).
///
/// The default (fp32 + Adam, no ZeRO) is 4 + 4 + 8 = 16 bytes/param — the
/// historical [`crate::parallel::memory::STATE_BYTES_PER_PARAM`] — and an
/// activation scale of 1.0, so it reproduces the pre-spec planner
/// bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainConfig {
    pub dtype: Dtype,
    pub optimizer: OptimizerKind,
    /// Shard optimizer state (master weights + moments) over the DP degree.
    pub zero: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { dtype: Dtype::Fp32, optimizer: OptimizerKind::Adam, zero: false }
    }
}

impl TrainConfig {
    /// Scale factor on the fp32-calibrated activation bytes of a
    /// [`LayerProfile`] (1.0 for fp32, 0.5 for fp16/bf16).
    pub fn act_scale(&self) -> f64 {
        self.dtype.bytes() / 4.0
    }

    /// Parameter + gradient bytes per parameter (persistent on every
    /// replica; never ZeRO-sharded).
    pub fn param_grad_bytes(&self) -> f64 {
        2.0 * self.dtype.bytes()
    }

    /// fp32 master copy (mixed precision only) + optimizer moment bytes
    /// per parameter — the ZeRO-shardable part of the model state.
    pub fn optimizer_state_bytes(&self) -> f64 {
        let master = if self.dtype == Dtype::Fp32 { 0.0 } else { 4.0 };
        let moments = match self.optimizer {
            OptimizerKind::Adam => 8.0,
            OptimizerKind::Sgd => 0.0,
        };
        master + moments
    }

    /// Model-state bytes per parameter for a strategy whose pure
    /// data-parallel degree is `dp` (the divisor ZeRO shards over).
    pub fn state_bytes_per_param(&self, dp: usize) -> f64 {
        let shard = if self.zero { dp.max(1) as f64 } else { 1.0 };
        self.param_grad_bytes() + self.optimizer_state_bytes() / shard
    }

    /// Model-state bytes per parameter with no ZeRO sharding applied —
    /// the strategy-agnostic weight used by partition seeds.
    pub fn unsharded_state_bytes(&self) -> f64 {
        self.param_grad_bytes() + self.optimizer_state_bytes()
    }

    /// Whether this is the byte-compatible default (fp32 + Adam, no ZeRO).
    pub fn is_default(&self) -> bool {
        *self == TrainConfig::default()
    }

    /// Compact label like "bf16+adam+zero".
    pub fn label(&self) -> String {
        let mut s = format!("{}+{}", self.dtype.key(), self.optimizer.key());
        if self.zero {
            s.push_str("+zero");
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dtype", Json::str(self.dtype.key())),
            ("optimizer", Json::str(self.optimizer.key())),
            ("zero", Json::Bool(self.zero)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TrainConfig, SpecError> {
        check_keys(v, &["dtype", "optimizer", "zero"], "train config")?;
        let mut out = TrainConfig::default();
        if let Some(d) = v.get("dtype") {
            out.dtype = d
                .as_str()
                .ok_or_else(|| SpecError::new("train config: dtype must be a string"))?
                .parse()?;
        }
        if let Some(o) = v.get("optimizer") {
            out.optimizer = o
                .as_str()
                .ok_or_else(|| SpecError::new("train config: optimizer must be a string"))?
                .parse()?;
        }
        if let Some(z) = v.get("zero") {
            out.zero = z
                .as_bool()
                .ok_or_else(|| SpecError::new("train config: zero must be a boolean"))?;
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// ModelSpec
// ---------------------------------------------------------------------------

/// Architecture family — determines block roles, layer naming, and
/// family-specific extras (Swin patch-merging projections).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Causal decoder-only LM (GPT-style); optional grouped-query
    /// attention via [`BlockSpec::kv_heads`].
    DecoderOnly,
    /// Bidirectional encoder-only model (BERT/ViT-style).
    EncoderOnly,
    /// Encoder stacks followed by cross-attending decoder stacks
    /// (T5-style); blocks with `cross_seq` set are the decoders.
    EncoderDecoder,
    /// Hierarchical windowed-attention vision stages (Swin-style);
    /// patch-merging projections between stacks are added automatically.
    Windowed,
}

impl Family {
    pub fn key(self) -> &'static str {
        match self {
            Family::DecoderOnly => "decoder-only",
            Family::EncoderOnly => "encoder-only",
            Family::EncoderDecoder => "encoder-decoder",
            Family::Windowed => "windowed",
        }
    }
}

impl std::str::FromStr for Family {
    type Err = SpecError;

    fn from_str(s: &str) -> Result<Family, SpecError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "decoder-only" | "gpt" => Ok(Family::DecoderOnly),
            "encoder-only" | "bert" => Ok(Family::EncoderOnly),
            "encoder-decoder" | "t5" => Ok(Family::EncoderDecoder),
            "windowed" | "swin" => Ok(Family::Windowed),
            other => Err(SpecError::new(format!(
                "unknown model family {other:?}; expected \"decoder-only\", \
                 \"encoder-only\", \"encoder-decoder\" or \"windowed\""
            ))),
        }
    }
}

/// MoE feed-forward description for a block run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeSpec {
    /// Expert count (each expert is a full FFN).
    pub experts: usize,
    /// Experts each token is routed to.
    pub top_k: usize,
}

/// One run of `count` identical transformer blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockSpec {
    pub count: usize,
    pub hidden: usize,
    pub heads: usize,
    /// Sequence length (tokens/patches) seen by these blocks.
    pub seq: usize,
    /// Attention window (kv context); `None` = full attention over `seq`.
    pub window: Option<usize>,
    /// Grouped-query attention: key/value head count (`None` = `heads`).
    pub kv_heads: Option<usize>,
    /// Cross-attention to an encoder of this length (decoder blocks of the
    /// encoder-decoder family).
    pub cross_seq: Option<usize>,
    /// Replace the dense FFN with a routed mixture of experts.
    pub moe: Option<MoeSpec>,
}

impl BlockSpec {
    /// Plain full-attention block run (the common case).
    pub fn dense(count: usize, hidden: usize, heads: usize, seq: usize) -> BlockSpec {
        BlockSpec {
            count,
            hidden,
            heads,
            seq,
            window: None,
            kv_heads: None,
            cross_seq: None,
            moe: None,
        }
    }

    /// kv context length of one block.
    fn kv_seq(&self) -> usize {
        self.window.unwrap_or(self.seq)
    }

    fn validate(&self, family: Family, idx: usize) -> Result<(), SpecError> {
        let at = |what: String| SpecError::new(format!("blocks[{idx}]: {what}"));
        if self.count == 0 {
            return Err(at("count must be >= 1".into()));
        }
        if self.hidden == 0 || self.heads == 0 || self.seq == 0 {
            return Err(at("hidden, heads and seq must be >= 1".into()));
        }
        if self.hidden % self.heads != 0 {
            return Err(at(format!(
                "hidden {} is not divisible by heads {}",
                self.hidden, self.heads
            )));
        }
        if let Some(w) = self.window {
            if w == 0 || w > self.seq {
                return Err(at(format!("window {w} must be in 1..={}", self.seq)));
            }
        }
        if let Some(kv) = self.kv_heads {
            if kv == 0 || kv > self.heads || self.heads % kv != 0 {
                return Err(at(format!(
                    "kv_heads {kv} must divide heads {}",
                    self.heads
                )));
            }
        }
        if let Some(moe) = self.moe {
            if moe.experts < 2 {
                return Err(at("moe.experts must be >= 2".into()));
            }
            if moe.top_k == 0 || moe.top_k > moe.experts {
                return Err(at(format!(
                    "moe.top_k {} must be in 1..={}",
                    moe.top_k, moe.experts
                )));
            }
        }
        if self.cross_seq == Some(0) {
            return Err(at("cross_seq must be >= 1".into()));
        }
        if self.cross_seq.is_some() {
            if family != Family::EncoderDecoder {
                return Err(at(format!(
                    "cross_seq requires the encoder-decoder family (got {})",
                    family.key()
                )));
            }
            if self.kv_heads.is_some() || self.moe.is_some() || self.window.is_some() {
                return Err(at(
                    "kv_heads/moe/window are not supported on cross-attention \
                     decoder blocks"
                        .into(),
                ));
            }
        }
        Ok(())
    }

    /// Build the `LayerProfile` of one block named `name`. Plain blocks
    /// delegate to the calibrated zoo constructors (bit-identical to the
    /// historical zoo); GQA/MoE blocks use the generalized formulas below.
    fn layer(&self, name: &str) -> LayerProfile {
        if let Some(cross) = self.cross_seq {
            return LayerProfile::decoder(name, self.hidden, self.seq, self.heads, cross);
        }
        let plain_attn = self.kv_heads.map_or(true, |kv| kv == self.heads);
        if plain_attn && self.moe.is_none() {
            return LayerProfile::windowed_encoder(
                name,
                self.hidden,
                self.seq,
                self.heads,
                self.kv_seq(),
            );
        }
        // Generalized block: GQA shrinks the k/v projections by
        // kv_heads/heads; MoE replicates the FFN weights across experts
        // (plus an h×E router) and multiplies FFN compute/activations by
        // top_k. ratio = 1, experts = top_k = 1 reduces to the standard
        // 12h² + 13h / 24sh² + 4swh / 4(17sh + 2.5asw) block.
        let (h, s, a) = (self.hidden as f64, self.seq as f64, self.heads as f64);
        let w = self.kv_seq() as f64;
        let ratio = self.kv_heads.map_or(1.0, |kv| kv as f64 / self.heads as f64);
        let (e, k) = self.moe.map_or((1.0, 1.0), |m| (m.experts as f64, m.top_k as f64));
        let router = if e > 1.0 { h * e } else { 0.0 };
        let router_flops = if e > 1.0 { 2.0 * s * h * e } else { 0.0 };
        LayerProfile {
            name: name.to_string(),
            hidden: self.hidden,
            seq: self.seq,
            heads: self.heads,
            kv_seq: self.kv_seq(),
            // attn q+o (2h²) + kv (2h²·ratio) + ffn (8h²·E) + router + biases.
            params: (2.0 + 2.0 * ratio) * h * h + 8.0 * h * h * e + router + 13.0 * h,
            // projections (4+4·ratio)sh² + ffn 16sh²·k + attention 4swh.
            flops_fwd: (4.0 + 4.0 * ratio) * s * h * h
                + 16.0 * s * h * h * k
                + 4.0 * s * w * h
                + router_flops,
            // Of the calibrated 17sh activation term, 2sh are k/v
            // projections (scaled by ratio) and 8sh the FFN intermediate
            // (scaled by top_k); attention scores stay per q-head.
            act_bytes: 4.0 * ((7.0 + 2.0 * ratio + 8.0 * k) * s * h + 2.5 * a * s * w),
            bnd_bytes: 4.0 * s * h,
        }
    }
}

/// Patch-embedding front end (vision models): a `channels × size × size →
/// hidden` projection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatchSpec {
    pub channels: usize,
    pub size: usize,
}

/// Embedding-side layers, attributed to the first pipeline stage.
#[derive(Debug, Clone, PartialEq)]
pub struct EmbeddingSpec {
    /// Token vocabulary rows (`vocab × hidden` params); 0 = none.
    pub vocab: usize,
    /// Learned position embeddings over this many positions; 0 = none.
    pub positions: usize,
    /// Patch-embedding projection (vision models).
    pub patch: Option<PatchSpec>,
    /// Additional embedding-side parameters not covered above (segment
    /// embeddings, layer norms, ...), as a raw count.
    pub extra_params: f64,
}

impl Default for EmbeddingSpec {
    fn default() -> Self {
        EmbeddingSpec { vocab: 0, positions: 0, patch: None, extra_params: 0.0 }
    }
}

impl EmbeddingSpec {
    /// Vocabulary-only embedding (tied LM head).
    pub fn vocab(vocab: usize) -> EmbeddingSpec {
        EmbeddingSpec { vocab, ..Default::default() }
    }

    fn params(&self, hidden: f64) -> f64 {
        self.vocab as f64 * hidden
            + self.positions as f64 * hidden
            + self
                .patch
                .map_or(0.0, |p| (p.channels * p.size * p.size) as f64 * hidden)
            + self.extra_params
    }
}

/// Head-side (output) layers, attributed to the last pipeline stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HeadSpec {
    /// Classification head: `hidden × classes` (+ `classes` bias terms).
    Classifier { classes: usize, bias: bool },
    /// BERT MLM-style head: `h×h` transform + norms + vocabulary bias
    /// (`h² + 3h + vocab`; the tied decoder matrix is not re-counted).
    MlmVocab { vocab: usize },
}

impl HeadSpec {
    fn params(&self, hidden: f64) -> f64 {
        match *self {
            HeadSpec::Classifier { classes, bias } => {
                hidden * classes as f64 + if bias { classes as f64 } else { 0.0 }
            }
            HeadSpec::MlmVocab { vocab } => hidden * hidden + 3.0 * hidden + vocab as f64,
        }
    }
}

/// A declarative model description: architecture family, block runs, and
/// optional embedding/head layers. Compiles to the planner's
/// [`ModelProfile`]; serializes to/from JSON (`--model-file`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub family: Family,
    /// Block runs in model order.
    pub blocks: Vec<BlockSpec>,
    pub embedding: Option<EmbeddingSpec>,
    pub head: Option<HeadSpec>,
}

impl ModelSpec {
    /// Total block (layer) count.
    pub fn n_layers(&self) -> usize {
        self.blocks.iter().map(|b| b.count).sum()
    }

    /// Structural validation (also run by [`ModelSpec::compile`]).
    pub fn validate(&self) -> Result<(), SpecError> {
        if self.name.trim().is_empty() {
            return Err(SpecError::new("model name must not be empty"));
        }
        if self.blocks.is_empty() {
            return Err(SpecError::new("model must have at least one block run"));
        }
        for (i, b) in self.blocks.iter().enumerate() {
            b.validate(self.family, i)?;
        }
        if let Some(e) = &self.embedding {
            if !(e.extra_params.is_finite() && e.extra_params >= 0.0) {
                return Err(SpecError::new(format!(
                    "embedding.extra_params must be a non-negative finite number, got {}",
                    e.extra_params
                )));
            }
        }
        if self.family == Family::EncoderDecoder
            && !self.blocks.iter().any(|b| b.cross_seq.is_some())
        {
            return Err(SpecError::new(
                "encoder-decoder family needs at least one decoder block run \
                 (a block with cross_seq set)",
            ));
        }
        Ok(())
    }

    /// Compile to the planner's layer-sequence view. The zoo specs
    /// reproduce the historical constructors bit-for-bit (pinned by test).
    pub fn compile(&self) -> Result<ModelProfile, SpecError> {
        self.validate()?;
        let mut layers = Vec::with_capacity(self.n_layers());
        let (mut enc_i, mut dec_i) = (0usize, 0usize);
        for (si, b) in self.blocks.iter().enumerate() {
            for i in 0..b.count {
                let name = match self.family {
                    Family::Windowed => format!("s{si}l{i}"),
                    Family::DecoderOnly => {
                        let n = format!("dec{dec_i}");
                        dec_i += 1;
                        n
                    }
                    Family::EncoderOnly => {
                        let n = format!("enc{enc_i}");
                        enc_i += 1;
                        n
                    }
                    Family::EncoderDecoder => {
                        if b.cross_seq.is_some() {
                            let n = format!("dec{dec_i}");
                            dec_i += 1;
                            n
                        } else {
                            let n = format!("enc{enc_i}");
                            enc_i += 1;
                            n
                        }
                    }
                };
                layers.push(b.layer(&name));
            }
        }

        // Embedding params bind to the first block's hidden size; head
        // params to the last block's.
        let h0 = self.blocks[0].hidden as f64;
        let h_last = self.blocks.last().map_or(0, |b| b.hidden) as f64;
        let mut pre_params = 0.0;
        if self.family == Family::Windowed {
            // Patch-merging projection into each next stage (4C -> 2C).
            for wnd in self.blocks.windows(2) {
                let h_next = wnd[1].hidden as f64;
                pre_params += 2.0 * h_next * h_next;
            }
        }
        if let Some(e) = &self.embedding {
            pre_params += e.params(h0);
        }
        let post_params = self.head.map_or(0.0, |h| h.params(h_last));

        Ok(ModelProfile { name: self.name.clone(), layers, pre_params, post_params })
    }

    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("family", Json::str(self.family.key())),
            (
                "blocks",
                Json::arr(self.blocks.iter().map(|b| {
                    let mut bf = vec![
                        ("count", Json::num(b.count as f64)),
                        ("hidden", Json::num(b.hidden as f64)),
                        ("heads", Json::num(b.heads as f64)),
                        ("seq", Json::num(b.seq as f64)),
                    ];
                    if let Some(w) = b.window {
                        bf.push(("window", Json::num(w as f64)));
                    }
                    if let Some(kv) = b.kv_heads {
                        bf.push(("kv_heads", Json::num(kv as f64)));
                    }
                    if let Some(c) = b.cross_seq {
                        bf.push(("cross_seq", Json::num(c as f64)));
                    }
                    if let Some(m) = b.moe {
                        bf.push((
                            "moe",
                            Json::obj(vec![
                                ("experts", Json::num(m.experts as f64)),
                                ("top_k", Json::num(m.top_k as f64)),
                            ]),
                        ));
                    }
                    Json::obj(bf)
                })),
            ),
        ];
        if let Some(e) = &self.embedding {
            let mut ef = Vec::new();
            if e.vocab > 0 {
                ef.push(("vocab", Json::num(e.vocab as f64)));
            }
            if e.positions > 0 {
                ef.push(("positions", Json::num(e.positions as f64)));
            }
            if let Some(p) = e.patch {
                ef.push((
                    "patch",
                    Json::obj(vec![
                        ("channels", Json::num(p.channels as f64)),
                        ("size", Json::num(p.size as f64)),
                    ]),
                ));
            }
            if e.extra_params != 0.0 {
                ef.push(("extra_params", Json::num(e.extra_params)));
            }
            fields.push(("embedding", Json::obj(ef)));
        }
        if let Some(h) = &self.head {
            let hv = match *h {
                HeadSpec::Classifier { classes, bias } => Json::obj(vec![
                    ("classes", Json::num(classes as f64)),
                    ("bias", Json::Bool(bias)),
                ]),
                HeadSpec::MlmVocab { vocab } => {
                    Json::obj(vec![("mlm_vocab", Json::num(vocab as f64))])
                }
            };
            fields.push(("head", hv));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<ModelSpec, SpecError> {
        let bad = |what: &str| SpecError::new(format!("model spec: missing or invalid {what}"));
        // Counts/sizes must be exact non-negative integers — reject the
        // silent truncation `Json::as_usize` would apply to e.g. 1280.9.
        let exact_usize = |x: &Json| -> Option<usize> {
            let n = x.as_f64()?;
            if n.fract() == 0.0 && (0.0..=9.007199254740992e15).contains(&n) {
                Some(n as usize)
            } else {
                None
            }
        };
        check_keys(v, &["name", "family", "blocks", "embedding", "head"], "model spec")?;
        let name = v.get("name").and_then(Json::as_str).ok_or_else(|| bad("name"))?.to_string();
        let family: Family =
            v.get("family").and_then(Json::as_str).ok_or_else(|| bad("family"))?.parse()?;
        let mut blocks = Vec::new();
        for (i, bv) in v
            .get("blocks")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("blocks (array)"))?
            .iter()
            .enumerate()
        {
            check_keys(
                bv,
                &["count", "hidden", "heads", "seq", "window", "kv_heads", "cross_seq", "moe"],
                &format!("blocks[{i}]"),
            )?;
            let req = |key: &str| {
                bv.get(key)
                    .and_then(&exact_usize)
                    .ok_or_else(|| bad(&format!("blocks[{i}].{key}")))
            };
            let opt = |key: &str| -> Result<Option<usize>, SpecError> {
                match bv.get(key) {
                    None | Some(Json::Null) => Ok(None),
                    Some(x) => Ok(Some(
                        exact_usize(x).ok_or_else(|| bad(&format!("blocks[{i}].{key}")))?,
                    )),
                }
            };
            let moe = match bv.get("moe") {
                None | Some(Json::Null) => None,
                Some(m) => {
                    check_keys(m, &["experts", "top_k"], &format!("blocks[{i}].moe"))?;
                    Some(MoeSpec {
                        experts: m
                            .get("experts")
                            .and_then(&exact_usize)
                            .ok_or_else(|| bad(&format!("blocks[{i}].moe.experts")))?,
                        top_k: m
                            .get("top_k")
                            .and_then(&exact_usize)
                            .ok_or_else(|| bad(&format!("blocks[{i}].moe.top_k")))?,
                    })
                }
            };
            blocks.push(BlockSpec {
                count: req("count")?,
                hidden: req("hidden")?,
                heads: req("heads")?,
                seq: req("seq")?,
                window: opt("window")?,
                kv_heads: opt("kv_heads")?,
                cross_seq: opt("cross_seq")?,
                moe,
            });
        }
        let embedding = match v.get("embedding") {
            None | Some(Json::Null) => None,
            Some(ev) => {
                // Absent fields default; present fields must be valid.
                let field = |key: &str| -> Result<usize, SpecError> {
                    match ev.get(key) {
                        None | Some(Json::Null) => Ok(0),
                        Some(x) => {
                            exact_usize(x).ok_or_else(|| bad(&format!("embedding.{key}")))
                        }
                    }
                };
                check_keys(
                    ev,
                    &["vocab", "positions", "patch", "extra_params"],
                    "embedding",
                )?;
                let patch = match ev.get("patch") {
                    None | Some(Json::Null) => None,
                    Some(p) => {
                        check_keys(p, &["channels", "size"], "embedding.patch")?;
                        Some(PatchSpec {
                            channels: p
                                .get("channels")
                                .and_then(&exact_usize)
                                .ok_or_else(|| bad("embedding.patch.channels"))?,
                            size: p
                                .get("size")
                                .and_then(&exact_usize)
                                .ok_or_else(|| bad("embedding.patch.size"))?,
                        })
                    }
                };
                let extra_params = match ev.get("extra_params") {
                    None | Some(Json::Null) => 0.0,
                    Some(x) => x.as_f64().ok_or_else(|| bad("embedding.extra_params"))?,
                };
                Some(EmbeddingSpec {
                    vocab: field("vocab")?,
                    positions: field("positions")?,
                    patch,
                    extra_params,
                })
            }
        };
        let head = match v.get("head") {
            None | Some(Json::Null) => None,
            Some(hv) => {
                check_keys(hv, &["classes", "bias", "mlm_vocab"], "head")?;
                if hv.get("mlm_vocab").is_some()
                    && (hv.get("classes").is_some() || hv.get("bias").is_some())
                {
                    return Err(SpecError::new(
                        "head: \"mlm_vocab\" and \"classes\"/\"bias\" are mutually \
                         exclusive — describe one head, not both",
                    ));
                }
                if let Some(x) = hv.get("mlm_vocab") {
                    Some(HeadSpec::MlmVocab {
                        vocab: exact_usize(x).ok_or_else(|| bad("head.mlm_vocab"))?,
                    })
                } else if let Some(x) = hv.get("classes") {
                    Some(HeadSpec::Classifier {
                        classes: exact_usize(x).ok_or_else(|| bad("head.classes"))?,
                        bias: hv.get("bias").and_then(Json::as_bool).unwrap_or(false),
                    })
                } else {
                    return Err(bad("head (expected {\"classes\": ...} or {\"mlm_vocab\": ...})"));
                }
            }
        };
        let spec = ModelSpec { name, family, blocks, embedding, head };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from a JSON string.
    pub fn from_json_str(s: &str) -> Result<ModelSpec, SpecError> {
        let v = Json::parse(s).map_err(|e| SpecError::new(format!("model spec: {e}")))?;
        Self::from_json(&v)
    }

    /// Load a spec from a `--model-file` JSON file.
    pub fn load(path: &Path) -> Result<ModelSpec, SpecError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| SpecError::new(format!("reading {}: {e}", path.display())))?;
        Self::from_json_str(&text)
            .map_err(|e| SpecError::new(format!("{}: {e}", path.display())))
    }

    /// Write the spec as pretty-printed JSON — the byte format of the
    /// committed `examples/models/*.json` files, so `galvatron models
    /// --out-dir` regeneration is diff-clean (pinned by `spec_tests`).
    pub fn save(&self, path: &Path) -> Result<(), SpecError> {
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| SpecError::new(format!("writing {}: {e}", path.display())))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn gpt_spec() -> ModelSpec {
        ModelSpec {
            name: "GPT-Test".into(),
            family: Family::DecoderOnly,
            blocks: vec![BlockSpec::dense(4, 1024, 16, 512)],
            embedding: Some(EmbeddingSpec { vocab: 50257, positions: 512, ..Default::default() }),
            head: None,
        }
    }

    #[test]
    fn default_train_config_matches_fp32_adam_constants() {
        let t = TrainConfig::default();
        assert!(t.is_default());
        assert_eq!(t.state_bytes_per_param(1), 16.0);
        assert_eq!(t.state_bytes_per_param(8), 16.0); // no zero -> no sharding
        assert_eq!(t.act_scale(), 1.0);
    }

    #[test]
    fn dtype_and_optimizer_accounting() {
        let sgd = TrainConfig { optimizer: OptimizerKind::Sgd, ..Default::default() };
        // Adam adds 8 bytes/param of fp32 state over SGD.
        assert_eq!(TrainConfig::default().state_bytes_per_param(1) - sgd.state_bytes_per_param(1), 8.0);
        let fp16 = TrainConfig { dtype: Dtype::Fp16, ..Default::default() };
        // fp16: 2 param + 2 grad + 4 master + 8 moments.
        assert_eq!(fp16.state_bytes_per_param(1), 16.0);
        assert_eq!(fp16.act_scale(), 0.5);
        // ZeRO shards master + moments over the DP degree.
        let zero = TrainConfig { dtype: Dtype::Bf16, zero: true, ..Default::default() };
        assert_eq!(zero.state_bytes_per_param(4), 4.0 + 12.0 / 4.0);
        assert_eq!(zero.state_bytes_per_param(1), 16.0);
    }

    #[test]
    fn train_config_json_round_trip() {
        for t in [
            TrainConfig::default(),
            TrainConfig { dtype: Dtype::Bf16, optimizer: OptimizerKind::Sgd, zero: true },
            TrainConfig { dtype: Dtype::Fp16, optimizer: OptimizerKind::Adam, zero: false },
        ] {
            let v = Json::parse(&t.to_json().to_string()).unwrap();
            assert_eq!(TrainConfig::from_json(&v).unwrap(), t);
        }
        assert!("fp8".parse::<Dtype>().is_err());
        assert!("lion".parse::<OptimizerKind>().is_err());
    }

    #[test]
    fn compile_builds_layer_sequence() {
        let m = gpt_spec().compile().unwrap();
        assert_eq!(m.n_layers(), 4);
        assert_eq!(m.layers[0].name, "dec0");
        assert_eq!(m.layers[3].name, "dec3");
        assert_eq!(m.pre_params, 50257.0 * 1024.0 + 512.0 * 1024.0);
        assert_eq!(m.post_params, 0.0);
    }

    #[test]
    fn gqa_shrinks_params_and_flops() {
        let mut spec = gpt_spec();
        let dense = spec.compile().unwrap();
        spec.blocks[0].kv_heads = Some(4);
        let gqa = spec.compile().unwrap();
        assert!(gqa.layers[0].params < dense.layers[0].params);
        assert!(gqa.layers[0].flops_fwd < dense.layers[0].flops_fwd);
        assert!(gqa.layers[0].act_bytes < dense.layers[0].act_bytes);
        // kv_heads == heads delegates to the calibrated dense block.
        spec.blocks[0].kv_heads = Some(16);
        let same = spec.compile().unwrap();
        assert_eq!(same.layers[0].params, dense.layers[0].params);
        assert_eq!(same.layers[0].act_bytes, dense.layers[0].act_bytes);
    }

    #[test]
    fn moe_scales_ffn_params_not_flops_at_top1() {
        let mut spec = gpt_spec();
        let dense = spec.compile().unwrap();
        spec.blocks[0].moe = Some(MoeSpec { experts: 8, top_k: 1 });
        let moe = spec.compile().unwrap();
        // 8 experts ≈ 7 extra FFNs of params...
        assert!(moe.layers[0].params > 4.0 * dense.layers[0].params);
        // ...but top-1 routing keeps FLOPs near the dense block (router only).
        assert!(moe.layers[0].flops_fwd < 1.1 * dense.layers[0].flops_fwd);
        spec.blocks[0].moe = Some(MoeSpec { experts: 8, top_k: 2 });
        let top2 = spec.compile().unwrap();
        assert!(top2.layers[0].flops_fwd > moe.layers[0].flops_fwd);
        assert!(top2.layers[0].act_bytes > moe.layers[0].act_bytes);
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = gpt_spec();
        s.blocks.clear();
        assert!(s.validate().is_err());

        let mut s = gpt_spec();
        s.blocks[0].heads = 7; // 1024 % 7 != 0
        assert!(s.validate().is_err());

        let mut s = gpt_spec();
        s.blocks[0].kv_heads = Some(5);
        assert!(s.validate().is_err());

        let mut s = gpt_spec();
        s.blocks[0].window = Some(4096); // > seq
        assert!(s.validate().is_err());

        let mut s = gpt_spec();
        s.blocks[0].moe = Some(MoeSpec { experts: 4, top_k: 5 });
        assert!(s.validate().is_err());

        // cross_seq outside the encoder-decoder family.
        let mut s = gpt_spec();
        s.blocks[0].cross_seq = Some(512);
        assert!(s.validate().is_err());

        // encoder-decoder without any decoder blocks.
        let mut s = gpt_spec();
        s.family = Family::EncoderDecoder;
        assert!(s.validate().is_err());

        // Negative / non-finite embedding extras.
        let mut s = gpt_spec();
        s.embedding.as_mut().unwrap().extra_params = -1e12;
        assert!(s.validate().is_err());
        s.embedding.as_mut().unwrap().extra_params = f64::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn from_json_rejects_unknown_keys() {
        // A typo'd optional key must error, not silently plan a
        // different model.
        let typo = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512,"kv_head":4}]}"#;
        let err = ModelSpec::from_json_str(typo).unwrap_err();
        assert!(err.reason.contains("kv_head"), "{err}");
        let typo = r#"{"name":"x","famly":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}]}"#;
        assert!(ModelSpec::from_json_str(typo).is_err());
        let typo = r#"{"dtype":"bf16","zer0":true}"#;
        let v = Json::parse(typo).unwrap();
        let err = TrainConfig::from_json(&v).unwrap_err();
        assert!(err.reason.contains("zer0"), "{err}");
    }

    #[test]
    fn from_json_rejects_non_object_sections_and_ambiguous_heads() {
        // A scalar where an object belongs must not parse as "empty".
        let scalar = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}],
            "embedding":50257}"#;
        assert!(ModelSpec::from_json_str(scalar).is_err());
        let v = Json::parse(r#""bf16+adam+zero""#).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        // Both head forms at once is ambiguous, not first-match-wins.
        let both = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}],
            "head":{"classes":1000,"bias":true,"mlm_vocab":30522}}"#;
        let err = ModelSpec::from_json_str(both).unwrap_err();
        assert!(err.reason.contains("mutually"), "{err}");
    }

    #[test]
    fn from_json_rejects_inexact_numerics() {
        // Fractional sizes must error, not silently truncate.
        let frac = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1280.9,"heads":16,"seq":512}]}"#;
        assert!(ModelSpec::from_json_str(frac).is_err());
        let neg = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}],
            "embedding":{"vocab":-5}}"#;
        assert!(ModelSpec::from_json_str(neg).is_err());
        let bad_head = r#"{"name":"x","family":"decoder-only",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}],
            "head":{"mlm_vocab":1.5}}"#;
        assert!(ModelSpec::from_json_str(bad_head).is_err());
    }

    #[test]
    fn spec_json_round_trip() {
        let mut spec = gpt_spec();
        spec.blocks.push(BlockSpec {
            count: 2,
            hidden: 1024,
            heads: 16,
            seq: 512,
            window: Some(128),
            kv_heads: Some(4),
            cross_seq: None,
            moe: Some(MoeSpec { experts: 8, top_k: 2 }),
        });
        spec.head = Some(HeadSpec::Classifier { classes: 1000, bias: true });
        let text = spec.to_json().to_string();
        let back = ModelSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
        // Serialization is stable.
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn file_round_trip() {
        let spec = gpt_spec();
        let path = std::env::temp_dir().join(format!("galvatron-spec-{}.json", std::process::id()));
        spec.save(&path).unwrap();
        let back = ModelSpec::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, spec);
        assert!(ModelSpec::load(Path::new("/nonexistent/spec.json")).is_err());
    }
}
