//! Failure-aware replanning: take a live plan, shrink its cluster by
//! every combination of lost islands, and replan each surviving fleet —
//! elastic training's "we just lost a rack" question as a scenario class.
//!
//! Replans reuse the warm persistent cost store of the original plan when
//! a `cache_dir` is given: the cost-table context fingerprint covers only
//! cluster-global inputs, so surviving island classes hit the tables the
//! baseline run already measured instead of rebuilding them cold.

use std::path::PathBuf;

use crate::api::{PlanError, PlanReport, PlanRequest, Planner};
use crate::cluster::{ClusterSpec, IslandSpec};
use crate::util::json::Json;

/// Knobs for a degrade run.
#[derive(Debug, Clone, Default)]
pub struct DegradeOptions {
    /// Number of islands lost simultaneously (every combination is
    /// replanned). Must be between 1 and `n_islands - 1`.
    pub lose: usize,
    pub threads: Option<usize>,
    /// Warm store shared with the baseline plan.
    pub cache_dir: Option<PathBuf>,
}

/// What happened to one shrunk cluster.
#[derive(Debug, Clone, PartialEq)]
pub enum DegradeOutcome {
    Planned {
        report: PlanReport,
        /// Degraded / baseline throughput.
        throughput_ratio: f64,
        /// Whether the replan attached to a warm persistent cost store.
        /// In-process diagnostic only (mirrors `SearchTiming`): excluded
        /// from serialization, which must stay byte-deterministic across
        /// cache states.
        warm_start: bool,
    },
    /// The model no longer fits: every candidate plan exceeded memory.
    Infeasible { reason: String },
    /// Removing these islands leaves no valid cluster (e.g. the total
    /// device count is no longer a power of two).
    Invalid { reason: String },
}

/// One lost-island combination and its replanning result.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeScenario {
    /// Indices into the baseline cluster's island list that were lost.
    pub lost_islands: Vec<usize>,
    /// Canonical islands label of the survivors.
    pub cluster: String,
    pub outcome: DegradeOutcome,
}

/// Degrade analysis of one baseline plan.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeReport {
    pub model: String,
    pub base_cluster: String,
    pub base_throughput: f64,
    pub lose: usize,
    pub scenarios: Vec<DegradeScenario>,
}

/// Replan `base` under every combination of `opts.lose` lost islands.
pub fn degrade(base: &PlanReport, opts: &DegradeOptions) -> Result<DegradeReport, PlanError> {
    let cluster = crate::check::resolve_report_cluster(base)?;
    let n = cluster.n_islands();
    if opts.lose == 0 || opts.lose >= n {
        return Err(PlanError::InvalidFleet {
            reason: format!(
                "--lose must be between 1 and {} for cluster '{}' ({n} island(s))",
                n.saturating_sub(1),
                base.cluster
            ),
        });
    }
    let mut scenarios = Vec::new();
    for lost in combinations(n, opts.lose) {
        let survivors: Vec<IslandSpec> = cluster
            .islands
            .iter()
            .enumerate()
            .filter(|(i, _)| !lost.contains(i))
            .map(|(_, isl)| isl.clone())
            .collect();
        let scenario = match ClusterSpec::from_islands("degraded", survivors, cluster.inter_bw) {
            Ok(mut shrunk) => {
                shrunk.name = shrunk.islands_label();
                let label = shrunk.name.clone();
                DegradeScenario {
                    lost_islands: lost,
                    cluster: label,
                    outcome: replan(base, shrunk, opts)?,
                }
            }
            Err(e) => DegradeScenario {
                lost_islands: lost,
                cluster: String::new(),
                outcome: DegradeOutcome::Invalid { reason: e.to_string() },
            },
        };
        scenarios.push(scenario);
    }
    Ok(DegradeReport {
        model: base.model.clone(),
        base_cluster: base.cluster.clone(),
        base_throughput: base.throughput,
        lose: opts.lose,
        scenarios,
    })
}

/// Replan the baseline's exact knobs on a shrunk cluster. Infeasibility
/// is a scenario outcome; every other planner failure propagates.
fn replan(
    base: &PlanReport,
    shrunk: ClusterSpec,
    opts: &DegradeOptions,
) -> Result<DegradeOutcome, PlanError> {
    let mut req = PlanRequest::new(&base.model, "")
        .cluster_spec(shrunk)
        .method(base.method.clone())
        .schedule(base.schedule)
        .overlap_slowdown(base.overlap_slowdown)
        .train_config(base.train)
        .max_batch(base.max_batch);
    if let Some(spec) = &base.model_spec {
        req = req.model_spec(spec.clone());
    }
    if base.cost_model.is_some() {
        // The artifact only records the calibrated backend's provenance,
        // not the profile DB itself — replans price analytically.
        crate::util::diag::warn(
            "degrade replans use the analytic cost model; the baseline plan \
             was priced by a calibrated backend",
        );
    }
    if let Some(t) = opts.threads {
        req = req.threads(t);
    }
    if let Some(dir) = &opts.cache_dir {
        req = req.cache_dir(dir.clone());
    }
    match Planner::new().plan(&req) {
        Ok(report) => {
            let warm_start =
                report.search_trace.as_ref().is_some_and(|t| t.timing.warm_start);
            let throughput_ratio = if base.throughput > 0.0 {
                report.throughput / base.throughput
            } else {
                0.0
            };
            Ok(DegradeOutcome::Planned { report, throughput_ratio, warm_start })
        }
        Err(PlanError::Infeasible { reason }) => Ok(DegradeOutcome::Infeasible { reason }),
        Err(e) => Err(e),
    }
}

/// All `k`-subsets of `0..n` in lexicographic order.
fn combinations(n: usize, k: usize) -> Vec<Vec<usize>> {
    fn rec(start: usize, n: usize, k: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if cur.len() == k {
            out.push(cur.clone());
            return;
        }
        for i in start..n {
            cur.push(i);
            rec(i + 1, n, k, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(0, n, k, &mut Vec::with_capacity(k), &mut out);
    out
}

impl DegradeScenario {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "lost_islands",
                Json::arr(self.lost_islands.iter().map(|&i| Json::num(i as f64))),
            ),
            ("cluster", Json::str(&self.cluster)),
        ];
        match &self.outcome {
            DegradeOutcome::Planned { report, throughput_ratio, .. } => {
                fields.push(("status", Json::str("planned")));
                fields.push(("throughput", Json::num(report.throughput)));
                fields.push(("throughput_ratio", Json::num(*throughput_ratio)));
                fields.push(("report", report.to_json()));
            }
            DegradeOutcome::Infeasible { reason } => {
                fields.push(("status", Json::str("infeasible")));
                fields.push(("reason", Json::str(reason)));
            }
            DegradeOutcome::Invalid { reason } => {
                fields.push(("status", Json::str("invalid")));
                fields.push(("reason", Json::str(reason)));
            }
        }
        Json::obj(fields)
    }
}

impl DegradeReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("base_cluster", Json::str(&self.base_cluster)),
            ("base_throughput", Json::num(self.base_throughput)),
            ("lose", Json::num(self.lose as f64)),
            ("scenarios", Json::arr(self.scenarios.iter().map(DegradeScenario::to_json))),
        ])
    }

    /// Human-readable scenario table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "degrade report: {} on {}, losing {} island(s)\n\
             baseline throughput: {:.2} samples/s\n",
            self.model, self.base_cluster, self.lose, self.base_throughput
        );
        for s in &self.scenarios {
            let lost: Vec<String> = s.lost_islands.iter().map(ToString::to_string).collect();
            match &s.outcome {
                DegradeOutcome::Planned { report, throughput_ratio, .. } => {
                    out.push_str(&format!(
                        "  lost [{}] -> {}: {:.2} samples/s ({:.2}x of baseline), fits\n",
                        lost.join(","),
                        s.cluster,
                        report.throughput,
                        throughput_ratio
                    ));
                }
                DegradeOutcome::Infeasible { reason } => {
                    out.push_str(&format!(
                        "  lost [{}] -> {}: does not fit ({reason})\n",
                        lost.join(","),
                        s.cluster
                    ));
                }
                DegradeOutcome::Invalid { reason } => {
                    out.push_str(&format!(
                        "  lost [{}] -> no valid cluster ({reason})\n",
                        lost.join(",")
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn combinations_are_lexicographic_and_complete() {
        assert_eq!(combinations(3, 1), vec![vec![0], vec![1], vec![2]]);
        assert_eq!(combinations(4, 2).len(), 6);
        assert_eq!(combinations(4, 2)[0], vec![0, 1]);
        assert_eq!(combinations(4, 2)[5], vec![2, 3]);
    }

    #[test]
    fn lose_bounds_are_enforced() {
        let base = PlanRequest::new("bert-huge-32", "hetero4")
            .max_batch(8)
            .threads(1)
            .plan()
            .unwrap();
        for lose in [0, 2, 3] {
            let opts = DegradeOptions { lose, ..DegradeOptions::default() };
            assert!(
                matches!(degrade(&base, &opts), Err(PlanError::InvalidFleet { .. })),
                "lose={lose} on a 2-island cluster must be rejected"
            );
        }
    }
}
