//! `FrontierReport`: the capacity-advice artifact. Records the
//! non-dominated fleets over (throughput, worst-stage memory headroom,
//! $/hr), each point embedding the full [`PlanReport`] that produced it,
//! so every recommendation can be re-checked and executed later.
//!
//! Serialization follows the plan-artifact conventions exactly: a strict
//! top-level key set, canonical JSON via [`Json::to_pretty`], and a
//! version field bumped on breaking schema changes.

use std::path::Path;

use crate::api::{PlanError, PlanReport};
use crate::util::json::Json;
use crate::util::GIB;

/// Artifact format version (bump on breaking schema changes).
pub const FRONTIER_ARTIFACT_VERSION: usize = 1;

/// Every top-level key a version-1 frontier artifact may carry. Shared by
/// the strict [`FrontierReport::from_json`] schema and the checker's
/// frontier rules; extend it together with [`FrontierReport::to_json`].
pub const FRONTIER_ARTIFACT_KEYS: &[&str] = &[
    "version",
    "model",
    "max_batch",
    "fleets_considered",
    "fleets_planned",
    "fleets_infeasible",
    "points",
];

/// Every key a frontier point may carry.
pub const FRONTIER_POINT_KEYS: &[&str] =
    &["cluster", "devices", "cost_per_hour", "throughput", "headroom_bytes", "report"];

/// One non-dominated fleet with the plan that achieves its objectives.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    /// Canonical islands label of the fleet (re-resolvable cluster name).
    pub cluster: String,
    pub devices: usize,
    /// On-demand fleet price, $/hr.
    pub cost_per_hour: f64,
    /// End-to-end samples/s of the best plan found on this fleet.
    pub throughput: f64,
    /// Worst-stage headroom: min over stages of the stage site's device
    /// memory minus the plan's peak, bytes.
    pub headroom_bytes: f64,
    /// The full plan artifact the objectives were measured from.
    pub report: PlanReport,
}

/// Pareto dominance over (throughput max, headroom max, $/hr min):
/// `a` dominates `b` when it is no worse on every objective and strictly
/// better on at least one.
pub fn dominates(a: &FrontierPoint, b: &FrontierPoint) -> bool {
    let no_worse = a.throughput >= b.throughput
        && a.headroom_bytes >= b.headroom_bytes
        && a.cost_per_hour <= b.cost_per_hour;
    let better = a.throughput > b.throughput
        || a.headroom_bytes > b.headroom_bytes
        || a.cost_per_hour < b.cost_per_hour;
    no_worse && better
}

/// Filter to the non-dominated set and put it in canonical order:
/// cheapest first, throughput descending, then cluster label — a total
/// order, so frontier artifacts are byte-deterministic.
pub fn pareto(points: Vec<FrontierPoint>) -> Vec<FrontierPoint> {
    let mut kept: Vec<FrontierPoint> = Vec::new();
    for p in points {
        if kept.iter().any(|q| dominates(q, &p)) {
            continue;
        }
        kept.retain(|q| !dominates(&p, q));
        kept.push(p);
    }
    kept.sort_by(|a, b| {
        a.cost_per_hour
            .total_cmp(&b.cost_per_hour)
            .then(b.throughput.total_cmp(&a.throughput))
            .then(a.cluster.cmp(&b.cluster))
    });
    kept
}

/// The full advice artifact: sweep accounting plus the frontier.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierReport {
    /// Model zoo name the sweep planned for.
    pub model: String,
    pub max_batch: usize,
    /// Fleets the search space enumerated.
    pub fleets_considered: usize,
    /// Fleets that survived the cheap prune and planned feasibly.
    pub fleets_planned: usize,
    /// Fleets skipped by the never-fits prune or infeasible under search.
    pub fleets_infeasible: usize,
    /// The non-dominated set, cheapest first.
    pub points: Vec<FrontierPoint>,
}

impl FrontierPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cluster", Json::str(&self.cluster)),
            ("devices", Json::num(self.devices as f64)),
            ("cost_per_hour", Json::num(self.cost_per_hour)),
            ("throughput", Json::num(self.throughput)),
            ("headroom_bytes", Json::num(self.headroom_bytes)),
            ("report", self.report.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FrontierPoint, PlanError> {
        let bad = |what: &str| PlanError::Artifact { reason: format!("missing or invalid {what}") };
        crate::util::json::check_object_keys(v, FRONTIER_POINT_KEYS, "frontier point")
            .map_err(|reason| PlanError::Artifact { reason })?;
        let getn = |key: &str| v.get(key).and_then(Json::as_f64).ok_or_else(|| bad(key));
        Ok(FrontierPoint {
            cluster: v
                .get("cluster")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("cluster"))?
                .to_string(),
            devices: v.get("devices").and_then(Json::as_usize).ok_or_else(|| bad("devices"))?,
            cost_per_hour: getn("cost_per_hour")?,
            throughput: getn("throughput")?,
            headroom_bytes: getn("headroom_bytes")?,
            report: PlanReport::from_json(v.get("report").ok_or_else(|| bad("report"))?)?,
        })
    }
}

impl FrontierReport {
    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(FRONTIER_ARTIFACT_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("fleets_considered", Json::num(self.fleets_considered as f64)),
            ("fleets_planned", Json::num(self.fleets_planned as f64)),
            ("fleets_infeasible", Json::num(self.fleets_infeasible as f64)),
            ("points", Json::arr(self.points.iter().map(FrontierPoint::to_json))),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FrontierReport, PlanError> {
        let bad = |what: &str| PlanError::Artifact { reason: format!("missing or invalid {what}") };
        crate::util::json::check_object_keys(v, FRONTIER_ARTIFACT_KEYS, "frontier artifact")
            .map_err(|reason| PlanError::Artifact { reason })?;
        let getu = |key: &str| v.get(key).and_then(Json::as_usize).ok_or_else(|| bad(key));
        let version = getu("version")?;
        if version != FRONTIER_ARTIFACT_VERSION {
            return Err(PlanError::Artifact {
                reason: format!(
                    "unsupported frontier artifact version {version} \
                     (supported: {FRONTIER_ARTIFACT_VERSION})"
                ),
            });
        }
        let mut points = Vec::new();
        for pv in v.get("points").and_then(Json::as_arr).ok_or_else(|| bad("points"))? {
            points.push(FrontierPoint::from_json(pv)?);
        }
        Ok(FrontierReport {
            model: v.get("model").and_then(Json::as_str).ok_or_else(|| bad("model"))?.to_string(),
            max_batch: getu("max_batch")?,
            fleets_considered: getu("fleets_considered")?,
            fleets_planned: getu("fleets_planned")?,
            fleets_infeasible: getu("fleets_infeasible")?,
            points,
        })
    }

    /// Canonical artifact bytes: pretty-printed, sorted keys, trailing
    /// newline — byte-identical across threads and cache states.
    pub fn to_pretty_string(&self) -> String {
        self.to_json().to_pretty()
    }

    pub fn from_json_str(s: &str) -> Result<FrontierReport, PlanError> {
        let v = Json::parse(s)
            .map_err(|e| PlanError::Artifact { reason: format!("parse: {e}") })?;
        Self::from_json(&v)
    }

    pub fn save(&self, path: &Path) -> Result<(), PlanError> {
        std::fs::write(path, self.to_pretty_string()).map_err(|e| PlanError::Artifact {
            reason: format!("writing {}: {e}", path.display()),
        })
    }

    pub fn load(path: &Path) -> Result<FrontierReport, PlanError> {
        let text = std::fs::read_to_string(path).map_err(|e| PlanError::Artifact {
            reason: format!("reading {}: {e}", path.display()),
        })?;
        Self::from_json_str(&text)
    }

    // ---- queries ---------------------------------------------------------

    /// Cheapest frontier point sustaining at least `min_throughput`
    /// samples/s. Points are stored cheapest-first, so the first match
    /// wins; ties broke deterministically at sort time.
    pub fn cheapest_at_least(&self, min_throughput: f64) -> Option<&FrontierPoint> {
        self.points.iter().find(|p| p.throughput >= min_throughput)
    }

    // ---- presentation ----------------------------------------------------

    /// Human-readable frontier table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "capacity frontier for {} (max batch {})\n\
             fleets: {} considered, {} planned, {} infeasible; {} on the frontier\n",
            self.model,
            self.max_batch,
            self.fleets_considered,
            self.fleets_planned,
            self.fleets_infeasible,
            self.points.len(),
        );
        if self.points.is_empty() {
            return out;
        }
        out.push_str(&format!(
            "  {:>8}  {:>10}  {:>9}  {:>7}  fleet\n",
            "$/hr", "samples/s", "headroom", "devices"
        ));
        for p in &self.points {
            out.push_str(&format!(
                "  {:>8.2}  {:>10.2}  {:>8.2}G  {:>7}  {}\n",
                p.cost_per_hour,
                p.throughput,
                p.headroom_bytes / GIB,
                p.devices,
                p.cluster
            ));
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn point(cluster: &str, cost: f64, thr: f64, head: f64) -> FrontierPoint {
        // A structurally minimal report is enough for frontier math tests.
        let report = PlanReport {
            model: "bert-huge-32".into(),
            model_spec: None,
            cluster: cluster.into(),
            memory_budget_gb: 16.0,
            method: crate::api::MethodSpec::Bmw { ckpt: true },
            schedule: crate::cost::pipeline::Schedule::OneFOneB,
            overlap_slowdown: 1.3,
            train: crate::model::TrainConfig::default(),
            cost_model: None,
            max_batch: 8,
            plan: crate::parallel::ParallelPlan {
                pp: 1,
                partition: vec![32],
                strategies: vec![],
                batch: 8,
                microbatches: 1,
                stage_slots: None,
            },
            throughput: thr,
            iter_time: 1.0,
            alpha_t: 1.0,
            alpha_m: 1.0,
            stages: vec![],
            search_trace: None,
        };
        FrontierPoint {
            cluster: cluster.into(),
            devices: 2,
            cost_per_hour: cost,
            throughput: thr,
            headroom_bytes: head,
            report,
        }
    }

    #[test]
    fn dominance_requires_no_worse_everywhere_and_better_somewhere() {
        let a = point("a", 1.0, 10.0, 5.0);
        let b = point("b", 2.0, 10.0, 5.0); // strictly pricier
        let c = point("c", 1.0, 12.0, 1.0); // faster but less headroom
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
        assert!(!dominates(&a, &a), "a point never dominates itself");
    }

    #[test]
    fn pareto_keeps_exactly_the_non_dominated_set_in_canonical_order() {
        let pts = vec![
            point("pricey-slow", 4.0, 5.0, 1.0),
            point("cheap-fast", 1.0, 10.0, 1.0),
            point("mid-headroom", 2.0, 8.0, 9.0),
        ];
        let frontier = pareto(pts);
        let names: Vec<&str> = frontier.iter().map(|p| p.cluster.as_str()).collect();
        assert_eq!(names, vec!["cheap-fast", "mid-headroom"]);
    }

    #[test]
    fn cheapest_query_scans_cheapest_first() {
        let report = FrontierReport {
            model: "bert-huge-32".into(),
            max_batch: 8,
            fleets_considered: 3,
            fleets_planned: 3,
            fleets_infeasible: 0,
            points: pareto(vec![
                point("cheap", 1.0, 5.0, 1.0),
                point("fast", 3.0, 20.0, 1.0),
            ]),
        };
        assert_eq!(report.cheapest_at_least(4.0).unwrap().cluster, "cheap");
        assert_eq!(report.cheapest_at_least(10.0).unwrap().cluster, "fast");
        assert!(report.cheapest_at_least(100.0).is_none());
    }

    #[test]
    fn artifact_round_trips_and_rejects_unknown_keys() {
        let report = FrontierReport {
            model: "bert-huge-32".into(),
            max_batch: 8,
            fleets_considered: 1,
            fleets_planned: 1,
            fleets_infeasible: 0,
            points: vec![point("2xRTX-TITAN-24G", 1.6, 5.0, 2.0 * GIB)],
        };
        let text = report.to_pretty_string();
        let back = FrontierReport::from_json_str(&text).unwrap();
        assert_eq!(back, report);
        assert_eq!(back.to_pretty_string(), text, "round trip is byte-stable");
        let tampered = text.replace("\"model\"", "\"modle\"");
        assert!(matches!(
            FrontierReport::from_json_str(&tampered),
            Err(PlanError::Artifact { .. })
        ));
    }
}
