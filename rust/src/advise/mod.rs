//! Elastic capacity planning (`galvatron advise`): invert the planner's
//! question. Instead of "what is the best plan on this cluster", answer
//! "which cluster should run this model" — sweep a priced fleet search
//! space, plan every viable candidate, and report the Pareto frontier
//! over (throughput, worst-stage memory headroom, $/hr), plus
//! failure-aware replanning for clusters that lose islands mid-training.
//!
//! The sweep leans on two existing subsystems:
//! - the cheap never-fits prune is the `check` GAL0030 predicate, so
//!   hopeless fleets never reach the engine;
//! - every surviving fleet plans through one shared `--cache-dir` warm
//!   store. The persistent cost-table context covers only cluster-global
//!   inputs, so fleets that share GPU classes share measured cost tables
//!   and repeat sweeps answer from the plan store without searching.

pub mod degrade;
pub mod fleet;
pub mod frontier;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::api::{MethodSpec, PlanError, PlanReport, PlanRequest, Planner};
use crate::cluster::ClusterSpec;

pub use degrade::{degrade, DegradeOptions, DegradeOutcome, DegradeReport, DegradeScenario};
pub use fleet::{
    enumerate_fleets, fleet_cost_per_hour, model_never_fits, parse_fleet_spec, price_per_gpu_hour,
    FleetClass, FleetSearchSpace,
};
pub use frontier::{
    dominates, pareto, FrontierPoint, FrontierReport, FRONTIER_ARTIFACT_KEYS,
    FRONTIER_ARTIFACT_VERSION, FRONTIER_POINT_KEYS,
};

/// A capacity-advice request: which model, over which fleet space, under
/// which planning knobs.
#[derive(Debug, Clone)]
pub struct AdviseRequest {
    /// Model zoo name.
    pub model: String,
    pub space: FleetSearchSpace,
    pub method: MethodSpec,
    pub max_batch: usize,
    pub threads: Option<usize>,
    /// Warm store shared by every fleet of the sweep (and by repeat
    /// sweeps). `None` uses a run-private scratch directory: fleets still
    /// share cost tables within the run, nothing persists after it.
    pub cache_dir: Option<PathBuf>,
}

impl AdviseRequest {
    /// Defaults mirror `galvatron plan`: the paper's full BMW method.
    pub fn new(model: &str, space: FleetSearchSpace) -> AdviseRequest {
        AdviseRequest {
            model: model.to_string(),
            space,
            method: MethodSpec::Bmw { ckpt: true },
            max_batch: 64,
            threads: None,
            cache_dir: None,
        }
    }

    pub fn method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }
}

/// Distinguishes concurrent scratch sweeps within one process (the serve
/// daemon may run several).
static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run the fleet sweep and return the Pareto frontier.
pub fn advise(req: &AdviseRequest) -> Result<FrontierReport, PlanError> {
    let model = crate::api::resolve_model_name(&req.model)?;
    let fleets = enumerate_fleets(&req.space);
    if fleets.is_empty() {
        return Err(PlanError::InvalidFleet {
            reason: "the search space enumerates no viable fleet (power-of-two device \
                     totals within the class ranges and island cap)"
                .into(),
        });
    }
    let (cache_dir, scratch) = match &req.cache_dir {
        Some(dir) => (dir.clone(), None),
        None => {
            let dir = std::env::temp_dir().join(format!(
                "galvatron-advise-{}-{}",
                std::process::id(),
                SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            (dir.clone(), Some(dir))
        }
    };
    let result = sweep(req, &model, &fleets, &cache_dir);
    if let Some(dir) = scratch {
        std::fs::remove_dir_all(&dir).ok();
    }
    result
}

fn sweep(
    req: &AdviseRequest,
    model: &crate::model::ModelProfile,
    fleets: &[ClusterSpec],
    cache_dir: &std::path::Path,
) -> Result<FrontierReport, PlanError> {
    let planner = Planner::new();
    let mut planned = 0usize;
    let mut infeasible = 0usize;
    let mut points = Vec::new();
    for cluster in fleets {
        if model_never_fits(model, cluster) {
            infeasible += 1;
            continue;
        }
        let mut preq = PlanRequest::new(&req.model, "")
            .cluster_spec(cluster.clone())
            .method(req.method.clone())
            .max_batch(req.max_batch)
            .cache_dir(cache_dir.to_path_buf());
        if let Some(t) = req.threads {
            preq = preq.threads(t);
        }
        match planner.plan(&preq) {
            Ok(report) => {
                planned += 1;
                points.push(point_from_report(cluster, report));
            }
            Err(PlanError::Infeasible { .. }) => infeasible += 1,
            Err(e) => return Err(e),
        }
    }
    Ok(FrontierReport {
        model: req.model.clone(),
        max_batch: req.max_batch,
        fleets_considered: fleets.len(),
        fleets_planned: planned,
        fleets_infeasible: infeasible,
        points: pareto(points),
    })
}

fn point_from_report(cluster: &ClusterSpec, report: PlanReport) -> FrontierPoint {
    FrontierPoint {
        cluster: cluster.name.clone(),
        devices: cluster.n_devices(),
        cost_per_hour: fleet_cost_per_hour(cluster),
        throughput: report.throughput,
        headroom_bytes: headroom_bytes(cluster, &report),
        report,
    }
}

/// Worst-stage memory headroom of a plan on its cluster: the minimum over
/// pipeline stages of the stage site's device memory minus the plan's
/// peak for that stage, bytes.
pub fn headroom_bytes(cluster: &ClusterSpec, report: &PlanReport) -> f64 {
    let sites = cluster.stage_sites(report.plan.pp);
    let mut min = f64::INFINITY;
    for (s, stage) in report.stages.iter().enumerate() {
        let Some(site) = sites.get(report.plan.slot_of(s)) else { continue };
        let headroom = site.gpu.mem_bytes - stage.peak_mem_bytes;
        if headroom < min {
            min = headroom;
        }
    }
    if min.is_finite() {
        min
    } else {
        0.0
    }
}
