//! Fleet search space: the priced GPU catalog and the deterministic
//! enumeration of candidate island assemblies for `galvatron advise`.
//!
//! A fleet spec like `A100-80G:0..8,RTX-TITAN-24G:0..8` gives each GPU
//! class an inclusive device-count range. Enumeration considers, per
//! class, zero devices plus every power of two inside the range (islands
//! hold power-of-two device counts), assembles one island per non-empty
//! class in spec order, and keeps assemblies whose total device count is
//! itself a power of two — [`ClusterSpec::from_islands`] would reject
//! anything else. `--max-islands` caps the number of non-empty classes
//! per fleet.
//!
//! Pricing is a static on-demand $/hr table over the GPU catalog;
//! [`fleet_cost_per_hour`] prices a whole `ClusterSpec` against it.

use crate::api::PlanError;
use crate::cluster::{gpu_by_name, gpu_class_names, ClusterSpec, IslandSpec};
use crate::model::ModelProfile;
use crate::util::GIB;

/// Inter-island bandwidth every enumerated fleet is wired with — the same
/// 100 Gb IB figure `parse_islands` assumes, so a fleet's canonical
/// islands label re-resolves to an identical `ClusterSpec`.
const FLEET_INTER_BW: f64 = 10.0 * GIB;

/// Ranges beyond this are a typo, not a data center.
const MAX_FLEET_DEVICES: usize = 4096;

/// On-demand $/hr for one device of the named catalog class (aliases
/// accepted). `None` for names outside the catalog.
pub fn price_per_gpu_hour(name: &str) -> Option<f64> {
    let (gpu, _) = gpu_by_name(name)?;
    Some(match gpu.name.as_str() {
        "A100-80G" => 3.5,
        "A100-40G" => 2.5,
        "RTX-TITAN-24G" => 0.8,
        _ => 0.1, // "cpu": priced so it never looks free
    })
}

/// Total on-demand price of a cluster, $/hr. Classes outside the catalog
/// (impossible for enumerated fleets) price at zero.
pub fn fleet_cost_per_hour(cluster: &ClusterSpec) -> f64 {
    cluster
        .islands
        .iter()
        .map(|i| i.count as f64 * price_per_gpu_hour(&i.gpu.name).unwrap_or(0.0))
        .sum()
}

/// One GPU class of a fleet search space: a catalog name plus the
/// inclusive device-count range it may contribute.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetClass {
    /// Canonical catalog name (e.g. `A100-80G`).
    pub gpu: String,
    pub min_devices: usize,
    pub max_devices: usize,
}

/// A typed fleet search space: GPU classes in spec order (island assembly
/// preserves it) plus the island-count cap.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSearchSpace {
    pub classes: Vec<FleetClass>,
    /// Maximum number of non-empty classes (= islands) per fleet.
    pub max_islands: usize,
}

/// Parse `NAME:lo..hi[,NAME:lo..hi...]` into a search space. Class names
/// go through the GPU catalog (aliases fold to canonical names); errors
/// surface as [`PlanError::InvalidFleet`].
pub fn parse_fleet_spec(spec: &str, max_islands: usize) -> Result<FleetSearchSpace, PlanError> {
    let invalid = |reason: String| PlanError::InvalidFleet { reason };
    if max_islands == 0 {
        return Err(invalid("--max-islands must be at least 1".into()));
    }
    let mut classes: Vec<FleetClass> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        let (name, range) = part
            .split_once(':')
            .ok_or_else(|| invalid(format!("{part:?} is not of the form NAME:lo..hi")))?;
        let (gpu, _) = gpu_by_name(name.trim()).ok_or_else(|| {
            invalid(format!(
                "unknown GPU class {:?} (catalog: {})",
                name.trim(),
                gpu_class_names().join(", ")
            ))
        })?;
        let (lo, hi) = range
            .split_once("..")
            .ok_or_else(|| invalid(format!("range {range:?} is not of the form lo..hi")))?;
        let parse_count = |s: &str| -> Result<usize, PlanError> {
            s.trim()
                .parse()
                .map_err(|_| invalid(format!("{s:?} is not a device count in {part:?}")))
        };
        let (lo, hi) = (parse_count(lo)?, parse_count(hi)?);
        if lo > hi {
            return Err(invalid(format!("empty device range {lo}..{hi} for {}", gpu.name)));
        }
        if hi > MAX_FLEET_DEVICES {
            return Err(invalid(format!(
                "{hi} devices of {} exceeds the {MAX_FLEET_DEVICES}-device fleet limit",
                gpu.name
            )));
        }
        if classes.iter().any(|c| c.gpu == gpu.name) {
            return Err(invalid(format!("GPU class {} listed twice", gpu.name)));
        }
        classes.push(FleetClass { gpu: gpu.name, min_devices: lo, max_devices: hi });
    }
    Ok(FleetSearchSpace { classes, max_islands })
}

/// The device counts a class may contribute: zero (when the range allows
/// it) plus every power of two inside the range.
fn candidate_counts(class: &FleetClass) -> Vec<usize> {
    let mut counts = Vec::new();
    if class.min_devices == 0 {
        counts.push(0);
    }
    let mut p = 1usize;
    while p <= class.max_devices {
        if p >= class.min_devices.max(1) {
            counts.push(p);
        }
        p *= 2;
    }
    counts
}

/// Enumerate every viable fleet of the space, in deterministic order
/// (classes in spec order, device counts ascending). Each fleet's `name`
/// is its canonical islands label, so plan artifacts embedded in a
/// frontier re-resolve by name.
pub fn enumerate_fleets(space: &FleetSearchSpace) -> Vec<ClusterSpec> {
    let per_class: Vec<Vec<usize>> = space.classes.iter().map(candidate_counts).collect();
    let mut counts = vec![0usize; space.classes.len()];
    let mut fleets = Vec::new();
    enumerate_rec(space, &per_class, 0, &mut counts, &mut fleets);
    fleets
}

fn enumerate_rec(
    space: &FleetSearchSpace,
    per_class: &[Vec<usize>],
    depth: usize,
    counts: &mut Vec<usize>,
    out: &mut Vec<ClusterSpec>,
) {
    if depth == per_class.len() {
        if let Some(fleet) = build_fleet(space, counts) {
            out.push(fleet);
        }
        return;
    }
    for &n in &per_class[depth] {
        counts[depth] = n;
        enumerate_rec(space, per_class, depth + 1, counts, out);
    }
}

fn build_fleet(space: &FleetSearchSpace, counts: &[usize]) -> Option<ClusterSpec> {
    let total: usize = counts.iter().sum();
    let islands_used = counts.iter().filter(|&&n| n > 0).count();
    if total == 0 || !total.is_power_of_two() || islands_used > space.max_islands {
        return None;
    }
    let mut islands = Vec::new();
    for (class, &n) in space.classes.iter().zip(counts) {
        if n == 0 {
            continue;
        }
        let (gpu, intra_bw) = gpu_by_name(&class.gpu)?;
        islands.push(IslandSpec { gpu, count: n, intra_bw });
    }
    // Power-of-two counts and total make this infallible; a `None` here
    // would mean the filters above and `from_islands` disagree.
    let mut cluster = ClusterSpec::from_islands("fleet", islands, FLEET_INTER_BW).ok()?;
    cluster.name = cluster.islands_label();
    Some(cluster)
}

/// The `check` GAL0030 predicate, reused as the sweep's cheap prune: fp32
/// weights alone exceed the fleet's aggregate device memory, so no plan
/// can ever fit and the engine need not run.
pub fn model_never_fits(model: &ModelProfile, cluster: &ClusterSpec) -> bool {
    let weight_bytes = model.total_params() * 4.0;
    let capacity: f64 =
        cluster.islands.iter().map(|i| i.count as f64 * i.gpu.mem_bytes).sum();
    weight_bytes > capacity
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_canonicalizes_classes() {
        let space = parse_fleet_spec("titan:0..4, a100:1..2", 2).unwrap();
        assert_eq!(space.classes.len(), 2);
        assert_eq!(space.classes[0].gpu, "RTX-TITAN-24G");
        assert_eq!(space.classes[1].gpu, "A100-40G");
        assert_eq!((space.classes[1].min_devices, space.classes[1].max_devices), (1, 2));
    }

    #[test]
    fn parse_errors_are_typed() {
        for bad in [
            "A100-80G", // no range
            "A100-80G:4", // not lo..hi
            "A100-80G:4..2", // empty range
            "A100-80G:0..x", // not a count
            "H999:0..4", // unknown class
            "A100-80G:0..4,a100-80g:0..4", // duplicate class
            "A100-80G:0..100000", // absurd
        ] {
            match parse_fleet_spec(bad, 2) {
                Err(PlanError::InvalidFleet { .. }) => {}
                other => panic!("{bad:?} parsed as {other:?}"),
            }
        }
        assert!(matches!(
            parse_fleet_spec("A100-80G:0..4", 0),
            Err(PlanError::InvalidFleet { .. })
        ));
    }

    #[test]
    fn enumeration_is_deterministic_and_power_of_two_only() {
        let space = parse_fleet_spec("RTX-TITAN-24G:0..2,A100-40G:0..2", 2).unwrap();
        let labels: Vec<String> =
            enumerate_fleets(&space).into_iter().map(|c| c.name).collect();
        assert_eq!(
            labels,
            vec![
                "1xA100-40G",
                "2xA100-40G",
                "1xRTX-TITAN-24G",
                "1xRTX-TITAN-24G,1xA100-40G",
                "2xRTX-TITAN-24G",
                "2xRTX-TITAN-24G,2xA100-40G",
            ]
        );
    }

    #[test]
    fn max_islands_caps_nonempty_classes() {
        let space = parse_fleet_spec("RTX-TITAN-24G:0..2,A100-40G:0..2", 1).unwrap();
        let fleets = enumerate_fleets(&space);
        assert!(fleets.iter().all(|c| c.n_islands() == 1), "mixed fleet survived cap");
        assert_eq!(fleets.len(), 4);
    }

    #[test]
    fn fleets_reresolve_by_their_own_label() {
        let space = parse_fleet_spec("RTX-TITAN-24G:2..2,A100-80G:2..2", 2).unwrap();
        let fleets = enumerate_fleets(&space);
        assert_eq!(fleets.len(), 1);
        let reresolved = crate::api::resolve_cluster_name(&fleets[0].name).unwrap();
        assert_eq!(reresolved, fleets[0]);
    }

    #[test]
    fn pricing_sums_per_device_rates() {
        let space = parse_fleet_spec("RTX-TITAN-24G:2..2,A100-40G:2..2", 2).unwrap();
        let fleet = enumerate_fleets(&space).remove(0);
        let cost = fleet_cost_per_hour(&fleet);
        assert!((cost - (2.0 * 0.8 + 2.0 * 2.5)).abs() < 1e-9, "cost {cost}");
        assert_eq!(price_per_gpu_hour("titan"), Some(0.8));
        assert_eq!(price_per_gpu_hour("nope"), None);
    }

    #[test]
    fn never_fits_prunes_undersized_fleets() {
        let model = crate::model::model_by_name("gpt3-15b").unwrap();
        let space = parse_fleet_spec("RTX-TITAN-24G:1..1", 1).unwrap();
        let fleet = enumerate_fleets(&space).remove(0);
        assert!(model_never_fits(&model, &fleet));
        let small = crate::model::model_by_name("bert-huge-32").unwrap();
        assert!(!model_never_fits(&small, &fleet));
    }
}
