//! The public planning API — the one stable surface over the search
//! machinery (paper §IV) for library users, the CLI, the experiment
//! regenerators, and the benches.
//!
//! The pieces:
//!
//!   * [`PlanRequest`] — builder describing *what* to plan: model and
//!     cluster (by name, by declarative [`crate::model::ModelSpec`] —
//!     inline or via `model_file("my-model.json")` — or as a compiled
//!     profile), training numerics ([`crate::model::TrainConfig`]: dtype,
//!     optimizer, ZeRO), memory budget, method, schedule,
//!     batch/microbatch caps, overlap factor, pipeline-degree pins.
//!   * [`MethodSpec`] — the typed strategy catalog (every row of the
//!     paper's Tables II-VI); replaces the magic strings formerly
//!     dispatched by `search::baselines::run_method`.
//!   * [`Planner`] — resolves and validates a request, runs the search,
//!     and returns a [`PlanReport`] or a typed [`PlanError`]
//!     (unknown names carry did-you-mean suggestions; OOM is
//!     [`PlanError::Infeasible`], not a panic or a bare `None`).
//!   * [`PlanReport`] — the serializable plan artifact: the
//!     [`crate::parallel::ParallelPlan`] plus cost breakdown, per-stage
//!     memory/bubble diagnostics, and the engine's [`SearchTrace`]
//!     (cells explored/pruned, cache hit rate, winning cell). Round-trips
//!     through JSON via [`crate::util::json`], so `galvatron plan --out
//!     plan.json` → `galvatron simulate --plan plan.json` is a real
//!     pipeline.
//!
//! ```no_run
//! use galvatron::api::{MethodSpec, PlanRequest, Planner};
//!
//! let report = PlanRequest::new("bert-huge-32", "titan8")
//!     .memory_gb(16.0)
//!     .method(MethodSpec::Bmw { ckpt: true })
//!     .plan()?;
//! report.save(std::path::Path::new("plan.json"))?;
//! let sim = Planner::new().simulate_report(&report)?;
//! println!("est {:.2} / sim {:.2} samples/s", report.throughput, sim.throughput);
//! # Ok::<(), galvatron::api::PlanError>(())
//! ```

pub mod error;
pub mod method;
pub mod report;
pub mod request;

// Capacity advice rides on the same stable surface: `AdviseRequest` in,
// `FrontierReport` artifact out (see [`crate::advise`]).
pub use crate::advise::{AdviseRequest, FrontierPoint, FrontierReport};
pub use crate::cost::{CostModel, CostProvenance, ProfileDb};
pub use crate::search::engine::{CellTrace, SearchTiming, SearchTrace};
pub use error::{suggest, PlanError};
pub use method::{MethodSpec, PartitionPolicy, SearchOverrides};
pub use report::{PlanReport, StageReport, PLAN_ARTIFACT_KEYS, PLAN_ARTIFACT_VERSION};
pub use request::{
    parse_schedule, request_fingerprint, resolve_cluster_name, resolve_model_name, schedule_key,
    ClusterSource, ModelSource, PlanRequest, PlanSource, Planner, ResolvedRequest,
};
