//! `PlanReport`: the planner's output as a persistent, serializable
//! artifact. `galvatron plan --out plan.json` writes one;
//! `galvatron simulate --plan plan.json` (and eventually `train`) consumes
//! it, so a plan found once can be re-validated and executed later.

use std::path::Path;

use crate::cost::pipeline::Schedule;
use crate::cost::CostProvenance;
use crate::model::{model_by_name, ModelSpec, TrainConfig};
use crate::parallel::ParallelPlan;
use crate::search::engine::SearchTrace;
use crate::search::SearchOutcome;
use crate::util::json::Json;
use crate::util::GIB;

use super::error::PlanError;
use super::method::MethodSpec;
use super::request::{parse_schedule, schedule_key, ResolvedRequest};

/// Artifact format version (bump on breaking schema changes).
pub const PLAN_ARTIFACT_VERSION: usize = 1;

/// Every top-level key a version-1 plan artifact may carry. Shared by the
/// strict [`PlanReport::from_json`] schema and the checker's GAL0010
/// unknown-key rule; extend it together with [`PlanReport::to_json`].
pub const PLAN_ARTIFACT_KEYS: &[&str] = &[
    "version",
    "model",
    "model_spec",
    "cluster",
    "memory_budget_gb",
    "method",
    "schedule",
    "overlap_slowdown",
    "train",
    "cost_model",
    "max_batch",
    "plan",
    "throughput",
    "iter_time",
    "alpha_t",
    "alpha_m",
    "stages",
    "search_trace",
];

/// Per-stage diagnostics carried by a report.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    /// Model layer range `[start, end)` assigned to this stage.
    pub layers: (usize, usize),
    /// Peak memory under the report's schedule, bytes.
    pub peak_mem_bytes: f64,
    /// Per-microbatch stage time without gradient sync, seconds.
    pub time_nosync: f64,
    /// Per-microbatch stage time of the last (syncing) microbatch.
    pub time_sync: f64,
    /// Estimated pipeline-bubble fraction for this stage (Eq. 9 view:
    /// 1 - m·C_i / iter_time, clamped to [0, 1]).
    pub est_bubble: f64,
}

/// A complete planning result: the plan itself plus enough context
/// (model/cluster names, budget, method, schedule) to re-resolve,
/// re-simulate, and eventually execute it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanReport {
    /// Model zoo name (re-resolvable via `model_by_name`), or the name of
    /// the recorded [`PlanReport::model_spec`].
    pub model: String,
    /// The declarative model spec this plan was made from, when the model
    /// came from a `--model-file` / inline spec that the zoo cannot
    /// re-resolve by name. Keeps such artifacts self-contained for the
    /// `simulate --plan` leg; `None` (and absent from the JSON) for zoo
    /// models, so their artifacts keep the historical byte layout.
    pub model_spec: Option<ModelSpec>,
    /// Cluster preset name (re-resolvable via `cluster_by_name`).
    pub cluster: String,
    /// Per-device memory budget the plan was found under, GB.
    pub memory_budget_gb: f64,
    pub method: MethodSpec,
    pub schedule: Schedule,
    pub overlap_slowdown: f64,
    /// Training numerics the memory accounting used. Serialized only when
    /// non-default, keeping default artifacts byte-identical.
    pub train: TrainConfig,
    /// Which cost-model backend priced the search (backend name + profile
    /// DB content hash). `None` — and absent from the JSON — for the
    /// default analytic backend, so existing artifacts keep their byte
    /// layout; `simulate --plan` compares this against the backend it is
    /// about to simulate with and warns on mismatch.
    pub cost_model: Option<CostProvenance>,
    pub max_batch: usize,
    pub plan: ParallelPlan,
    /// Estimated throughput, samples/second (Eq. 9).
    pub throughput: f64,
    /// Estimated end-to-end iteration time, seconds.
    pub iter_time: f64,
    /// Time balance degree alpha_t (Eq. 6).
    pub alpha_t: f64,
    /// Memory balance degree alpha_m (Eq. 6).
    pub alpha_m: f64,
    pub stages: Vec<StageReport>,
    /// Structured diagnostics of the search that found this plan (cells
    /// explored/pruned, cache statistics, winning cell). `None` for
    /// artifacts written before the search engine existed — every other
    /// field stands alone.
    pub search_trace: Option<SearchTrace>,
}

impl PlanReport {
    /// Package a search outcome found for a resolved request.
    pub fn from_outcome(
        r: &ResolvedRequest,
        out: &SearchOutcome,
        search_trace: Option<SearchTrace>,
    ) -> PlanReport {
        let schedule = r.overrides.schedule.unwrap_or_else(|| r.method.default_schedule());
        let overlap = r
            .overrides
            .overlap_slowdown
            .unwrap_or(crate::cost::DEFAULT_OVERLAP_SLOWDOWN);
        let m = out.plan.microbatches as f64;
        let iter = out.cost.iter_time;
        let stages = out
            .cost
            .stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                let range = out.plan.stage_layers(s);
                let bubble = if iter > 0.0 {
                    (1.0 - m * st.time_nosync / iter).clamp(0.0, 1.0)
                } else {
                    0.0
                };
                StageReport {
                    layers: (range.start, range.end),
                    peak_mem_bytes: st.peak_mem,
                    time_nosync: st.time_nosync,
                    time_sync: st.time_sync,
                    est_bubble: bubble,
                }
            })
            .collect();
        // Record the spec only when the zoo cannot faithfully re-resolve
        // the model by name: zoo-equivalent specs keep the artifact
        // byte-identical to a by-name plan.
        let model_spec = r
            .model_spec
            .as_ref()
            .filter(|_| match model_by_name(&r.model_name) {
                Some(zoo) => zoo != r.model,
                None => true,
            })
            .cloned();
        PlanReport {
            model: r.model_name.clone(),
            model_spec,
            cluster: r.cluster_name.clone(),
            // Heterogeneous clusters: the floor island's capacity (their
            // per-island budgets are fixed by the cluster itself).
            memory_budget_gb: r.cluster.gpu().mem_bytes / GIB,
            method: r.method.clone(),
            schedule,
            overlap_slowdown: overlap,
            train: r.train,
            cost_model: r.cost_model.provenance(),
            max_batch: r.overrides.max_batch,
            plan: out.plan.clone(),
            throughput: out.cost.throughput,
            iter_time: out.cost.iter_time,
            alpha_t: out.cost.alpha_t,
            alpha_m: out.cost.alpha_m,
            stages,
            search_trace,
        }
    }

    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("version", Json::num(PLAN_ARTIFACT_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("cluster", Json::str(&self.cluster)),
            ("memory_budget_gb", Json::num(self.memory_budget_gb)),
            ("method", self.method.to_json()),
            ("schedule", Json::str(schedule_key(self.schedule))),
            ("overlap_slowdown", Json::num(self.overlap_slowdown)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("plan", self.plan.to_json()),
            ("throughput", Json::num(self.throughput)),
            ("iter_time", Json::num(self.iter_time)),
            ("alpha_t", Json::num(self.alpha_t)),
            ("alpha_m", Json::num(self.alpha_m)),
            (
                "stages",
                Json::arr(self.stages.iter().map(|s| {
                    Json::obj(vec![
                        (
                            "layers",
                            Json::arr(vec![
                                Json::num(s.layers.0 as f64),
                                Json::num(s.layers.1 as f64),
                            ]),
                        ),
                        ("peak_mem_bytes", Json::num(s.peak_mem_bytes)),
                        ("time_nosync", Json::num(s.time_nosync)),
                        ("time_sync", Json::num(s.time_sync)),
                        ("est_bubble", Json::num(s.est_bubble)),
                    ])
                })),
            ),
            (
                "search_trace",
                match &self.search_trace {
                    Some(t) => t.to_json(),
                    None => Json::Null,
                },
            ),
        ];
        // Emitted only when present / non-default, so artifacts planned
        // from zoo names with default numerics keep their byte layout.
        if let Some(spec) = &self.model_spec {
            fields.push(("model_spec", spec.to_json()));
        }
        if !self.train.is_default() {
            fields.push(("train", self.train.to_json()));
        }
        if let Some(prov) = &self.cost_model {
            fields.push(("cost_model", prov.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<PlanReport, PlanError> {
        let bad = |what: &str| PlanError::Artifact { reason: format!("missing or invalid {what}") };
        // Same strictness ModelSpec already has: a misspelled key must
        // error, not silently describe a different plan.
        crate::util::json::check_object_keys(v, PLAN_ARTIFACT_KEYS, "plan artifact")
            .map_err(|reason| PlanError::Artifact { reason })?;
        let version = v.get("version").and_then(Json::as_usize).ok_or_else(|| bad("version"))?;
        if version != PLAN_ARTIFACT_VERSION {
            return Err(PlanError::Artifact {
                reason: format!(
                    "unsupported plan artifact version {version} (supported: {PLAN_ARTIFACT_VERSION})"
                ),
            });
        }
        let gets = |key: &str| -> Result<String, PlanError> {
            Ok(v.get(key).and_then(Json::as_str).ok_or_else(|| bad(key))?.to_string())
        };
        let getn = |key: &str| v.get(key).and_then(Json::as_f64).ok_or_else(|| bad(key));
        let method = MethodSpec::from_json(v.get("method").ok_or_else(|| bad("method"))?)?;
        let schedule = parse_schedule(&gets("schedule")?)?;
        let plan = ParallelPlan::from_json(v.get("plan").ok_or_else(|| bad("plan"))?)
            .map_err(|e| PlanError::Artifact { reason: format!("plan: {e}") })?;
        let mut stages = Vec::new();
        for sv in v.get("stages").and_then(Json::as_arr).ok_or_else(|| bad("stages"))? {
            let layers = sv
                .get("layers")
                .and_then(Json::as_usize_vec)
                .filter(|l| l.len() == 2)
                .ok_or_else(|| bad("stage layers"))?;
            let f = |key: &str| sv.get(key).and_then(Json::as_f64).ok_or_else(|| bad(key));
            stages.push(StageReport {
                layers: (layers[0], layers[1]),
                peak_mem_bytes: f("peak_mem_bytes")?,
                time_nosync: f("time_nosync")?,
                time_sync: f("time_sync")?,
                est_bubble: f("est_bubble")?,
            });
        }
        // Optional (absent in pre-engine artifacts); reject mistyped data.
        let search_trace = match v.get("search_trace") {
            None | Some(Json::Null) => None,
            Some(t) => Some(SearchTrace::from_json(t).ok_or_else(|| bad("search_trace"))?),
        };
        // Optional: absent for zoo models / default numerics.
        let model_spec = match v.get("model_spec") {
            None | Some(Json::Null) => None,
            Some(s) => Some(ModelSpec::from_json(s).map_err(PlanError::from)?),
        };
        let train = match v.get("train") {
            None | Some(Json::Null) => TrainConfig::default(),
            Some(t) => TrainConfig::from_json(t).map_err(PlanError::from)?,
        };
        // Optional: absent for analytic (default-backend) plans.
        let cost_model = match v.get("cost_model") {
            None | Some(Json::Null) => None,
            Some(c) => Some(CostProvenance::from_json(c).ok_or_else(|| bad("cost_model"))?),
        };
        Ok(PlanReport {
            model: gets("model")?,
            model_spec,
            cluster: gets("cluster")?,
            memory_budget_gb: getn("memory_budget_gb")?,
            method,
            schedule,
            overlap_slowdown: getn("overlap_slowdown")?,
            train,
            cost_model,
            max_batch: v.get("max_batch").and_then(Json::as_usize).ok_or_else(|| bad("max_batch"))?,
            plan,
            throughput: getn("throughput")?,
            iter_time: getn("iter_time")?,
            alpha_t: getn("alpha_t")?,
            alpha_m: getn("alpha_m")?,
            stages,
            search_trace,
        })
    }

    /// Serialize to a compact JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Parse from a JSON string. Recognizes the `OOM` marker the CLI's
    /// `plan --out` writes for infeasible runs (kept byte-deterministic
    /// for CI gates) and reports it as a clear artifact error instead of
    /// a raw JSON parse failure.
    pub fn from_json_str(s: &str) -> Result<PlanReport, PlanError> {
        if s.trim() == "OOM" {
            return Err(PlanError::Artifact {
                reason: "artifact is an OOM marker: the planning run found no feasible plan \
                         (re-plan with a larger memory budget or different knobs)"
                    .into(),
            });
        }
        let v = Json::parse(s)
            .map_err(|e| PlanError::Artifact { reason: format!("parse: {e}") })?;
        Self::from_json(&v)
    }

    /// Write the artifact to disk.
    pub fn save(&self, path: &Path) -> Result<(), PlanError> {
        std::fs::write(path, self.to_json_string()).map_err(|e| PlanError::Artifact {
            reason: format!("writing {}: {e}", path.display()),
        })
    }

    /// Read an artifact from disk.
    pub fn load(path: &Path) -> Result<PlanReport, PlanError> {
        let text = std::fs::read_to_string(path).map_err(|e| PlanError::Artifact {
            reason: format!("reading {}: {e}", path.display()),
        })?;
        Self::from_json_str(&text)
    }

    // ---- presentation ----------------------------------------------------

    /// Human-readable summary (plan shape + cost + per-stage diagnostics).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let train = if self.train.is_default() {
            String::new()
        } else {
            format!(" | {}", self.train.label())
        };
        let backend = match &self.cost_model {
            Some(prov) => format!(" | {} cost model", prov.label()),
            None => String::new(),
        };
        out.push_str(&format!(
            "{} on {} @ {:.0} GB | {} | {} schedule{train}{backend}\n",
            self.model,
            self.cluster,
            self.memory_budget_gb,
            self.method.canonical_name(),
            crate::search::schedule_name(self.schedule),
        ));
        out.push_str(&self.plan.summary());
        out.push_str(&format!(
            "estimated: {:.2} samples/s, iter {:.3}s, alpha_t {:.3}, alpha_m {:.3}\n",
            self.throughput, self.iter_time, self.alpha_t, self.alpha_m
        ));
        for (i, s) in self.stages.iter().enumerate() {
            out.push_str(&format!(
                "  stage {i}: layers {}..{}, peak {:.2} GiB, mb time {:.4}s (sync {:.4}s), est bubble {:.1}%\n",
                s.layers.0,
                s.layers.1,
                s.peak_mem_bytes / GIB,
                s.time_nosync,
                s.time_sync,
                s.est_bubble * 100.0
            ));
        }
        if let Some(t) = &self.search_trace {
            out.push_str(&t.summary());
            out.push('\n');
        }
        out
    }
}
