//! Planner error type: every failure mode of the public API, with
//! did-you-mean suggestions for name lookups instead of panics.

use std::fmt;

/// Why a [`super::PlanRequest`] could not be turned into a
/// [`super::PlanReport`].
#[derive(Debug, Clone, PartialEq)]
pub enum PlanError {
    /// The requested model name is not in the Table I zoo.
    UnknownModel { name: String, suggestion: Option<String> },
    /// The requested cluster name is not a known preset.
    UnknownCluster { name: String, suggestion: Option<String> },
    /// The requested method name is not in the strategy catalog.
    UnknownMethod { name: String, suggestion: Option<String> },
    /// The request is structurally invalid (zero batch, bad schedule, ...).
    InvalidRequest { reason: String },
    /// A model spec (inline, or loaded from a `--model-file` JSON path)
    /// failed to load, parse, or validate — the typed surface of
    /// [`crate::model::SpecError`].
    InvalidModel { reason: String },
    /// The cluster description is invalid (bad island list, unknown GPU
    /// class, non-power-of-two shapes) — the typed surface of
    /// [`crate::cluster::ClusterError`].
    InvalidCluster { reason: String },
    /// A cost-model profile database (`--profile-db`) could not be read,
    /// parsed, or holds out-of-range data — the malformed surface of
    /// [`crate::cost::ProfileDbError`].
    InvalidProfileDb { reason: String },
    /// A profile database loaded but lacks the samples the calibrated
    /// cost-model backend needs (empty layer table, too few collective
    /// points to fit the alpha-beta link model).
    ProfileDbCoverage { reason: String },
    /// Every candidate plan exceeded the device memory budget ("OOM" in
    /// the paper's tables).
    Infeasible { reason: String },
    /// A fleet search space (`advise --gpus`) could not be parsed, or the
    /// degrade/sweep request is out of range for its cluster.
    InvalidFleet { reason: String },
    /// A plan artifact could not be read, written, or parsed.
    Artifact { reason: String },
    /// A plan artifact parsed but failed the static checker's
    /// Error-severity gate (see [`crate::check::gate`]): the plan it
    /// describes is illegal for the model/cluster it names.
    InvalidArtifact { diagnostics: Vec<crate::check::Diagnostic> },
}

impl PlanError {
    fn write_unknown(
        f: &mut fmt::Formatter<'_>,
        kind: &str,
        name: &str,
        suggestion: &Option<String>,
        listing: &str,
    ) -> fmt::Result {
        write!(f, "unknown {kind} {name:?}")?;
        if let Some(s) = suggestion {
            write!(f, "; did you mean {s:?}?")?;
        }
        write!(f, " (run `galvatron {listing}` for the full list)")
    }
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::UnknownModel { name, suggestion } => {
                // Mirror the `--islands` hint of InvalidCluster: the model
                // argument has a second, file-based form.
                Self::write_unknown(f, "model", name, suggestion, "models")?;
                write!(f, "; a model argument ending in \".json\" is loaded as a ModelSpec file")
            }
            PlanError::UnknownCluster { name, suggestion } => {
                Self::write_unknown(f, "cluster", name, suggestion, "clusters")
            }
            PlanError::UnknownMethod { name, suggestion } => {
                Self::write_unknown(f, "method", name, suggestion, "methods")
            }
            PlanError::InvalidRequest { reason } => write!(f, "invalid plan request: {reason}"),
            PlanError::InvalidModel { reason } => write!(f, "invalid model spec: {reason}"),
            PlanError::InvalidCluster { reason } => write!(f, "invalid cluster: {reason}"),
            PlanError::InvalidProfileDb { reason } => {
                write!(f, "invalid profile db: {reason}")
            }
            PlanError::ProfileDbCoverage { reason } => {
                write!(f, "profile db coverage: {reason}")
            }
            PlanError::Infeasible { reason } => write!(f, "infeasible: {reason}"),
            PlanError::InvalidFleet { reason } => write!(f, "invalid fleet: {reason}"),
            PlanError::Artifact { reason } => write!(f, "plan artifact error: {reason}"),
            PlanError::InvalidArtifact { diagnostics } => {
                write!(f, "invalid plan artifact: {} error(s)", diagnostics.len())?;
                for d in diagnostics {
                    write!(f, "; {d}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for PlanError {}

impl From<crate::cluster::ClusterError> for PlanError {
    fn from(e: crate::cluster::ClusterError) -> Self {
        PlanError::InvalidCluster { reason: e.to_string() }
    }
}

impl From<crate::model::SpecError> for PlanError {
    fn from(e: crate::model::SpecError) -> Self {
        PlanError::InvalidModel { reason: e.reason }
    }
}

impl From<crate::cost::ProfileDbError> for PlanError {
    fn from(e: crate::cost::ProfileDbError) -> Self {
        match e {
            crate::cost::ProfileDbError::Malformed { reason } => {
                PlanError::InvalidProfileDb { reason }
            }
            crate::cost::ProfileDbError::Coverage { reason } => {
                PlanError::ProfileDbCoverage { reason }
            }
        }
    }
}

/// Case-insensitive Levenshtein distance (iterative two-row DP).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.to_ascii_lowercase().chars().collect();
    let b: Vec<char> = b.to_ascii_lowercase().chars().collect();
    if a.is_empty() {
        return b.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Closest candidate to `name`, if any is close enough to be a plausible
/// typo (distance at most 3 and under half the query length, so wildly
/// wrong inputs produce no suggestion).
pub fn suggest<'a, I>(name: &str, candidates: I) -> Option<String>
where
    I: IntoIterator<Item = &'a str>,
{
    let mut best: Option<(usize, &str)> = None;
    for c in candidates {
        let d = edit_distance(name, c);
        if best.map_or(true, |(bd, _)| d < bd) {
            best = Some((d, c));
        }
    }
    let (d, c) = best?;
    let cutoff = 3.min(1 + name.chars().count() / 2);
    if d <= cutoff {
        Some(c.to_string())
    } else {
        None
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn distance_basics() {
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", "abd"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        // Case-insensitive.
        assert_eq!(edit_distance("Galvatron-BMW", "galvatron-bmw"), 0);
    }

    #[test]
    fn suggests_close_names() {
        let names = ["bert-huge-32", "bert-huge-48", "vit-huge-32"];
        assert_eq!(suggest("bert-hug-32", names), Some("bert-huge-32".into()));
        assert_eq!(suggest("VIT-huge-32", names), Some("vit-huge-32".into()));
        // Hopeless inputs get no suggestion.
        assert_eq!(suggest("resnet50", names), None);
    }

    #[test]
    fn error_messages_carry_suggestions() {
        let e = PlanError::UnknownModel {
            name: "bert-hug-32".into(),
            suggestion: Some("bert-huge-32".into()),
        };
        let msg = e.to_string();
        assert!(msg.contains("bert-hug-32") && msg.contains("did you mean"), "{msg}");
        let e = PlanError::UnknownCluster { name: "xyz".into(), suggestion: None };
        assert!(!e.to_string().contains("did you mean"));
    }

    #[test]
    fn unknown_model_hints_at_spec_files() {
        // Mirrors the `--islands` hint of InvalidCluster: the error points
        // at the file-based model form.
        let e = PlanError::UnknownModel { name: "my-model".into(), suggestion: None };
        let msg = e.to_string();
        assert!(msg.contains(".json") && msg.contains("ModelSpec"), "{msg}");
        // Cluster/method errors do not carry the model-file hint.
        let e = PlanError::UnknownCluster { name: "xyz".into(), suggestion: None };
        assert!(!e.to_string().contains("ModelSpec"));
    }
}
