//! `PlanRequest`: the one way to ask the planner for a hybrid-parallel
//! plan — model/cluster by name or inline spec, memory budget, method,
//! schedule and search knobs — plus the `Planner` facade that resolves and
//! executes it.

use std::path::{Path, PathBuf};

use crate::cluster::{
    cluster_by_name, cluster_names, looks_like_islands, parse_islands, ClusterSpec,
};
use crate::cost::pipeline::Schedule;
use crate::cost::{CostModel, ProfileDb};
use crate::model::{
    model_by_name, model_names, Dtype, ModelProfile, ModelSpec, OptimizerKind, TrainConfig,
};
use crate::sim::{simulate_costed, SimReport};
use crate::util::GIB;

use super::error::{suggest, PlanError};
use super::method::{MethodSpec, SearchOverrides};
use super::report::PlanReport;

/// A model: a zoo name, a declarative [`ModelSpec`] (inline or from a
/// JSON file), or a pre-compiled [`ModelProfile`].
#[derive(Debug, Clone)]
pub enum ModelSource {
    /// Zoo name (`galvatron models`); a name ending in `.json` is loaded
    /// as a [`ModelSpec`] file.
    Name(String),
    /// Declarative spec, compiled at resolve time.
    Spec(ModelSpec),
    /// Spec file path, loaded + compiled at resolve time.
    File(PathBuf),
    /// Pre-compiled layer profile (bypasses the spec layer).
    Profile(ModelProfile),
}

/// A cluster, referenced by preset name or provided inline.
#[derive(Debug, Clone)]
pub enum ClusterSource {
    Name(String),
    Spec(ClusterSpec),
}

/// Parse a pipeline-schedule name ("1f1b" / "gpipe").
pub fn parse_schedule(name: &str) -> Result<Schedule, PlanError> {
    match name.trim().to_ascii_lowercase().as_str() {
        "1f1b" | "1f1b-flush" | "pipedream-flush" => Ok(Schedule::OneFOneB),
        "gpipe" => Ok(Schedule::GPipe),
        other => Err(PlanError::InvalidRequest {
            reason: format!("unknown schedule {other:?}; expected \"1f1b\" or \"gpipe\""),
        }),
    }
}

/// Stable artifact name for a schedule (inverse of [`parse_schedule`]).
pub fn schedule_key(s: Schedule) -> &'static str {
    match s {
        Schedule::OneFOneB => "1f1b",
        Schedule::GPipe => "gpipe",
    }
}

/// Builder for one planning run. Construct with [`PlanRequest::new`], chain
/// setters, then call [`PlanRequest::plan`] (or hand it to a [`Planner`]).
///
/// ```no_run
/// use galvatron::api::{MethodSpec, PlanRequest};
/// let report = PlanRequest::new("bert-huge-32", "titan8")
///     .memory_gb(16.0)
///     .max_batch(512)
///     .method(MethodSpec::Bmw { ckpt: true })
///     .plan()?;
/// println!("{:.2} samples/s", report.throughput);
/// # Ok::<(), galvatron::api::PlanError>(())
/// ```
#[derive(Debug, Clone)]
pub struct PlanRequest {
    pub model: ModelSource,
    pub cluster: ClusterSource,
    /// Per-device memory budget in GB; `None` keeps the preset's physical
    /// memory (the paper restricts 24 GB cards to 8/12/16/20 GB budgets).
    /// Only valid on homogeneous clusters — a heterogeneous cluster's
    /// per-island budgets are fixed by its GPU classes and a uniform
    /// override is rejected with a diagnostic.
    pub memory_gb: Option<f64>,
    pub method: MethodSpec,
    /// Unresolved method name set by [`PlanRequest::method_name`];
    /// resolved (and surfaced as a typed error) at `plan()` time, taking
    /// precedence over `method`.
    pub method_name: Option<String>,
    /// Training numerics: dtype, optimizer, optional ZeRO sharding. The
    /// default (fp32 + Adam, unsharded) reproduces the pre-spec planner
    /// byte-for-byte.
    pub train: TrainConfig,
    pub max_batch: usize,
    pub schedule: Option<Schedule>,
    pub overlap_slowdown: Option<f64>,
    pub microbatch_limit: Option<usize>,
    pub pipeline_degrees: Option<Vec<usize>>,
    /// Worker threads for the search engine's (batch × PP) fan-out.
    /// `None` (or `Some(0)`) = auto: `GALVATRON_THREADS` if set, else the
    /// machine's available parallelism. The resulting plan (and its JSON
    /// artifact) is byte-identical for every value.
    pub threads: Option<usize>,
    /// Path of a [`ProfileDb`] JSON file to plan with the calibrated
    /// cost-model backend (the `--profile-db` CLI form); loaded and
    /// validated at `plan()`/`resolve()` time, surfacing
    /// [`PlanError::InvalidProfileDb`] / [`PlanError::ProfileDbCoverage`].
    pub profile_db: Option<PathBuf>,
    /// Explicit cost-model backend (the programmatic form of
    /// [`PlanRequest::profile_db`]). `None` = the default analytic model.
    pub cost_model: Option<CostModel>,
    /// Persistent planning cache directory (the `--cache-dir` CLI form).
    /// `None` falls back to the `GALVATRON_CACHE_DIR` environment variable
    /// at `resolve()` time; when neither is set, nothing is persisted.
    /// The cache never changes a plan — warm and cold artifacts are
    /// byte-identical — it only removes recomputation.
    pub cache_dir: Option<PathBuf>,
    /// Cold-path pruning (dominance pruning, DP reachability bounds,
    /// lower-bound evaluation skips). `None` = engine default: on unless
    /// the `GALVATRON_NO_PRUNE` environment variable disables it. Pruning
    /// never changes an artifact byte — only planning wall time.
    pub prune: Option<bool>,
}

impl PlanRequest {
    /// Start a request for `model` on `cluster` (both by name) with the
    /// full Galvatron-BMW method and the paper's default knobs.
    pub fn new(model: &str, cluster: &str) -> PlanRequest {
        PlanRequest {
            model: ModelSource::Name(model.to_string()),
            cluster: ClusterSource::Name(cluster.to_string()),
            memory_gb: None,
            method: MethodSpec::Bmw { ckpt: true },
            method_name: None,
            train: TrainConfig::default(),
            max_batch: 512,
            schedule: None,
            overlap_slowdown: None,
            microbatch_limit: None,
            pipeline_degrees: None,
            threads: None,
            profile_db: None,
            cost_model: None,
            cache_dir: None,
            prune: None,
        }
    }

    /// Plan for an inline declarative [`ModelSpec`] instead of a zoo name
    /// (compiled — and validated, with errors at `plan()` time — through
    /// the same path as `--model-file` specs).
    pub fn model_spec(mut self, spec: ModelSpec) -> Self {
        self.model = ModelSource::Spec(spec);
        self
    }

    /// Plan for a [`ModelSpec`] JSON file (the `--model-file` form).
    pub fn model_file(mut self, path: impl Into<PathBuf>) -> Self {
        self.model = ModelSource::File(path.into());
        self
    }

    /// Plan for a pre-compiled model profile (bypasses the spec layer).
    pub fn model_profile(mut self, model: ModelProfile) -> Self {
        self.model = ModelSource::Profile(model);
        self
    }

    /// Set the training numerics (dtype / optimizer / ZeRO).
    pub fn train_config(mut self, train: TrainConfig) -> Self {
        self.train = train;
        self
    }

    /// Set the parameter/activation dtype (fp32 master weights are
    /// accounted automatically under mixed precision).
    pub fn dtype(mut self, dtype: Dtype) -> Self {
        self.train.dtype = dtype;
        self
    }

    /// Set the optimizer whose state the memory model accounts for.
    pub fn optimizer(mut self, optimizer: OptimizerKind) -> Self {
        self.train.optimizer = optimizer;
        self
    }

    /// Toggle ZeRO-style sharding of the optimizer state over the DP degree.
    pub fn zero(mut self, zero: bool) -> Self {
        self.train.zero = zero;
        self
    }

    /// Plan for an inline cluster spec instead of a preset name.
    pub fn cluster_spec(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = ClusterSource::Spec(cluster);
        self
    }

    /// Restrict the per-device memory budget (GB).
    pub fn memory_gb(mut self, gb: f64) -> Self {
        self.memory_gb = Some(gb);
        self
    }

    /// Choose the planning method (default: full Galvatron-BMW). Clears
    /// any pending [`PlanRequest::method_name`] — the last setter wins.
    pub fn method(mut self, method: MethodSpec) -> Self {
        self.method = method;
        self.method_name = None;
        self
    }

    /// Choose the planning method by catalog name. Resolution is deferred
    /// to `plan()` time, so the builder chain stays fluent and an unknown
    /// name surfaces as a typed [`PlanError::UnknownMethod`] like every
    /// other resolution error. Use [`PlanRequest::try_method_name`] to
    /// resolve eagerly.
    pub fn method_name(mut self, name: &str) -> Self {
        self.method_name = Some(name.to_string());
        self
    }

    /// Eagerly-resolving variant of [`PlanRequest::method_name`] for
    /// callers that want the catalog error immediately.
    pub fn try_method_name(mut self, name: &str) -> Result<Self, PlanError> {
        self.method = MethodSpec::parse(name)?;
        self.method_name = None;
        Ok(self)
    }

    /// Largest global batch size the sweep explores.
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Override the pipeline schedule (default: the method's own).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Override the compute/communication contention factor (§V).
    pub fn overlap_slowdown(mut self, factor: f64) -> Self {
        self.overlap_slowdown = Some(factor);
        self
    }

    /// Cap the microbatch count (gradient-accumulation depth).
    pub fn microbatch_limit(mut self, limit: usize) -> Self {
        self.microbatch_limit = Some(limit);
        self
    }

    /// Restrict the pipeline degrees explored (e.g. `&[4]` to pin PP=4).
    pub fn pipeline_degrees(mut self, degrees: &[usize]) -> Self {
        self.pipeline_degrees = Some(degrees.to_vec());
        self
    }

    /// Pin the search engine's worker-thread count (0 = auto). Affects
    /// wall-clock only — never the plan found.
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Plan with the calibrated cost-model backend loaded from a
    /// [`ProfileDb`] JSON file (written by `galvatron calibrate`).
    /// Resolution — and the malformed / insufficient-coverage diagnostics
    /// — happen at `plan()` time. Clears any pending
    /// [`PlanRequest::cost_model`] — the last setter wins.
    pub fn profile_db(mut self, path: impl Into<PathBuf>) -> Self {
        self.profile_db = Some(path.into());
        self.cost_model = None;
        self
    }

    /// Plan with an explicit cost-model backend (e.g. a [`ProfileDb`]
    /// already in memory via [`CostModel::calibrated`]). Clears any
    /// pending [`PlanRequest::profile_db`] — the last setter wins.
    pub fn cost_model(mut self, cost_model: CostModel) -> Self {
        self.cost_model = Some(cost_model);
        self.profile_db = None;
        self
    }

    /// Persist and reuse planning state under `dir` (the `--cache-dir`
    /// form): memoized cost tables warm-start compatible later runs, and
    /// an identical request returns its artifact without searching.
    pub fn cache_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Force cold-path pruning on or off (default: on, unless the
    /// `GALVATRON_NO_PRUNE` environment variable disables it). Pruning
    /// never changes an artifact byte — only planning wall time — so this
    /// exists for benchmarking and byte-identity checks.
    pub fn prune(mut self, prune: bool) -> Self {
        self.prune = Some(prune);
        self
    }

    /// Convenience: plan with a default [`Planner`].
    pub fn plan(&self) -> Result<PlanReport, PlanError> {
        Planner::new().plan(self)
    }
}

/// A request after name resolution and validation: concrete model, cluster
/// (budget applied), and method — ready to search.
#[derive(Debug, Clone)]
pub struct ResolvedRequest {
    /// Name the report will carry (re-resolvable where possible).
    pub model_name: String,
    pub cluster_name: String,
    pub model: ModelProfile,
    /// The declarative spec the model came from, when it was planned from
    /// one ([`ModelSource::Spec`]/[`ModelSource::File`]/`.json` name).
    pub model_spec: Option<ModelSpec>,
    pub cluster: ClusterSpec,
    pub method: MethodSpec,
    pub train: TrainConfig,
    /// The cost-model backend the search prices with (analytic unless the
    /// request carried a profile DB / explicit model). Its provenance is
    /// recorded into the resulting [`PlanReport`] when non-default.
    pub cost_model: CostModel,
    pub overrides: SearchOverrides,
    /// Persistent planning cache directory (request field or the
    /// `GALVATRON_CACHE_DIR` environment fallback; `None` = no cache).
    pub cache_dir: Option<PathBuf>,
}

/// Fingerprint identifying a resolved request up to plan equality: two
/// requests with equal fingerprints produce byte-identical artifacts, so
/// the persistent cache may answer one with the other's stored
/// [`PlanReport`]. Hashes the artifact schema version, resolved names,
/// model/cluster content, the declarative spec (it is embedded in the
/// artifact), the full method, training numerics, the cost-model
/// provenance, and every search override *except* `threads`, `cache_dir`
/// and `prune` — all three are proven not to change the artifact.
pub fn request_fingerprint(r: &ResolvedRequest) -> u64 {
    use crate::search::engine::persist;
    let mut fp = persist::Fingerprint::new();
    fp.u64(crate::api::report::PLAN_ARTIFACT_VERSION as u64);
    fp.str(&r.model_name).str(&r.cluster_name);
    persist::hash_model(&mut fp, &r.model);
    persist::hash_cluster(&mut fp, &r.cluster);
    match &r.model_spec {
        Some(spec) => fp.str(&spec.to_json().to_string()),
        None => fp.str("-"),
    };
    fp.str(&r.method.to_json().to_string());
    persist::hash_train(&mut fp, &r.train);
    fp.u64(r.cost_model.cache_fingerprint());
    let o = &r.overrides;
    fp.usize(o.max_batch);
    fp.str(o.schedule.map(schedule_key).unwrap_or("-"));
    fp.f64(o.overlap_slowdown.unwrap_or(-1.0));
    fp.usize(o.microbatch_limit.map_or(0, |m| m + 1));
    match &o.pp_degrees {
        Some(pps) => {
            fp.usize(pps.len() + 1);
            for &pp in pps {
                fp.usize(pp);
            }
        }
        None => {
            fp.usize(0);
        }
    }
    fp.finish()
}

/// Full model resolution for every [`ModelSource`] form: the display name
/// the report will carry, the compiled profile, and the declarative spec
/// when the model came from one (recorded into the artifact).
fn resolve_model_source(
    src: &ModelSource,
) -> Result<(String, ModelProfile, Option<ModelSpec>), PlanError> {
    match src {
        ModelSource::Name(n) => {
            if let Some(m) = model_by_name(n) {
                return Ok((n.clone(), m, None));
            }
            if n.ends_with(".json") {
                // The model-side counterpart of the `--islands` cluster
                // syntax: a .json name is a spec file.
                let spec = ModelSpec::load(Path::new(n))?;
                let m = spec.compile()?;
                return Ok((spec.name.clone(), m, Some(spec)));
            }
            Err(PlanError::UnknownModel {
                name: n.clone(),
                suggestion: suggest(n, model_names()),
            })
        }
        ModelSource::Spec(spec) => {
            let m = spec.compile()?;
            Ok((spec.name.clone(), m, Some(spec.clone())))
        }
        ModelSource::File(path) => {
            let spec = ModelSpec::load(path)?;
            let m = spec.compile()?;
            Ok((spec.name.clone(), m, Some(spec)))
        }
        ModelSource::Profile(m) => Ok((m.name.clone(), m.clone(), None)),
    }
}

/// Resolve a model name against the Table I zoo; a name ending in `.json`
/// is loaded (and compiled) as a [`ModelSpec`] file.
pub fn resolve_model_name(name: &str) -> Result<ModelProfile, PlanError> {
    resolve_model_source(&ModelSource::Name(name.to_string())).map(|(_, m, _)| m)
}

/// Resolve a cluster preset name (physical memory budget) or an
/// island-syntax description such as `"2xA100-80G,2xRTX-TITAN-24G"`
/// (the `--islands` CLI form; see [`crate::cluster::parse_islands`]).
pub fn resolve_cluster_name(name: &str) -> Result<ClusterSpec, PlanError> {
    if let Some(c) = cluster_by_name(name) {
        return Ok(c);
    }
    if looks_like_islands(name) {
        return parse_islands(name).map_err(PlanError::from);
    }
    Err(PlanError::UnknownCluster {
        name: name.to_string(),
        suggestion: suggest(name, cluster_names()),
    })
}

/// How a [`Planner::plan_resolved_sourced`] call obtained its report:
/// a request-level warm hit from the persistent plan store, or a fresh
/// search. Informational only — the artifact bytes are identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanSource {
    /// Answered from the cache-dir plan store without searching.
    Stored,
    /// Produced by running the method's search.
    Searched,
}

/// The planning facade: resolves a [`PlanRequest`], runs the method's
/// search, and packages the result as a serializable [`PlanReport`].
#[derive(Debug, Default)]
pub struct Planner;

impl Planner {
    pub fn new() -> Planner {
        Planner
    }

    /// Name resolution + validation without running the (expensive) search.
    pub fn resolve(&self, req: &PlanRequest) -> Result<ResolvedRequest, PlanError> {
        let (model_name, model, model_spec) = resolve_model_source(&req.model)?;
        let (cluster_name, mut cluster) = match &req.cluster {
            ClusterSource::Name(n) => (n.clone(), resolve_cluster_name(n)?),
            ClusterSource::Spec(c) => (c.name.clone(), c.clone()),
        };
        if let Some(gb) = req.memory_gb {
            if !(gb.is_finite() && gb > 0.0) {
                return Err(PlanError::InvalidRequest {
                    reason: format!("memory budget must be a positive number of GB, got {gb}"),
                });
            }
            if !cluster.is_homogeneous() {
                return Err(PlanError::InvalidRequest {
                    reason: format!(
                        "a uniform memory budget cannot be applied to heterogeneous cluster \
                         {cluster_name}: per-island budgets are fixed by its GPU classes ({})",
                        cluster.islands_label()
                    ),
                });
            }
            cluster = cluster.with_memory_budget(gb * GIB);
        }
        if req.max_batch == 0 {
            return Err(PlanError::InvalidRequest { reason: "max_batch must be >= 1".into() });
        }
        if let Some(o) = req.overlap_slowdown {
            if !(o.is_finite() && o >= 1.0) {
                return Err(PlanError::InvalidRequest {
                    reason: format!("overlap slowdown must be >= 1.0, got {o}"),
                });
            }
        }
        if let Some(m) = req.microbatch_limit {
            if m == 0 {
                return Err(PlanError::InvalidRequest {
                    reason: "microbatch limit must be >= 1".into(),
                });
            }
        }
        if let Some(pps) = &req.pipeline_degrees {
            for &p in pps {
                if p == 0 || cluster.n_devices() % p != 0 {
                    return Err(PlanError::InvalidRequest {
                        reason: format!(
                            "pipeline degree {p} does not divide the {} devices of {cluster_name}",
                            cluster.n_devices()
                        ),
                    });
                }
                // The default degree list filters these implicitly; pinned
                // degrees must honor the same search invariants (at least
                // one layer per stage, power-of-two stage device groups)
                // or the partition/enumeration layers panic.
                if p > model.n_layers() {
                    return Err(PlanError::InvalidRequest {
                        reason: format!(
                            "pipeline degree {p} exceeds the {} layers of {model_name}",
                            model.n_layers()
                        ),
                    });
                }
                if !crate::util::is_pow2(cluster.n_devices() / p) {
                    return Err(PlanError::InvalidRequest {
                        reason: format!(
                            "pipeline degree {p} leaves a non-power-of-two stage group of {} devices",
                            cluster.n_devices() / p
                        ),
                    });
                }
            }
        }
        // Deferred method-name resolution (the fluent `method_name` form).
        let method = match &req.method_name {
            Some(name) => MethodSpec::parse(name)?,
            None => req.method.clone(),
        };
        // Cost-model resolution: an explicit backend wins, else a profile
        // DB path is loaded + validated here (malformed / insufficient
        // coverage surface as typed errors), else analytic.
        let cost_model = match (&req.cost_model, &req.profile_db) {
            (Some(m), _) => m.clone(),
            (None, Some(path)) => CostModel::calibrated(ProfileDb::load(path)?),
            (None, None) => CostModel::Analytic,
        };
        let mut overrides = SearchOverrides::new(req.max_batch);
        overrides.schedule = req.schedule;
        overrides.overlap_slowdown = req.overlap_slowdown;
        overrides.microbatch_limit = req.microbatch_limit;
        overrides.pp_degrees = req.pipeline_degrees.clone();
        overrides.threads = req.threads;
        overrides.train = req.train;
        overrides.cost_model = Some(cost_model.clone());
        overrides.prune = req.prune;
        let cache_dir = req
            .cache_dir
            .clone()
            .or_else(|| std::env::var_os("GALVATRON_CACHE_DIR").map(PathBuf::from));
        overrides.cache_dir = cache_dir.clone();
        Ok(ResolvedRequest {
            model_name,
            cluster_name,
            model,
            model_spec,
            cluster,
            method,
            train: req.train,
            cost_model,
            overrides,
            cache_dir,
        })
    }

    /// Run the full planning pipeline:
    /// resolve → search (on the parallel memoized engine) → package as an
    /// artifact carrying the structured search trace.
    pub fn plan(&self, req: &PlanRequest) -> Result<PlanReport, PlanError> {
        let r = self.resolve(req)?;
        self.plan_resolved(&r)
    }

    /// The search + packaging half of [`Planner::plan`] for callers that
    /// already hold a [`ResolvedRequest`] (the CLI resolves once to print
    /// the run header — and to load a `--profile-db` exactly once — then
    /// plans from the same resolution).
    pub fn plan_resolved(&self, r: &ResolvedRequest) -> Result<PlanReport, PlanError> {
        self.plan_resolved_sourced(r).map(|(report, _)| report)
    }

    /// [`Planner::plan_resolved`], additionally reporting where the answer
    /// came from — a request-level warm hit or a fresh search. The serve
    /// daemon uses the source to label responses; the bytes are identical
    /// either way.
    pub fn plan_resolved_sourced(
        &self,
        r: &ResolvedRequest,
    ) -> Result<(PlanReport, PlanSource), PlanError> {
        use crate::search::engine::persist;
        // Request-level warm hit: an identical resolved request (see
        // [`request_fingerprint`]) returns its stored artifact without
        // searching. The entry is re-proved by the same Error-severity
        // gate a fresh plan passes through; anything that fails to parse
        // or validate is treated as corrupt and planned cold.
        let request_fp = r.cache_dir.as_deref().map(|dir| (dir, request_fingerprint(r)));
        if let Some((dir, fp)) = request_fp {
            if let Some(v) = persist::load_plan_entry(dir, fp) {
                match PlanReport::from_json(&v) {
                    Ok(report) if crate::check::gate(&r.model, &r.cluster, &report).is_ok() => {
                        return Ok((report, PlanSource::Stored));
                    }
                    _ => crate::util::diag::warn(&format!(
                        "ignoring invalid cached plan entry {} (planning cold)",
                        persist::plan_file_path(dir, fp).display()
                    )),
                }
            }
        }
        let (outcome, trace) = r.method.run_traced_with(&r.model, &r.cluster, &r.overrides);
        let outcome = outcome.ok_or_else(|| PlanError::Infeasible {
            reason: format!(
                "no plan for {} on {} fits the {:.1} GB budget ({}, max batch {})",
                r.model_name,
                r.cluster_name,
                r.cluster.gpu().mem_bytes / GIB,
                r.method.canonical_name(),
                r.overrides.max_batch
            ),
        })?;
        let report = PlanReport::from_outcome(r, &outcome, Some(trace));
        // Self-check: the search's own invariants, re-proved on the
        // artifact by the cheap Error-severity rules. A failure here is a
        // planner bug surfacing as a typed diagnostic, not a panic.
        crate::check::gate(&r.model, &r.cluster, &report)?;
        if let Some((dir, fp)) = request_fp {
            persist::store_plan_entry(dir, fp, &report.to_json());
        }
        Ok((report, PlanSource::Searched))
    }

    /// Re-run the discrete-event simulator for a saved report (the
    /// `plan → simulate` artifact pipeline). The model comes from the
    /// report's recorded [`ModelSpec`] when present (plans made from
    /// `--model-file` / inline specs), otherwise from the zoo by name; the
    /// cluster resolves by name from the built-in catalogs. The plan is
    /// re-validated before simulation.
    ///
    /// A report planned from an inline [`PlanRequest::model_profile`] /
    /// [`PlanRequest::cluster_spec`] carries only the spec's *name*,
    /// which the catalogs may not (faithfully) resolve — pass the
    /// original specs to [`Planner::simulate_plan`] instead.
    pub fn simulate_report(&self, report: &PlanReport) -> Result<SimReport, PlanError> {
        self.simulate_report_costed(report, &CostModel::Analytic)
    }

    /// [`Planner::simulate_report`] under an explicit cost-model backend
    /// (the `simulate --profile-db` form). Simulating a calibrated plan
    /// with a different backend than the one recorded in
    /// [`PlanReport::cost_model`] is allowed but the caller should warn —
    /// the CLI compares provenances and does.
    pub fn simulate_report_costed(
        &self,
        report: &PlanReport,
        cost_model: &CostModel,
    ) -> Result<SimReport, PlanError> {
        let model = match &report.model_spec {
            Some(spec) => spec.compile()?,
            None => resolve_model_name(&report.model)?,
        };
        let mut cluster = resolve_cluster_name(&report.cluster)?;
        if cluster.is_homogeneous() {
            // Heterogeneous clusters fix per-island budgets via their GPU
            // classes; `memory_budget_gb` records only the floor there.
            cluster = cluster.with_memory_budget(report.memory_budget_gb * GIB);
        }
        self.simulate_plan_costed(&model, &cluster, report, cost_model)
    }

    /// Simulate a report against explicitly provided model/cluster specs
    /// (the inline-spec counterpart of [`Planner::simulate_report`]).
    pub fn simulate_plan(
        &self,
        model: &ModelProfile,
        cluster: &ClusterSpec,
        report: &PlanReport,
    ) -> Result<SimReport, PlanError> {
        self.simulate_plan_costed(model, cluster, report, &CostModel::Analytic)
    }

    /// [`Planner::simulate_plan`] under an explicit cost-model backend.
    pub fn simulate_plan_costed(
        &self,
        model: &ModelProfile,
        cluster: &ClusterSpec,
        report: &PlanReport,
        cost_model: &CostModel,
    ) -> Result<SimReport, PlanError> {
        // The static checker's Error-severity gate subsumes the old bare
        // `plan.validate` call: shape legality plus device divisibility,
        // strategy degrees, microbatching and stage-slot placement.
        crate::check::gate(model, cluster, report)?;
        Ok(simulate_costed(
            model,
            cluster,
            &report.plan,
            report.schedule,
            report.overlap_slowdown,
            report.train,
            cost_model,
        ))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn unknown_names_get_suggestions() {
        let err = PlanRequest::new("bert-hug-32", "titan8").plan().unwrap_err();
        match err {
            PlanError::UnknownModel { suggestion, .. } => {
                assert_eq!(suggestion.as_deref(), Some("bert-huge-32"))
            }
            other => panic!("wrong error: {other:?}"),
        }
        let err = PlanRequest::new("bert-huge-32", "titan9").plan().unwrap_err();
        match err {
            PlanError::UnknownCluster { suggestion, .. } => {
                assert_eq!(suggestion.as_deref(), Some("titan8"))
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn invalid_knobs_rejected() {
        let p = Planner::new();
        let req = PlanRequest::new("bert-huge-32", "titan8").memory_gb(-4.0);
        assert!(matches!(p.resolve(&req), Err(PlanError::InvalidRequest { .. })));
        let req = PlanRequest::new("bert-huge-32", "titan8").max_batch(0);
        assert!(matches!(p.resolve(&req), Err(PlanError::InvalidRequest { .. })));
        let req = PlanRequest::new("bert-huge-32", "titan8").pipeline_degrees(&[3]);
        assert!(matches!(p.resolve(&req), Err(PlanError::InvalidRequest { .. })));
        // Divides the devices but exceeds the model's 32 layers.
        let req = PlanRequest::new("bert-huge-32", "a100x64").pipeline_degrees(&[64]);
        assert!(matches!(p.resolve(&req), Err(PlanError::InvalidRequest { .. })));
    }

    #[test]
    fn island_syntax_resolves_as_cluster_name() {
        let c = resolve_cluster_name("2xA100-80G,2xRTX-TITAN-24G").unwrap();
        assert_eq!(c.n_devices(), 4);
        assert!(!c.is_homogeneous());
        // Bad island syntax surfaces the typed cluster error, not a panic.
        let err = resolve_cluster_name("2xH100,2xRTX-TITAN-24G").unwrap_err();
        assert!(matches!(err, PlanError::InvalidCluster { .. }), "{err:?}");
        let err = resolve_cluster_name("3xA100-80G,1xRTX-TITAN-24G").unwrap_err();
        assert!(matches!(err, PlanError::InvalidCluster { .. }), "{err:?}");
        // Names that do not look like island syntax keep the suggestion path.
        let err = resolve_cluster_name("titen8").unwrap_err();
        assert!(matches!(err, PlanError::UnknownCluster { .. }), "{err:?}");
    }

    #[test]
    fn uniform_budget_rejected_on_heterogeneous_cluster() {
        let p = Planner::new();
        let req = PlanRequest::new("bert-huge-32", "hetero4").memory_gb(16.0);
        let err = p.resolve(&req).unwrap_err();
        assert!(matches!(err, PlanError::InvalidRequest { .. }), "{err:?}");
        // Without the override the mixed cluster resolves fine.
        let req = PlanRequest::new("bert-huge-32", "hetero4");
        let r = p.resolve(&req).unwrap();
        assert!(!r.cluster.is_homogeneous());
    }

    #[test]
    fn method_name_resolves_at_plan_time() {
        // The fluent form defers resolution: the chain never breaks, the
        // typo surfaces as a typed error from plan()/resolve().
        let req = PlanRequest::new("bert-huge-32", "titan8").method_name("bogus-method");
        let err = Planner::new().resolve(&req).unwrap_err();
        assert!(matches!(err, PlanError::UnknownMethod { .. }), "{err:?}");
        let ok = PlanRequest::new("bert-huge-32", "titan8").method_name("bmw");
        let r = Planner::new().resolve(&ok).unwrap();
        assert_eq!(r.method, MethodSpec::Bmw { ckpt: true });
        // The eager variant fails immediately.
        assert!(PlanRequest::new("bert-huge-32", "titan8")
            .try_method_name("bogus-method")
            .is_err());
        let eager = PlanRequest::new("bert-huge-32", "titan8").try_method_name("gpipe").unwrap();
        assert_eq!(Planner::new().resolve(&eager).unwrap().method, MethodSpec::PurePipeline);
        // Last setter wins: a typed .method(..) clears a pending name.
        let last = PlanRequest::new("bert-huge-32", "titan8")
            .method_name("gpipe")
            .method(MethodSpec::Bmw { ckpt: true });
        assert_eq!(Planner::new().resolve(&last).unwrap().method, MethodSpec::Bmw { ckpt: true });
    }

    #[test]
    fn spec_sources_resolve_and_record_the_spec() {
        use crate::model::spec_by_name;
        let spec = spec_by_name("bert-huge-32").unwrap();
        let req = PlanRequest::new("ignored", "titan8").model_spec(spec.clone());
        let r = Planner::new().resolve(&req).unwrap();
        assert_eq!(r.model_name, "BERT-Huge-32");
        assert_eq!(r.model_spec.as_ref(), Some(&spec));
        assert_eq!(r.model, crate::model::model_by_name("bert-huge-32").unwrap());

        // A model *name* ending in .json loads the same spec from disk.
        let path = std::env::temp_dir().join(format!("galvatron-req-{}.json", std::process::id()));
        spec.save(&path).unwrap();
        let req = PlanRequest::new(path.to_str().unwrap(), "titan8");
        let r = Planner::new().resolve(&req).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(r.model_spec.as_ref(), Some(&spec));

        // Missing files surface as typed model errors.
        let req = PlanRequest::new("no-such-file.json", "titan8");
        let err = Planner::new().resolve(&req).unwrap_err();
        assert!(matches!(err, PlanError::InvalidModel { .. }), "{err:?}");
    }

    #[test]
    fn train_config_travels_through_resolution() {
        use crate::model::{Dtype, OptimizerKind};
        let req = PlanRequest::new("bert-huge-32", "titan8")
            .dtype(Dtype::Bf16)
            .optimizer(OptimizerKind::Sgd)
            .zero(true);
        let r = Planner::new().resolve(&req).unwrap();
        assert_eq!(r.train.dtype, Dtype::Bf16);
        assert_eq!(r.train.optimizer, OptimizerKind::Sgd);
        assert!(r.train.zero);
        assert_eq!(r.overrides.train, r.train);
    }

    #[test]
    fn profile_db_resolution_and_typed_errors() {
        use crate::cost::ProfileDb;
        let p = Planner::new();
        // Missing file surfaces the typed malformed error.
        let req = PlanRequest::new("bert-huge-32", "titan8").profile_db("no-such-db.json");
        let err = p.resolve(&req).unwrap_err();
        assert!(matches!(err, PlanError::InvalidProfileDb { .. }), "{err:?}");
        // A valid synthetic DB resolves to the calibrated backend.
        let cluster = resolve_cluster_name("titan8").unwrap();
        let db = ProfileDb::synthetic(&cluster);
        let path = std::env::temp_dir().join(format!("galvatron-db-{}.json", std::process::id()));
        db.save(&path).unwrap();
        let req = PlanRequest::new("bert-huge-32", "titan8").profile_db(&path);
        let r = p.resolve(&req).unwrap();
        assert_eq!(r.cost_model.backend_name(), "calibrated");
        assert_eq!(
            r.cost_model.provenance().unwrap().db_hash,
            db.content_hash_hex()
        );
        // An insufficient-coverage DB gets its own error class.
        let mut thin = db.clone();
        thin.layers.clear();
        std::fs::write(&path, thin.to_pretty_string()).unwrap();
        let err = p.resolve(&PlanRequest::new("bert-huge-32", "titan8").profile_db(&path));
        std::fs::remove_file(&path).ok();
        assert!(
            matches!(err, Err(PlanError::ProfileDbCoverage { .. })),
            "{err:?}"
        );
        // Without either setter the backend stays analytic and silent.
        let r = p.resolve(&PlanRequest::new("bert-huge-32", "titan8")).unwrap();
        assert!(r.cost_model.is_analytic());
        assert_eq!(r.cost_model.provenance(), None);
        // Last setter wins between the two forms.
        let req = PlanRequest::new("bert-huge-32", "titan8")
            .profile_db("stale.json")
            .cost_model(crate::cost::CostModel::Analytic);
        assert!(req.profile_db.is_none());
    }

    #[test]
    fn schedule_names_round_trip() {
        for s in [Schedule::OneFOneB, Schedule::GPipe] {
            assert_eq!(parse_schedule(schedule_key(s)).unwrap(), s);
        }
        assert!(parse_schedule("fifo").is_err());
    }
}
