//! Typed strategy catalog: every method the paper evaluates (§VII-A) as a
//! [`MethodSpec`] value instead of a magic string. `search::baselines`
//! keeps its name-based entry points as thin compat shims over this enum.

use crate::cluster::ClusterSpec;
use crate::cost::pipeline::Schedule;
use crate::model::{ModelProfile, TrainConfig};
use crate::parallel::Dim;
use crate::search::base::{optimize_traced, SearchConfig, SearchOutcome};
use crate::search::bmw::optimize_bmw_traced;
use crate::search::decision_tree::SpaceOptions;
use crate::search::engine::{CellAlgo, PartitionKind, SearchEngine, SearchTrace};
use crate::search::levels;
use crate::util::json::Json;

use super::error::{suggest, PlanError};

/// Fixed pipeline-partition policy for the Table V ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Memory-balanced partition p_m (1F1B live-microbatch aware).
    Memory,
    /// Time-balanced partition p_t (FLOPs-balanced).
    Time,
}

/// A planning method: which optimizer runs and over which search space.
///
/// The catalog covers every row of Tables II-VI; [`MethodSpec::parse`]
/// resolves the paper's row names (and a few short aliases) and
/// [`MethodSpec::canonical_name`] maps back, so specs round-trip through
/// plan artifacts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MethodSpec {
    /// Pure single-dimension parallelism over all devices (PyTorch DDP,
    /// Megatron-TP, FSDP/ZeRO-3), single-shot (no gradient accumulation).
    Pure(Dim),
    /// Pure pipeline parallelism (PyTorch GPipe): serial stages, GPipe
    /// schedule, per-microbatch re-materialization stays in the space.
    PurePipeline,
    /// DeepSpeed 3D: expert 2-way DP x 2-way TP x PP over the rest.
    DeepSpeed3d,
    /// Limited-dimension automatic search (prior-work baselines such as
    /// Galvatron (DP+TP) / Galvatron (DP+PP)); `pp` enables the pipeline
    /// dimension on top of `dims`.
    Limited { dims: Vec<Dim>, pp: bool },
    /// Galvatron-Base (Algorithm 1); `ckpt` toggles the CKPT dimension
    /// ("Galvatron" in the tables is the no-CKPT variant).
    Base { ckpt: bool },
    /// Galvatron-BMW (Algorithm 2, bi-objective workload balancing);
    /// `ckpt: false` is the tables' "Galvatron (1F1B+Bi-obj)" row.
    Bmw { ckpt: bool },
    /// Alpa-like: best of (DP+TP+PP) and (SDP+TP+PP) restricted searches,
    /// no CKPT (Table VI).
    Alpa,
    /// Table V ablation: fixed balanced partition, no adjustment loop,
    /// CKPT disabled, 1F1B schedule.
    Partition(PartitionPolicy),
}

/// Request-level overrides applied on top of a method's own search
/// configuration (see [`super::PlanRequest`]). `None` keeps the method's
/// default for that knob.
#[derive(Debug, Clone)]
pub struct SearchOverrides {
    /// Largest global batch size to consider.
    pub max_batch: usize,
    /// Pipeline schedule for cost/memory accounting.
    pub schedule: Option<Schedule>,
    /// Compute/communication contention factor (§V).
    pub overlap_slowdown: Option<f64>,
    /// Cap on the microbatch count (gradient-accumulation depth); combined
    /// with a method's own cap by taking the stricter of the two.
    pub microbatch_limit: Option<usize>,
    /// Restrict the PP degrees explored.
    pub pp_degrees: Option<Vec<usize>>,
    /// Worker threads for the search engine's cell fan-out (`None` = auto;
    /// plans are identical for every value).
    pub threads: Option<usize>,
    /// Training numerics (dtype/optimizer/ZeRO) for the memory accounting.
    pub train: TrainConfig,
    /// Cost-model backend (`None` keeps the default analytic formulas;
    /// `Some(Calibrated)` prices the search from a loaded profile DB).
    pub cost_model: Option<crate::cost::CostModel>,
    /// Persistent planning cache directory (`None` = no persistence).
    pub cache_dir: Option<std::path::PathBuf>,
    /// Cold-path pruning (`None` = engine default: on unless the
    /// `GALVATRON_NO_PRUNE` environment variable disables it). Never
    /// changes a plan or trace byte — only wall time.
    pub prune: Option<bool>,
}

impl SearchOverrides {
    pub fn new(max_batch: usize) -> Self {
        SearchOverrides {
            max_batch,
            schedule: None,
            overlap_slowdown: None,
            microbatch_limit: None,
            pp_degrees: None,
            threads: None,
            train: TrainConfig::default(),
            cost_model: None,
            cache_dir: None,
            prune: None,
        }
    }

    /// Apply these overrides to a method's base configuration.
    fn apply(&self, mut cfg: SearchConfig) -> SearchConfig {
        cfg.max_batch = self.max_batch;
        if let Some(s) = self.schedule {
            cfg.schedule = s;
        }
        if let Some(o) = self.overlap_slowdown {
            cfg.overlap_slowdown = o;
        }
        if let Some(m) = self.microbatch_limit {
            cfg.microbatch_limit = Some(cfg.microbatch_limit.map_or(m, |cur| cur.min(m)));
        }
        if let Some(pp) = &self.pp_degrees {
            cfg.pp_degrees = Some(pp.clone());
        }
        if self.threads.is_some() {
            cfg.threads = self.threads;
        }
        cfg.train = self.train;
        if let Some(cm) = &self.cost_model {
            cfg.cost_model = cm.clone();
        }
        if let Some(dir) = &self.cache_dir {
            cfg.cache_dir = Some(dir.clone());
        }
        if self.prune.is_some() {
            cfg.prune = self.prune;
        }
        cfg
    }
}

impl MethodSpec {
    /// The strategy rows of Table II, in row order (the historical
    /// `search::baselines::method_names()` list).
    pub fn paper_table_specs() -> Vec<MethodSpec> {
        vec![
            MethodSpec::Pure(Dim::Dp),
            MethodSpec::Pure(Dim::Tp),
            MethodSpec::PurePipeline,
            MethodSpec::Pure(Dim::Sdp),
            MethodSpec::DeepSpeed3d,
            MethodSpec::Limited { dims: vec![Dim::Dp, Dim::Tp], pp: false },
            MethodSpec::Limited { dims: vec![Dim::Dp], pp: true },
            MethodSpec::Base { ckpt: false },
            MethodSpec::Base { ckpt: true },
            MethodSpec::Bmw { ckpt: false },
            MethodSpec::Bmw { ckpt: true },
        ]
    }

    /// The full catalog: Table II rows plus Alpa (Table VI) and the
    /// partition ablations (Table V).
    pub fn catalog() -> Vec<MethodSpec> {
        let mut out = Self::paper_table_specs();
        out.push(MethodSpec::Alpa);
        out.push(MethodSpec::Partition(PartitionPolicy::Memory));
        out.push(MethodSpec::Partition(PartitionPolicy::Time));
        out
    }

    /// Catalog names in display order (for `galvatron methods`).
    pub fn catalog_names() -> Vec<String> {
        Self::catalog().iter().map(|s| s.canonical_name().to_string()).collect()
    }

    /// The paper's row name for this method — the historical string
    /// accepted by `run_method` and stored in plan artifacts.
    pub fn canonical_name(&self) -> &'static str {
        match self {
            MethodSpec::Pure(Dim::Dp) => "PyTorch DDP (DP)",
            MethodSpec::Pure(Dim::Tp) => "Megatron (TP)",
            MethodSpec::Pure(Dim::Sdp) => "FSDP/ZeRO-3 (SDP)",
            MethodSpec::PurePipeline => "PyTorch GPipe (PP)",
            MethodSpec::DeepSpeed3d => "DeepSpeed 3D",
            MethodSpec::Limited { dims, pp } => {
                if *pp && dims == &[Dim::Dp] {
                    "Galvatron (DP+PP)"
                } else if !*pp && dims == &[Dim::Dp, Dim::Tp] {
                    "Galvatron (DP+TP)"
                } else {
                    // Non-catalog restriction: no paper row name exists.
                    "Galvatron (limited)"
                }
            }
            MethodSpec::Base { ckpt: false } => "Galvatron",
            MethodSpec::Base { ckpt: true } => "Galvatron-Base",
            MethodSpec::Bmw { ckpt: false } => "Galvatron (1F1B+Bi-obj)",
            MethodSpec::Bmw { ckpt: true } => "Galvatron-BMW",
            MethodSpec::Alpa => "Alpa",
            MethodSpec::Partition(PartitionPolicy::Memory) => "Galvatron (1F1B+Mem)",
            MethodSpec::Partition(PartitionPolicy::Time) => "Galvatron (1F1B+Time)",
        }
    }

    /// The pipeline schedule this method plans under when the request
    /// does not override it.
    pub fn default_schedule(&self) -> Schedule {
        match self {
            MethodSpec::PurePipeline => Schedule::GPipe,
            _ => Schedule::OneFOneB,
        }
    }

    /// Short aliases accepted by [`MethodSpec::parse`] besides the
    /// canonical names (CLI convenience).
    fn aliases() -> Vec<(&'static str, MethodSpec)> {
        vec![
            ("ddp", MethodSpec::Pure(Dim::Dp)),
            ("dp", MethodSpec::Pure(Dim::Dp)),
            ("tp", MethodSpec::Pure(Dim::Tp)),
            ("megatron", MethodSpec::Pure(Dim::Tp)),
            ("sdp", MethodSpec::Pure(Dim::Sdp)),
            ("fsdp", MethodSpec::Pure(Dim::Sdp)),
            ("zero-3", MethodSpec::Pure(Dim::Sdp)),
            ("pp", MethodSpec::PurePipeline),
            ("gpipe", MethodSpec::PurePipeline),
            ("deepspeed-3d", MethodSpec::DeepSpeed3d),
            ("3d", MethodSpec::DeepSpeed3d),
            ("dp+tp", MethodSpec::Limited { dims: vec![Dim::Dp, Dim::Tp], pp: false }),
            ("dp+pp", MethodSpec::Limited { dims: vec![Dim::Dp], pp: true }),
            ("galvatron-no-ckpt", MethodSpec::Base { ckpt: false }),
            ("base", MethodSpec::Base { ckpt: true }),
            ("bi-obj", MethodSpec::Bmw { ckpt: false }),
            ("bmw", MethodSpec::Bmw { ckpt: true }),
            ("alpa", MethodSpec::Alpa),
            ("1f1b+mem", MethodSpec::Partition(PartitionPolicy::Memory)),
            ("1f1b+time", MethodSpec::Partition(PartitionPolicy::Time)),
        ]
    }

    /// Resolve a method name (case-insensitive; canonical row names and
    /// short aliases) to a spec, with a did-you-mean suggestion on miss.
    pub fn parse(name: &str) -> Result<MethodSpec, PlanError> {
        let want = name.trim().to_ascii_lowercase();
        for spec in Self::catalog() {
            if spec.canonical_name().to_ascii_lowercase() == want {
                return Ok(spec);
            }
        }
        for (alias, spec) in Self::aliases() {
            if alias == want {
                return Ok(spec);
            }
        }
        let names: Vec<String> = Self::catalog_names();
        Err(PlanError::UnknownMethod {
            name: name.to_string(),
            suggestion: suggest(name, names.iter().map(|s| s.as_str())),
        })
    }

    /// Serialize for plan artifacts. Catalog methods round-trip through
    /// their canonical name; non-catalog `Limited` restrictions (which
    /// all share the "Galvatron (limited)" display name) keep their
    /// structure so `save → load` is lossless for every spec.
    pub fn to_json(&self) -> Json {
        if let MethodSpec::Limited { dims, pp } = self {
            if Self::parse(self.canonical_name()).as_ref() != Ok(self) {
                return Json::obj(vec![(
                    "limited",
                    Json::obj(vec![
                        ("dims", Json::arr(dims.iter().map(|d| Json::str(&d.to_string())))),
                        ("pp", Json::Bool(*pp)),
                    ]),
                )]);
            }
        }
        Json::str(self.canonical_name())
    }

    /// Inverse of [`MethodSpec::to_json`].
    pub fn from_json(v: &Json) -> Result<MethodSpec, PlanError> {
        if let Some(name) = v.as_str() {
            return Self::parse(name);
        }
        if let Some(lim) = v.get("limited") {
            let bad = |what: &str| PlanError::Artifact {
                reason: format!("method.limited: missing or invalid {what}"),
            };
            let mut dims = Vec::new();
            for d in lim.get("dims").and_then(Json::as_arr).ok_or_else(|| bad("dims"))? {
                dims.push(match d.as_str().ok_or_else(|| bad("dims"))? {
                    "DP" => Dim::Dp,
                    "SDP" => Dim::Sdp,
                    "TP" => Dim::Tp,
                    other => {
                        return Err(PlanError::Artifact {
                            reason: format!("method.limited: unknown dimension {other:?}"),
                        })
                    }
                });
            }
            let pp = lim.get("pp").and_then(Json::as_bool).ok_or_else(|| bad("pp"))?;
            return Ok(MethodSpec::Limited { dims, pp });
        }
        Err(PlanError::Artifact {
            reason: "method must be a catalog name or a {\"limited\": ...} object".into(),
        })
    }

    /// Run this method with default overrides — the engine behind the
    /// `search::baselines::run_method` shim. `None` means OOM everywhere.
    pub fn run(
        &self,
        model: &ModelProfile,
        cluster: &ClusterSpec,
        max_batch: usize,
    ) -> Option<SearchOutcome> {
        self.run_with(model, cluster, &SearchOverrides::new(max_batch))
    }

    /// Run this method with explicit request-level overrides.
    pub fn run_with(
        &self,
        model: &ModelProfile,
        cluster: &ClusterSpec,
        ov: &SearchOverrides,
    ) -> Option<SearchOutcome> {
        self.run_traced_with(model, cluster, ov).0
    }

    /// Run this method and also return the engine's [`SearchTrace`] (for
    /// composite methods like Alpa, the traces of all runs merged).
    pub fn run_traced_with(
        &self,
        model: &ModelProfile,
        cluster: &ClusterSpec,
        ov: &SearchOverrides,
    ) -> (Option<SearchOutcome>, SearchTrace) {
        let n = cluster.n_devices();
        let base = SearchConfig { max_batch: ov.max_batch, ..Default::default() };
        match self {
            MethodSpec::Pure(dim) => optimize_traced(
                model,
                cluster,
                &ov.apply(SearchConfig {
                    fixed_strategy: Some(levels(&[(*dim, n)])),
                    pp_degrees: Some(vec![1]),
                    space: SpaceOptions::default().no_ckpt(),
                    microbatch_limit: Some(1),
                    ..base
                }),
            ),
            // GPipe re-materializes activations per microbatch (its
            // documented default), so the CKPT variant stays in the space.
            MethodSpec::PurePipeline => optimize_traced(
                model,
                cluster,
                &ov.apply(SearchConfig {
                    fixed_strategy: Some(crate::parallel::Strategy::serial(false)),
                    pp_degrees: Some(vec![n.min(model.n_layers())]),
                    schedule: Schedule::GPipe,
                    ..base
                }),
            ),
            // Official suggestion: 2-way DP x 2-way TP x PP over the rest
            // (https://github.com/microsoft/Megatron-DeepSpeed pretrain_bert).
            MethodSpec::DeepSpeed3d => {
                let pp = (n / 4).max(1).min(model.n_layers());
                optimize_traced(
                    model,
                    cluster,
                    &ov.apply(SearchConfig {
                        fixed_strategy: Some(levels(&[(Dim::Dp, 2), (Dim::Tp, 2)])),
                        pp_degrees: Some(vec![pp]),
                        space: SpaceOptions::default().no_ckpt(),
                        ..base
                    }),
                )
            }
            MethodSpec::Limited { dims, pp } => {
                // OptCNN/FlexFlow-era restricted automatic parallelism: no
                // CKPT; without the pipeline dimension there is also no
                // gradient accumulation.
                let mut cfg = SearchConfig {
                    space: SpaceOptions::default().with_dims(dims).no_ckpt(),
                    ..base
                };
                if !*pp {
                    cfg.pp_degrees = Some(vec![1]);
                    cfg.microbatch_limit = Some(1);
                }
                optimize_traced(model, cluster, &ov.apply(cfg))
            }
            MethodSpec::Base { ckpt: false } => optimize_traced(
                model,
                cluster,
                &ov.apply(SearchConfig { space: SpaceOptions::default().no_ckpt(), ..base }),
            ),
            MethodSpec::Base { ckpt: true } => optimize_traced(model, cluster, &ov.apply(base)),
            MethodSpec::Bmw { ckpt: false } => optimize_bmw_traced(
                model,
                cluster,
                &ov.apply(SearchConfig { space: SpaceOptions::default().no_ckpt(), ..base }),
            ),
            MethodSpec::Bmw { ckpt: true } => {
                optimize_bmw_traced(model, cluster, &ov.apply(base))
            }
            // Alpa treats SDP as a global alternative to DP (paper §VII-D):
            // best of two restricted searches, no CKPT.
            MethodSpec::Alpa => {
                let (a, ta) = optimize_traced(
                    model,
                    cluster,
                    &ov.apply(SearchConfig {
                        space: SpaceOptions::default().with_dims(&[Dim::Dp, Dim::Tp]).no_ckpt(),
                        ..base.clone()
                    }),
                );
                let (b, tb) = optimize_traced(
                    model,
                    cluster,
                    &ov.apply(SearchConfig {
                        space: SpaceOptions::default().with_dims(&[Dim::Sdp, Dim::Tp]).no_ckpt(),
                        ..base
                    }),
                );
                let a_wins = match (&a, &b) {
                    (Some(x), Some(y)) => x.throughput() >= y.throughput(),
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                let best_cell = if a_wins { ta.best_cell } else { tb.best_cell };
                let mut trace = ta;
                trace.merge(tb);
                trace.best_cell = best_cell;
                (if a_wins { a } else { b.or(a) }, trace)
            }
            // Table V ablations: fixed memory-balanced or time-balanced
            // partitions (no adjustment loop), CKPT disabled, 1F1B.
            MethodSpec::Partition(policy) => {
                let kind = match policy {
                    PartitionPolicy::Memory => PartitionKind::MemoryBalanced,
                    PartitionPolicy::Time => PartitionKind::TimeBalanced,
                };
                let cfg = ov.apply(SearchConfig {
                    space: SpaceOptions::default().no_ckpt(),
                    ..base
                });
                SearchEngine::new(model, cluster, &cfg, CellAlgo::Fixed(kind)).run()
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_parse_back() {
        for spec in MethodSpec::catalog() {
            let parsed = MethodSpec::parse(spec.canonical_name()).unwrap();
            assert_eq!(parsed, spec, "{}", spec.canonical_name());
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(MethodSpec::parse("bmw").unwrap(), MethodSpec::Bmw { ckpt: true });
        assert_eq!(MethodSpec::parse("GALVATRON-BMW").unwrap(), MethodSpec::Bmw { ckpt: true });
        assert_eq!(MethodSpec::parse("fsdp").unwrap(), MethodSpec::Pure(Dim::Sdp));
        assert_eq!(
            MethodSpec::parse("dp+pp").unwrap(),
            MethodSpec::Limited { dims: vec![Dim::Dp], pp: true }
        );
    }

    #[test]
    fn unknown_method_suggests() {
        let err = MethodSpec::parse("Galvatron-BWM").unwrap_err();
        match err {
            PlanError::UnknownMethod { suggestion, .. } => {
                assert_eq!(suggestion.as_deref(), Some("Galvatron-BMW"));
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn method_json_round_trips_including_non_catalog_limited() {
        let mut specs = MethodSpec::catalog();
        // Non-catalog restriction: not nameable, must survive structurally.
        specs.push(MethodSpec::Limited { dims: vec![Dim::Sdp], pp: true });
        specs.push(MethodSpec::Limited { dims: vec![Dim::Sdp, Dim::Tp], pp: false });
        for spec in specs {
            let v = crate::util::json::Json::parse(&spec.to_json().to_string()).unwrap();
            assert_eq!(MethodSpec::from_json(&v).unwrap(), spec, "{spec:?}");
        }
    }

    #[test]
    fn overrides_tighten_microbatch_cap() {
        let base = SearchConfig { microbatch_limit: Some(1), ..Default::default() };
        let mut ov = SearchOverrides::new(64);
        ov.microbatch_limit = Some(4);
        assert_eq!(ov.apply(base.clone()).microbatch_limit, Some(1));
        let loose = SearchConfig { microbatch_limit: None, ..Default::default() };
        assert_eq!(ov.apply(loose).microbatch_limit, Some(4));
        assert_eq!(ov.apply(base).max_batch, 64);
    }
}
