//! Collective-communication cost formulas (ring algorithms, NCCL-style).
//!
//! Volumes are the classic ring costs per participating device:
//!   all-reduce      2(n-1)/n · bytes
//!   all-gather      (n-1)/n · bytes
//!   reduce-scatter  (n-1)/n · bytes
//! so SDP (2× all-gather + 1× reduce-scatter over model states) moves 1.5×
//! the bytes of DP's single all-reduce — paper Takeaway #3's premise.

use crate::model::{LayerProfile, TrainConfig};
use crate::parallel::Strategy;

/// Ring all-reduce bytes on the wire per device.
pub fn allreduce_bytes(n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        0.0
    } else {
        2.0 * (n as f64 - 1.0) / n as f64 * bytes
    }
}

/// Ring all-gather (or reduce-scatter) bytes per device.
pub fn allgather_bytes(n: usize, bytes: f64) -> f64 {
    if n <= 1 {
        0.0
    } else {
        (n as f64 - 1.0) / n as f64 * bytes
    }
}

/// Per-layer communication volumes for one strategy; all quantities are
/// bytes per device. `b_m` is the (global) microbatch size.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerCommVolumes {
    /// TP activation all-reduces during forward (per microbatch).
    pub tp_fwd: f64,
    /// TP activation all-reduces during backward (per microbatch).
    pub tp_bwd: f64,
    /// SDP parameter all-gather during forward (per microbatch).
    pub sdp_fwd: f64,
    /// SDP parameter all-gather + gradient reduce-scatter during backward
    /// (per microbatch).
    pub sdp_bwd: f64,
    /// DP gradient all-reduce (once per global batch, overlapping the last
    /// microbatch's backward).
    pub dp_grad: f64,
}

/// Compute communication volumes for `layer` under `strategy` with the
/// default training numerics (fp32: the historical 4 B/param wire cost).
///
/// `extra_params` — embedding/head params attributed to this layer.
pub fn layer_comm_volumes(
    layer: &LayerProfile,
    strategy: &Strategy,
    b_m: f64,
    extra_params: f64,
) -> LayerCommVolumes {
    layer_comm_volumes_with(layer, strategy, b_m, extra_params, &TrainConfig::default())
}

/// [`layer_comm_volumes`] under explicit training numerics: parameter and
/// gradient collectives (SDP gathers/scatters, the DP gradient
/// all-reduce) ride the wire in the training dtype, so fp16/bf16 halves
/// their volume. Activation collectives (TP) keep the fp32 calibration of
/// the layer profiles, matching the rest of the time model. The default
/// `train` reproduces [`layer_comm_volumes`] bit-for-bit.
pub fn layer_comm_volumes_with(
    layer: &LayerProfile,
    strategy: &Strategy,
    b_m: f64,
    extra_params: f64,
    train: &TrainConfig,
) -> LayerCommVolumes {
    let mut v = LayerCommVolumes::default();
    let params = layer.params + extra_params;
    let param_bytes = params * train.dtype.bytes(); // weights/grads on the wire

    // Activation tensor entering/leaving the layer on this device.
    let local_samples = b_m / strategy.batch_split() as f64;
    let act_bytes = layer.bnd_bytes * local_samples;

    let tp = strategy.tp();
    if tp > 1 {
        // Megatron TP: 2 all-reduces fwd (attention out + MLP out), mirrored
        // in backward.
        v.tp_fwd = 2.0 * allreduce_bytes(tp, act_bytes);
        v.tp_bwd = 2.0 * allreduce_bytes(tp, act_bytes);
    }

    let sdp = strategy.sdp();
    if sdp > 1 {
        // Params as seen by this SDP group: already sharded by TP.
        let group_param_bytes = param_bytes / strategy.tp() as f64;
        v.sdp_fwd = allgather_bytes(sdp, group_param_bytes);
        v.sdp_bwd = allgather_bytes(sdp, group_param_bytes) // re-gather for bwd
            + allgather_bytes(sdp, group_param_bytes); // reduce-scatter grads
    }

    let dp = strategy.dp();
    if dp > 1 {
        let group_param_bytes = param_bytes / strategy.state_shard() as f64;
        v.dp_grad = allreduce_bytes(dp, group_param_bytes);
    }
    v
}

/// CKPT recompute repeats the forward TP all-reduces (paper §III-A3).
pub fn ckpt_recompute_comm(v: &LayerCommVolumes) -> f64 {
    v.tp_fwd
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::LayerProfile;
    use crate::parallel::Dim;

    fn layer() -> LayerProfile {
        LayerProfile::encoder("enc", 1024, 512, 16)
    }

    #[test]
    fn ring_formulas() {
        assert_eq!(allreduce_bytes(1, 100.0), 0.0);
        assert_eq!(allreduce_bytes(2, 100.0), 100.0);
        assert_eq!(allreduce_bytes(4, 100.0), 150.0);
        assert_eq!(allgather_bytes(4, 100.0), 75.0);
    }

    #[test]
    fn sdp_is_1_5x_dp() {
        // Paper Takeaway #3 premise at equal degree.
        let l = layer();
        let dp = layer_comm_volumes(&l, &Strategy::single(Dim::Dp, 4, false), 8.0, 0.0);
        let sdp = layer_comm_volumes(&l, &Strategy::single(Dim::Sdp, 4, false), 8.0, 0.0);
        let dp_total = dp.dp_grad;
        let sdp_total = sdp.sdp_fwd + sdp.sdp_bwd;
        assert!((sdp_total / dp_total - 1.5).abs() < 1e-9);
    }

    #[test]
    fn dp_sdp_mix_worse_than_pure_sdp() {
        // Takeaway #3: 2-way DP x 2-way SDP moves more bytes than 4-way SDP.
        // (The mixed strategy is excluded from the search space; verify the
        // premise with raw ring formulas.)
        // Paper's expression: 2(N1-1)/N1 (DP) + 3(N2-1)/N2 (SDP) vs
        // 3(N-1)/N (pure SDP), over full model-state bytes.
        let bytes = 1000.0;
        let mixed = allreduce_bytes(2, bytes) + 3.0 * allgather_bytes(2, bytes);
        let pure = 3.0 * allgather_bytes(4, bytes);
        assert!(mixed > pure, "mixed {mixed} vs pure {pure}");
    }

    #[test]
    fn tp_comm_scales_with_batch() {
        let l = layer();
        let s = Strategy::single(Dim::Tp, 4, false);
        let v1 = layer_comm_volumes(&l, &s, 4.0, 0.0);
        let v2 = layer_comm_volumes(&l, &s, 8.0, 0.0);
        assert!((v2.tp_fwd / v1.tp_fwd - 2.0).abs() < 1e-9);
        assert_eq!(v1.dp_grad, 0.0);
    }

    #[test]
    fn dp_comm_independent_of_batch() {
        let l = layer();
        let s = Strategy::single(Dim::Dp, 4, false);
        let v1 = layer_comm_volumes(&l, &s, 4.0, 0.0);
        let v2 = layer_comm_volumes(&l, &s, 64.0, 0.0);
        assert_eq!(v1.dp_grad, v2.dp_grad);
    }

    #[test]
    fn tp_then_sdp_gathers_tp_shard_only() {
        let l = layer();
        let s = Strategy { levels: vec![(Dim::Sdp, 2), (Dim::Tp, 2)], ckpt: false };
        let v = layer_comm_volumes(&l, &s, 8.0, 0.0);
        let expect = allgather_bytes(2, l.params * 4.0 / 2.0);
        assert!((v.sdp_fwd - expect).abs() < 1.0);
    }

    #[test]
    fn dtype_scales_param_collectives_only() {
        use crate::model::{Dtype, TrainConfig};
        let l = layer();
        let bf16 = TrainConfig { dtype: Dtype::Bf16, ..Default::default() };
        // DP grad all-reduce and SDP gathers halve; TP (activation)
        // volumes keep the fp32 calibration.
        let s = Strategy::single(Dim::Dp, 4, false);
        let v32 = layer_comm_volumes(&l, &s, 8.0, 0.0);
        let v16 = layer_comm_volumes_with(&l, &s, 8.0, 0.0, &bf16);
        assert_eq!(v16.dp_grad, v32.dp_grad / 2.0);
        let s = Strategy::single(Dim::Sdp, 4, false);
        let v32 = layer_comm_volumes(&l, &s, 8.0, 0.0);
        let v16 = layer_comm_volumes_with(&l, &s, 8.0, 0.0, &bf16);
        assert_eq!(v16.sdp_fwd, v32.sdp_fwd / 2.0);
        assert_eq!(v16.sdp_bwd, v32.sdp_bwd / 2.0);
        let s = Strategy::single(Dim::Tp, 4, false);
        let v32 = layer_comm_volumes(&l, &s, 8.0, 0.0);
        let v16 = layer_comm_volumes_with(&l, &s, 8.0, 0.0, &bf16);
        assert_eq!(v16, v32);
        // The default config is the fp32 path bit-for-bit.
        let s = Strategy::single(Dim::Sdp, 4, false);
        assert_eq!(
            layer_comm_volumes_with(&l, &s, 8.0, 0.0, &TrainConfig::default()),
            layer_comm_volumes(&l, &s, 8.0, 0.0)
        );
    }

    #[test]
    fn ckpt_repeats_fwd_tp_comm() {
        let l = layer();
        let s = Strategy::single(Dim::Tp, 2, true);
        let v = layer_comm_volumes(&l, &s, 8.0, 0.0);
        assert_eq!(ckpt_recompute_comm(&v), v.tp_fwd);
    }
}
