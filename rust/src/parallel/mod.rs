//! Parallelism strategies: the atoms of the Galvatron-BMW search space.
//!
//! A per-layer strategy (paper §III) is an *ordered* sequence of
//! (dimension, degree) levels — outermost level first, i.e. applied across
//! the slowest links of the stage's device group — plus an activation-
//! checkpointing flag. PP is not part of the per-layer strategy: it is the
//! outer decomposition (decision-tree root), chosen before layer-level
//! optimization (Takeaway #1).

pub mod comm;
pub mod memory;
pub mod transform;

use std::fmt;

/// Intra-stage parallelism dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Dim {
    /// Data parallelism: replicate model, split batch, all-reduce grads.
    Dp,
    /// Sharded data parallelism (ZeRO-3/FSDP): split batch AND shard model
    /// states; all-gather params fwd+bwd, reduce-scatter grads.
    Sdp,
    /// Tensor parallelism (Megatron): shard parameters, all-reduce
    /// activations in fwd and bwd.
    Tp,
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::Dp => write!(f, "DP"),
            Dim::Sdp => write!(f, "SDP"),
            Dim::Tp => write!(f, "TP"),
        }
    }
}

/// A hybrid per-layer strategy over a stage device group.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Strategy {
    /// (dimension, degree) levels, outermost (slowest links) first.
    /// Every degree is a power of two >= 2; dims are distinct.
    pub levels: Vec<(Dim, usize)>,
    /// Whether activation checkpointing is applied to this layer.
    pub ckpt: bool,
}

impl Strategy {
    /// The serial strategy (single device in the group).
    pub fn serial(ckpt: bool) -> Strategy {
        Strategy { levels: vec![], ckpt }
    }

    /// Single-dimension strategy.
    pub fn single(dim: Dim, degree: usize, ckpt: bool) -> Strategy {
        if degree == 1 {
            Strategy::serial(ckpt)
        } else {
            Strategy { levels: vec![(dim, degree)], ckpt }
        }
    }

    /// Total device count covered (product of level degrees).
    pub fn degree(&self) -> usize {
        self.levels.iter().map(|(_, d)| d).product()
    }

    fn dim_degree(&self, dim: Dim) -> usize {
        self.levels
            .iter()
            .filter(|(d, _)| *d == dim)
            .map(|(_, deg)| deg)
            .product()
    }

    pub fn dp(&self) -> usize {
        self.dim_degree(Dim::Dp)
    }

    pub fn sdp(&self) -> usize {
        self.dim_degree(Dim::Sdp)
    }

    pub fn tp(&self) -> usize {
        self.dim_degree(Dim::Tp)
    }

    /// Degree by which the batch is split (DP and SDP both split samples).
    pub fn batch_split(&self) -> usize {
        self.dp() * self.sdp()
    }

    /// Degree by which model states are sharded (TP shards params, SDP
    /// shards params+grads+optimizer states; DP replicates).
    pub fn state_shard(&self) -> usize {
        self.tp() * self.sdp()
    }

    /// The group size (number of devices inside the tree-level) *outside*
    /// of level `i` — the factor of slower-level parallelism wrapping it.
    pub fn outer_degree(&self, i: usize) -> usize {
        self.levels[..i].iter().map(|(_, d)| d).product()
    }

    /// Validity: distinct dims, pow-2 degrees >= 2, no DP+SDP mix
    /// (Takeaway #3).
    pub fn is_valid(&self) -> bool {
        let mut seen = Vec::new();
        for &(dim, deg) in &self.levels {
            if deg < 2 || !crate::util::is_pow2(deg) || seen.contains(&dim) {
                return false;
            }
            seen.push(dim);
        }
        !(seen.contains(&Dim::Dp) && seen.contains(&Dim::Sdp))
    }

    /// Compact label like "TP2-DP4" or "TP2-DP4+CKPT".
    pub fn label(&self) -> String {
        let mut s = if self.levels.is_empty() {
            "SERIAL".to_string()
        } else {
            self.levels
                .iter()
                .map(|(d, n)| format!("{d}{n}"))
                .collect::<Vec<_>>()
                .join("-")
        };
        if self.ckpt {
            s.push_str("+CKPT");
        }
        s
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

impl std::str::FromStr for Strategy {
    type Err = anyhow::Error;

    /// Parse a [`Strategy::label`] string back ("SERIAL", "DP2-TP4+CKPT",
    /// ...) — the plan-artifact wire format.
    fn from_str(s: &str) -> anyhow::Result<Strategy> {
        let (body, ckpt) = match s.strip_suffix("+CKPT") {
            Some(b) => (b, true),
            None => (s, false),
        };
        if body == "SERIAL" {
            return Ok(Strategy::serial(ckpt));
        }
        let mut levels = Vec::new();
        for tok in body.split('-') {
            // Longest dimension name first: "SDP" contains "DP".
            let (dim, rest) = if let Some(r) = tok.strip_prefix("SDP") {
                (Dim::Sdp, r)
            } else if let Some(r) = tok.strip_prefix("DP") {
                (Dim::Dp, r)
            } else if let Some(r) = tok.strip_prefix("TP") {
                (Dim::Tp, r)
            } else {
                anyhow::bail!("bad strategy level {tok:?} in {s:?}");
            };
            let degree: usize = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad degree in level {tok:?} of {s:?}"))?;
            levels.push((dim, degree));
        }
        let out = Strategy { levels, ckpt };
        anyhow::ensure!(out.is_valid(), "invalid strategy {s:?}");
        Ok(out)
    }
}

/// A complete distributed execution plan for a model on a cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelPlan {
    /// Pipeline parallel degree (number of stages).
    pub pp: usize,
    /// Layers per pipeline stage (sums to the model's layer count).
    pub partition: Vec<usize>,
    /// Per-layer strategy, in model layer order.
    pub strategies: Vec<Strategy>,
    /// Global batch size.
    pub batch: usize,
    /// Number of microbatches per batch.
    pub microbatches: usize,
    /// Stage→slot assignment on a heterogeneous cluster: stage `s` runs on
    /// cluster slot `stage_slots[s]` (see `ClusterSpec::stage_sites`), a
    /// permutation of `0..pp` chosen by the planner's placement pass so
    /// memory-heavy stages land on large-memory islands. `None` on
    /// homogeneous clusters (the identity), keeping their plan artifacts
    /// byte-identical to the pre-island planner.
    pub stage_slots: Option<Vec<usize>>,
}

impl ParallelPlan {
    /// Microbatch size (global batch / microbatch count).
    pub fn microbatch_size(&self) -> f64 {
        self.batch as f64 / self.microbatches as f64
    }

    /// Cluster slot of stage `s` (identity when no placement is recorded).
    pub fn slot_of(&self, s: usize) -> usize {
        self.stage_slots.as_ref().map_or(s, |v| v[s])
    }

    /// Index range of the layers in stage `s`.
    pub fn stage_layers(&self, s: usize) -> std::ops::Range<usize> {
        let start: usize = self.partition[..s].iter().sum();
        start..start + self.partition[s]
    }

    /// Validate structural invariants against a model layer count.
    pub fn validate(&self, n_layers: usize, n_devices: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.pp > 0, "pp must be >= 1");
        anyhow::ensure!(self.microbatches > 0, "microbatches must be >= 1");
        anyhow::ensure!(self.partition.len() == self.pp, "partition arity != pp");
        anyhow::ensure!(
            self.partition.iter().sum::<usize>() == n_layers,
            "partition does not cover the model"
        );
        anyhow::ensure!(self.partition.iter().all(|&p| p > 0), "empty stage");
        anyhow::ensure!(self.strategies.len() == n_layers, "strategy per layer");
        anyhow::ensure!(n_devices % self.pp == 0, "pp must divide devices");
        let group = n_devices / self.pp;
        for (i, s) in self.strategies.iter().enumerate() {
            anyhow::ensure!(s.is_valid(), "layer {i}: invalid strategy {s}");
            anyhow::ensure!(
                s.degree() == group || s.degree() == 1 && group == 1,
                "layer {i}: strategy degree {} != stage group size {group}",
                s.degree()
            );
        }
        anyhow::ensure!(self.batch % self.microbatches == 0, "m must divide B");
        if let Some(slots) = &self.stage_slots {
            anyhow::ensure!(slots.len() == self.pp, "stage_slots arity != pp");
            let mut seen = vec![false; self.pp];
            for &s in slots {
                anyhow::ensure!(s < self.pp, "stage slot {s} out of range");
                anyhow::ensure!(!seen[s], "stage slot {s} assigned twice");
                seen[s] = true;
            }
        }
        Ok(())
    }

    /// Multi-line human summary: header plus per-stage "(strategy) ×N"
    /// runs (the paper's Fig. 6 visualization).
    pub fn summary(&self) -> String {
        let partition = self
            .partition
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let mut out = format!(
            "PP={} partition=[{partition}] batch={} microbatches={}\n",
            self.pp, self.batch, self.microbatches
        );
        for s in 0..self.pp {
            let range = self.stage_layers(s);
            out.push_str(&format!("  stage {s} (layers {}..{}", range.start, range.end));
            if self.stage_slots.is_some() {
                out.push_str(&format!(", slot {}", self.slot_of(s)));
            }
            out.push_str("):");
            let mut runs: Vec<(String, usize)> = Vec::new();
            for li in range {
                let label = self.strategies[li].label();
                match runs.last_mut() {
                    Some((l, n)) if *l == label => *n += 1,
                    _ => runs.push((label, 1)),
                }
            }
            for (label, n) in runs {
                out.push_str(&format!(" [{label} ×{n}]"));
            }
            out.push('\n');
        }
        out
    }

    /// Serialize for plan artifacts (strategies as their compact labels).
    /// `stage_slots` is emitted only when a heterogeneous placement exists,
    /// so homogeneous artifacts keep their original byte layout.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut fields = vec![
            ("pp", Json::num(self.pp as f64)),
            ("partition", Json::arr(self.partition.iter().map(|&c| Json::num(c as f64)))),
            ("strategies", Json::arr(self.strategies.iter().map(|s| Json::str(&s.label())))),
            ("batch", Json::num(self.batch as f64)),
            ("microbatches", Json::num(self.microbatches as f64)),
        ];
        if let Some(slots) = &self.stage_slots {
            fields.push(("stage_slots", Json::arr(slots.iter().map(|&s| Json::num(s as f64)))));
        }
        Json::obj(fields)
    }

    /// Inverse of [`ParallelPlan::to_json`].
    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<ParallelPlan> {
        use anyhow::Context;
        let mut strategies = Vec::new();
        for s in v.req("strategies")?.as_arr().context("strategies must be an array")? {
            strategies.push(s.as_str().context("strategy must be a string")?.parse()?);
        }
        // Optional: absent for homogeneous (pre-island) artifacts.
        let stage_slots = match v.get("stage_slots") {
            None | Some(crate::util::json::Json::Null) => None,
            Some(s) => {
                Some(s.as_usize_vec().context("stage_slots must be a number array")?)
            }
        };
        let plan = ParallelPlan {
            pp: v.req("pp")?.as_usize().context("pp must be a number")?,
            partition: v
                .req("partition")?
                .as_usize_vec()
                .context("partition must be a number array")?,
            strategies,
            batch: v.req("batch")?.as_usize().context("batch must be a number")?,
            microbatches: v
                .req("microbatches")?
                .as_usize()
                .context("microbatches must be a number")?,
            stage_slots,
        };
        // Reject degenerate values up front so corrupt artifacts surface
        // as errors, not divide-by-zero panics in later validation.
        anyhow::ensure!(plan.pp > 0, "pp must be >= 1");
        anyhow::ensure!(plan.microbatches > 0, "microbatches must be >= 1");
        anyhow::ensure!(plan.batch > 0, "batch must be >= 1");
        Ok(plan)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn degrees_and_accessors() {
        let s = Strategy { levels: vec![(Dim::Dp, 2), (Dim::Tp, 4)], ckpt: false };
        assert_eq!(s.degree(), 8);
        assert_eq!(s.dp(), 2);
        assert_eq!(s.tp(), 4);
        assert_eq!(s.sdp(), 1);
        assert_eq!(s.batch_split(), 2);
        assert_eq!(s.state_shard(), 4);
        assert_eq!(s.label(), "DP2-TP4");
    }

    #[test]
    fn validity_rules() {
        let ok = Strategy { levels: vec![(Dim::Sdp, 2), (Dim::Tp, 2)], ckpt: true };
        assert!(ok.is_valid());
        // DP+SDP mixing violates Takeaway #3.
        let mix = Strategy { levels: vec![(Dim::Dp, 2), (Dim::Sdp, 2)], ckpt: false };
        assert!(!mix.is_valid());
        // Repeated dim.
        let rep = Strategy { levels: vec![(Dim::Tp, 2), (Dim::Tp, 2)], ckpt: false };
        assert!(!rep.is_valid());
        // Non-pow2 degree.
        let bad = Strategy { levels: vec![(Dim::Dp, 3)], ckpt: false };
        assert!(!bad.is_valid());
        assert!(Strategy::serial(false).is_valid());
    }

    #[test]
    fn plan_validation() {
        let s = Strategy::single(Dim::Dp, 4, false);
        let plan = ParallelPlan {
            pp: 2,
            partition: vec![2, 2],
            strategies: vec![s.clone(), s.clone(), s.clone(), s.clone()],
            batch: 8,
            microbatches: 4,
            stage_slots: None,
        };
        plan.validate(4, 8).unwrap();
        assert_eq!(plan.stage_layers(1), 2..4);
        assert_eq!(plan.microbatch_size(), 2.0);
        assert!(plan.validate(5, 8).is_err());
        assert!(plan.validate(4, 16).is_err());
    }

    #[test]
    fn strategy_labels_parse_back() {
        for s in [
            Strategy::serial(false),
            Strategy::serial(true),
            Strategy::single(Dim::Sdp, 8, false),
            Strategy { levels: vec![(Dim::Tp, 2), (Dim::Dp, 4)], ckpt: true },
            Strategy { levels: vec![(Dim::Sdp, 2), (Dim::Tp, 2)], ckpt: false },
        ] {
            let parsed: Strategy = s.label().parse().unwrap();
            assert_eq!(parsed, s, "{}", s.label());
        }
        assert!("DP3".parse::<Strategy>().is_err()); // non-pow2 degree
        assert!("XP2".parse::<Strategy>().is_err()); // unknown dimension
        assert!("DP2-SDP2".parse::<Strategy>().is_err()); // Takeaway #3
    }

    #[test]
    fn plan_json_round_trip() {
        let plan = ParallelPlan {
            pp: 2,
            partition: vec![3, 1],
            strategies: vec![
                Strategy::single(Dim::Dp, 4, false),
                Strategy { levels: vec![(Dim::Tp, 2), (Dim::Sdp, 2)], ckpt: true },
                Strategy::single(Dim::Tp, 4, true),
                Strategy::single(Dim::Sdp, 4, false),
            ],
            batch: 48,
            microbatches: 4,
            stage_slots: None,
        };
        let text = plan.to_json().to_string();
        let back = ParallelPlan::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn stage_slots_round_trip_and_validation() {
        let s = Strategy::single(Dim::Dp, 4, false);
        let mut plan = ParallelPlan {
            pp: 2,
            partition: vec![2, 2],
            strategies: vec![s.clone(), s.clone(), s.clone(), s],
            batch: 8,
            microbatches: 2,
            stage_slots: Some(vec![1, 0]),
        };
        plan.validate(4, 8).unwrap();
        assert_eq!(plan.slot_of(0), 1);
        assert_eq!(plan.slot_of(1), 0);
        let text = plan.to_json().to_string();
        assert!(text.contains("stage_slots"), "{text}");
        let back =
            ParallelPlan::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
        // Homogeneous plans omit the key entirely.
        plan.stage_slots = None;
        assert!(!plan.to_json().to_string().contains("stage_slots"));
        // Non-permutations are rejected.
        plan.stage_slots = Some(vec![0, 0]);
        assert!(plan.validate(4, 8).is_err());
        plan.stage_slots = Some(vec![0]);
        assert!(plan.validate(4, 8).is_err());
        plan.stage_slots = Some(vec![0, 2]);
        assert!(plan.validate(4, 8).is_err());
    }

    #[test]
    fn summary_groups_runs() {
        let s = Strategy::single(Dim::Dp, 4, false);
        let plan = ParallelPlan {
            pp: 2,
            partition: vec![2, 2],
            strategies: vec![s.clone(), s.clone(), Strategy::single(Dim::Tp, 4, true), s],
            batch: 16,
            microbatches: 4,
            stage_slots: None,
        };
        let text = plan.summary();
        assert!(text.contains("[DP4 ×2]"), "{text}");
        assert!(text.contains("[TP4+CKPT ×1]"), "{text}");
    }
}
