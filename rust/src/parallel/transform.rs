//! Layout transformation cost R(l, S_i, S_j) between neighboring layers
//! with different strategies (paper Eq. 4 + §VI "Slice-Gather").
//!
//! When layer l-1 runs strategy S_i and layer l runs S_j, the boundary
//! activation produced under S_i's placement must be redistributed to S_j's
//! required placement. We model the dominant term of the Slice-Gather step:
//!
//!   * If the batch split changes (dp·sdp), every device must gather the
//!     sample shards it is missing: an all-gather-like volume of the
//!     boundary tensor across the regrouping factor.
//!   * If only the TP degree changes, boundary activations are already
//!     replicated across TP, so switching TP degree is free for the
//!     activation itself (slice is a local op); the cost is borne by the
//!     next layer's own TP collectives.
//!   * Identical strategies (ignoring CKPT) cost zero.

use crate::model::LayerProfile;
use crate::parallel::Strategy;

/// Bytes each device must exchange to re-layout the boundary activation of
/// `layer` (computed under `prev`) as required by `cur`, per microbatch of
/// `b_m` samples.
pub fn transform_bytes(layer: &LayerProfile, prev: &Strategy, cur: &Strategy, b_m: f64) -> f64 {
    if prev.levels == cur.levels {
        return 0.0;
    }
    let split_prev = prev.batch_split();
    let split_cur = cur.batch_split();
    if split_prev == split_cur {
        // Same sample placement; TP-degree changes slice locally.
        return 0.0;
    }
    // Device must end up holding b_m/split_cur samples, of which it already
    // has the overlap with its previous shard (b_m/max(split) if the groups
    // nest; we charge the conservative full difference).
    let have = b_m / split_prev as f64;
    let need = b_m / split_cur as f64;
    let moved_samples = (need - have).abs().max(need.min(have) * 0.0);
    layer.bnd_bytes * moved_samples
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::LayerProfile;
    use crate::parallel::Dim;

    fn layer() -> LayerProfile {
        LayerProfile::encoder("enc", 1024, 512, 16)
    }

    #[test]
    fn identical_strategies_free() {
        let l = layer();
        let s = Strategy::single(Dim::Dp, 4, false);
        assert_eq!(transform_bytes(&l, &s, &s, 8.0), 0.0);
        // CKPT difference alone does not move data.
        let s_ck = Strategy::single(Dim::Dp, 4, true);
        assert_eq!(transform_bytes(&l, &s, &s_ck, 8.0), 0.0);
    }

    #[test]
    fn batch_regrouping_costs() {
        let l = layer();
        let dp4 = Strategy::single(Dim::Dp, 4, false);
        let tp4 = Strategy::single(Dim::Tp, 4, false);
        // DP4 -> TP4: each device needs the full microbatch boundary: moves
        // (1 - 1/4)·b_m... here modeled as |1 - 1/4|·b_m samples.
        let b = transform_bytes(&l, &dp4, &tp4, 8.0);
        assert!(b > 0.0);
        let expect = l.bnd_bytes * (8.0 - 2.0);
        assert!((b - expect).abs() < 1.0);
        // Symmetric direction also costs.
        assert!(transform_bytes(&l, &tp4, &dp4, 8.0) > 0.0);
    }

    #[test]
    fn tp_degree_change_is_free() {
        let l = layer();
        let tp2 = Strategy::single(Dim::Tp, 2, false);
        let tp4 = Strategy::single(Dim::Tp, 4, false);
        assert_eq!(transform_bytes(&l, &tp2, &tp4, 8.0), 0.0);
    }

    #[test]
    fn time_scales_with_bandwidth() {
        // Timing lives in cluster::LinkModel now; the ideal model over
        // transform_bytes is the historical bytes/bw division.
        use crate::cluster::LinkModel;
        let l = layer();
        let dp = Strategy::single(Dim::Dp, 2, false);
        let tp = Strategy::single(Dim::Tp, 2, false);
        let bytes = transform_bytes(&l, &dp, &tp, 8.0);
        let t_fast = LinkModel::ideal().time(bytes, 1e10);
        let t_slow = LinkModel::ideal().time(bytes, 1e9);
        assert!((t_slow / t_fast - 10.0).abs() < 1e-6);
    }
}
