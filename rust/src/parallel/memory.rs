//! Per-layer, per-strategy memory accounting (paper Eq. 1–3).
//!
//! For layer l with strategy S we compute:
//!   O_ms — model-state bytes per device (params + grads + Adam moments),
//!   O_f  — forward-activation bytes per device per microbatch,
//!   O_b  — backward peak-extra bytes per device per microbatch.
//!
//! Sharding rules (paper §III-A2, Fig. 2):
//!   * DP replicates model states, splits the batch.
//!   * SDP shards model states by its degree, splits the batch.
//!   * TP shards parameters AND intermediate activations by its degree but
//!     replicates boundary activations.
//!   * CKPT keeps only boundary activations live through the forward pass
//!     (O_f = bnd) and pays the intermediate as backward peak (O_b = int).

use crate::model::{LayerProfile, TrainConfig};
use crate::parallel::Strategy;

/// Bytes of model state per parameter under the *default* training
/// numerics: fp32 param + grad + Adam m + v. The general accounting lives
/// in [`TrainConfig::state_bytes_per_param`]; its default reproduces this
/// constant exactly.
pub const STATE_BYTES_PER_PARAM: f64 = 16.0;

/// Memory footprint of one layer under one strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerMemory {
    /// Model states, bytes per device (static across the iteration).
    pub o_ms: f64,
    /// Forward activations stashed until this layer's backward, per device
    /// per microbatch sample count `b_m`.
    pub o_f: f64,
    /// Extra peak during this layer's backward (CKPT recompute results).
    pub o_b: f64,
}

impl LayerMemory {
    pub fn total_fwd(&self) -> f64 {
        self.o_ms + self.o_f
    }
}

/// Compute the memory footprint of `layer` under `strategy` with microbatch
/// size `b_m` (samples per microbatch, *before* batch splitting) and
/// `extra_params` additional parameters attributed to this layer
/// (embeddings on the first layer, heads on the last), under the default
/// training numerics (fp32 + Adam, no ZeRO).
pub fn layer_memory(layer: &LayerProfile, strategy: &Strategy, b_m: f64, extra_params: f64) -> LayerMemory {
    layer_memory_with(layer, strategy, b_m, extra_params, &TrainConfig::default())
}

/// [`layer_memory`] under explicit training numerics: model-state bytes
/// follow the dtype/optimizer (with ZeRO sharding the optimizer state over
/// the strategy's DP degree) and activation bytes scale with the dtype.
/// The default `train` reproduces [`layer_memory`] bit-for-bit.
pub fn layer_memory_with(
    layer: &LayerProfile,
    strategy: &Strategy,
    b_m: f64,
    extra_params: f64,
    train: &TrainConfig,
) -> LayerMemory {
    let params = layer.params + extra_params;
    let o_ms = params * train.state_bytes_per_param(strategy.dp()) / strategy.state_shard() as f64;

    // Samples this device actually processes per microbatch; activations
    // are stored in the training dtype.
    let local_samples = b_m / strategy.batch_split() as f64;
    let scale = train.act_scale();
    let bnd = layer.bnd_bytes * scale * local_samples;
    // TP shards the intermediate activations; boundary is replicated.
    let int = layer.int_bytes() * scale * local_samples / strategy.tp() as f64;

    let (o_f, o_b) = if strategy.ckpt {
        (bnd, int)
    } else {
        (bnd + int, 0.0)
    };
    LayerMemory { o_ms, o_f, o_b }
}

/// Peak memory of a pipeline stage holding `layers[i]` with
/// `strategies[i]`, when `live_mb` microbatches are simultaneously in
/// flight (1F1B: P - stage_index; GPipe: m).
///
/// Implements Eq. 2 within the stage: while back-propagating layer i of the
/// *oldest* microbatch, the stage holds all live microbatches' forward
/// activations for layers <= i of the newest ones — we take the standard
/// upper bound: (live-1) complete forward footprints plus the Eq. 2 walk of
/// the current microbatch.
pub fn stage_peak_memory(mems: &[LayerMemory], live_mb: usize) -> f64 {
    let ms_total: f64 = mems.iter().map(|m| m.o_ms).sum();
    let f_total: f64 = mems.iter().map(|m| m.o_f).sum();
    let live_extra = (live_mb.max(1) - 1) as f64 * f_total;

    // Eq. 2 walk over the current microbatch.
    let mut prefix_f = 0.0;
    let mut walk_peak: f64 = 0.0;
    for m in mems {
        prefix_f += m.o_f;
        walk_peak = walk_peak.max(prefix_f + m.o_b);
    }
    ms_total + live_extra + walk_peak
}

/// Forward-memory total E_f of Eq. 3 for a stage (single microbatch).
pub fn stage_forward_memory(mems: &[LayerMemory]) -> f64 {
    mems.iter().map(|m| m.o_ms + m.o_f).sum()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::LayerProfile;
    use crate::parallel::Dim;

    fn layer() -> LayerProfile {
        LayerProfile::encoder("enc", 1024, 512, 16)
    }

    #[test]
    fn dp_replicates_states_splits_batch() {
        let l = layer();
        let dp4 = Strategy::single(Dim::Dp, 4, false);
        let serial = Strategy::serial(false);
        let m4 = layer_memory(&l, &dp4, 8.0, 0.0);
        let m1 = layer_memory(&l, &serial, 8.0, 0.0);
        assert_eq!(m4.o_ms, m1.o_ms); // replicated
        assert!((m4.o_f - m1.o_f / 4.0).abs() < 1.0); // batch split
        assert_eq!(m4.o_b, 0.0);
    }

    #[test]
    fn sdp_shards_states() {
        let l = layer();
        let sdp4 = Strategy::single(Dim::Sdp, 4, false);
        let dp4 = Strategy::single(Dim::Dp, 4, false);
        let ms = layer_memory(&l, &sdp4, 8.0, 0.0);
        let md = layer_memory(&l, &dp4, 8.0, 0.0);
        assert!((ms.o_ms - md.o_ms / 4.0).abs() < 1.0);
        assert_eq!(ms.o_f, md.o_f); // same batch split
    }

    #[test]
    fn tp_shards_intermediate_not_boundary() {
        let l = layer();
        let tp4 = Strategy::single(Dim::Tp, 4, false);
        let m = layer_memory(&l, &tp4, 8.0, 0.0);
        let expect_f = l.bnd_bytes * 8.0 + l.int_bytes() * 8.0 / 4.0;
        assert!((m.o_f - expect_f).abs() < 1.0);
        // TP shards params too.
        assert!((m.o_ms - l.params * STATE_BYTES_PER_PARAM / 4.0).abs() < 1.0);
    }

    #[test]
    fn ckpt_moves_intermediate_to_backward() {
        let l = layer();
        let plain = layer_memory(&l, &Strategy::serial(false), 4.0, 0.0);
        let ck = layer_memory(&l, &Strategy::serial(true), 4.0, 0.0);
        assert!(ck.o_f < plain.o_f);
        assert!((ck.o_f - l.bnd_bytes * 4.0).abs() < 1.0);
        assert!((ck.o_b - l.int_bytes() * 4.0).abs() < 1.0);
        assert!((ck.o_f + ck.o_b - plain.o_f).abs() < 1.0); // conservation
    }

    #[test]
    fn extra_params_counted() {
        let l = layer();
        let with = layer_memory(&l, &Strategy::serial(false), 1.0, 1e6);
        let without = layer_memory(&l, &Strategy::serial(false), 1.0, 0.0);
        assert!((with.o_ms - without.o_ms - 16e6).abs() < 1.0);
    }

    #[test]
    fn train_config_default_is_bit_identical() {
        use crate::model::TrainConfig;
        let l = layer();
        for strat in [
            Strategy::serial(false),
            Strategy::single(Dim::Dp, 4, true),
            Strategy::single(Dim::Tp, 4, false),
            Strategy::single(Dim::Sdp, 8, false),
        ] {
            let legacy = layer_memory(&l, &strat, 8.0, 1e6);
            let dflt = layer_memory_with(&l, &strat, 8.0, 1e6, &TrainConfig::default());
            assert_eq!(legacy.o_ms.to_bits(), dflt.o_ms.to_bits());
            assert_eq!(legacy.o_f.to_bits(), dflt.o_f.to_bits());
            assert_eq!(legacy.o_b.to_bits(), dflt.o_b.to_bits());
        }
        assert_eq!(TrainConfig::default().state_bytes_per_param(1), STATE_BYTES_PER_PARAM);
    }

    #[test]
    fn fp16_halves_activations_keeps_states() {
        use crate::model::{Dtype, TrainConfig};
        let l = layer();
        let s = Strategy::serial(false);
        let fp32 = layer_memory_with(&l, &s, 8.0, 0.0, &TrainConfig::default());
        let half = TrainConfig { dtype: Dtype::Fp16, ..Default::default() };
        let fp16 = layer_memory_with(&l, &s, 8.0, 0.0, &half);
        assert!((fp16.o_f - fp32.o_f / 2.0).abs() < 1.0, "fp16 activations must halve");
        // fp16 Adam: 2 param + 2 grad + 4 master + 8 moments = 16 (same total).
        assert_eq!(fp16.o_ms, fp32.o_ms);
    }

    #[test]
    fn sgd_drops_adam_state_and_zero_shards_it() {
        use crate::model::{OptimizerKind, TrainConfig};
        let l = layer();
        let dp4 = Strategy::single(Dim::Dp, 4, false);
        let adam = layer_memory_with(&l, &dp4, 8.0, 0.0, &TrainConfig::default());
        let sgd_cfg = TrainConfig { optimizer: OptimizerKind::Sgd, ..Default::default() };
        let sgd = layer_memory_with(&l, &dp4, 8.0, 0.0, &sgd_cfg);
        // Adam adds exactly 8 bytes/param of fp32 state over SGD.
        assert!((adam.o_ms - sgd.o_ms - 8.0 * l.params).abs() < 1.0);
        // ZeRO divides the optimizer state by the DP degree.
        let zero_cfg = TrainConfig { zero: true, ..Default::default() };
        let zero = layer_memory_with(&l, &dp4, 8.0, 0.0, &zero_cfg);
        assert!((zero.o_ms - (8.0 + 8.0 / 4.0) * l.params).abs() < 1.0);
        // Without a DP dimension there is nothing to shard over.
        let serial = layer_memory_with(&l, &Strategy::serial(false), 8.0, 0.0, &zero_cfg);
        assert!((serial.o_ms - 16.0 * l.params).abs() < 1.0);
        // Activations are untouched by optimizer/ZeRO choices.
        assert_eq!(zero.o_f, adam.o_f);
    }

    #[test]
    fn stage_peak_monotone_in_live_microbatches() {
        let l = layer();
        let mems: Vec<_> = (0..4)
            .map(|_| layer_memory(&l, &Strategy::serial(false), 2.0, 0.0))
            .collect();
        let p1 = stage_peak_memory(&mems, 1);
        let p2 = stage_peak_memory(&mems, 2);
        let p4 = stage_peak_memory(&mems, 4);
        assert!(p1 < p2 && p2 < p4);
        // live=1 peak equals Eq.2 walk = ms + all forward activations.
        let expect = mems.iter().map(|m| m.o_ms + m.o_f).sum::<f64>();
        assert!((p1 - expect).abs() < 1.0);
    }

    #[test]
    fn ckpt_lowers_stage_peak_with_many_live() {
        let l = layer();
        let plain: Vec<_> = (0..4)
            .map(|_| layer_memory(&l, &Strategy::serial(false), 2.0, 0.0))
            .collect();
        let ck: Vec<_> = (0..4)
            .map(|_| layer_memory(&l, &Strategy::serial(true), 2.0, 0.0))
            .collect();
        assert!(stage_peak_memory(&ck, 4) < stage_peak_memory(&plain, 4));
    }
}
