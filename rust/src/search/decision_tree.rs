//! Decision-tree search-space construction (paper §III-B).
//!
//! For a stage device group of size G (a power of two), the candidate
//! hybrid strategies are ordered sequences of (dimension, degree) levels:
//!
//!   * tree height = number of parallelism paradigms used,
//!   * no dimension repeats across levels,
//!   * non-leaf degrees come from {2, 4, 8, ...},
//!   * Takeaway #3 prunes any tree containing both DP and SDP,
//!   * each tree exists with and without CKPT.
//!
//! For 8 GPUs this yields 11 + 7 + 3 + 1 = 22 trees across PP degrees
//! {1,2,4,8}, i.e. 44 candidates with CKPT — the counts in paper Fig. 3
//! (and 68 pre-Takeaway-3) — verified by unit tests below.

use crate::cost::estimator::LayerCost;
use crate::parallel::{Dim, Strategy};
use crate::util::is_pow2;

/// Options controlling search-space construction (used to express the
/// restricted baselines: DP+TP, DP+PP, no-CKPT, ...).
#[derive(Debug, Clone)]
pub struct SpaceOptions {
    /// Dimensions available inside a stage.
    pub dims: Vec<Dim>,
    /// Whether CKPT variants are generated.
    pub allow_ckpt: bool,
    /// Whether Takeaway #3 (no DP+SDP mixing) prunes the space.
    pub takeaway3: bool,
}

impl Default for SpaceOptions {
    fn default() -> Self {
        SpaceOptions { dims: vec![Dim::Dp, Dim::Sdp, Dim::Tp], allow_ckpt: true, takeaway3: true }
    }
}

impl SpaceOptions {
    pub fn no_ckpt(mut self) -> Self {
        self.allow_ckpt = false;
        self
    }

    pub fn with_dims(mut self, dims: &[Dim]) -> Self {
        self.dims = dims.to_vec();
        self
    }
}

/// Enumerate the candidate strategies for one stage group of `group`
/// devices. Order within the returned vector is deterministic.
pub fn candidate_strategies(group: usize, opts: &SpaceOptions) -> Vec<Strategy> {
    assert!(is_pow2(group), "group size must be a power of two, got {group}");
    let mut levelings: Vec<Vec<(Dim, usize)>> = Vec::new();
    enumerate_levels(group, &opts.dims, opts.takeaway3, &mut Vec::new(), &mut levelings);

    let mut out = Vec::new();
    for levels in levelings {
        out.push(Strategy { levels: levels.clone(), ckpt: false });
        if opts.allow_ckpt {
            out.push(Strategy { levels, ckpt: true });
        }
    }
    out
}

fn enumerate_levels(
    remaining: usize,
    dims: &[Dim],
    takeaway3: bool,
    prefix: &mut Vec<(Dim, usize)>,
    out: &mut Vec<Vec<(Dim, usize)>>,
) {
    if remaining == 1 {
        out.push(prefix.clone());
        return;
    }
    for &dim in dims {
        if prefix.iter().any(|(d, _)| *d == dim) {
            continue;
        }
        if takeaway3 {
            let has_dp = dim == Dim::Dp || prefix.iter().any(|(d, _)| *d == Dim::Dp);
            let has_sdp = dim == Dim::Sdp || prefix.iter().any(|(d, _)| *d == Dim::Sdp);
            if has_dp && has_sdp {
                continue;
            }
        }
        let mut degree = 2;
        while degree <= remaining {
            prefix.push((dim, degree));
            enumerate_levels(remaining / degree, dims, takeaway3, prefix, out);
            prefix.pop();
            degree *= 2;
        }
    }
}

/// Pairwise dominance over a candidate catalog, judged on memoized cost
/// rows (`class_costs[layer_class][candidate]`, one row per distinct layer
/// cost class of the model). Returns a mask: `true` means the candidate can
/// be dropped from the stage-level DP without changing its answer.
///
/// Candidate `j` is dominated by an earlier candidate `k < j` iff, for
/// every layer class:
///
///   * the batch-split degree matches (so transform costs R are identical
///     for every neighbor — R reads only the split),
///   * the forward-memory weight is *bitwise* identical (`o_ms` and `o_f`
///     bit-equal, so the DP bucket of every layer is the same at any
///     granularity/live-microbatch count) and the backward spike is no
///     larger (`o_b <=`, so the Eq. 2 peak of the substituted path can
///     only shrink),
///   * the time components satisfy `fwd+bwd <=` and `bwd_sync-bwd <=`
///     (exactly the two terms the DP's per-batch cost combines, so
///     `m·(fwd+bwd) + (bwd_sync-bwd)` is `<=` for *every* microbatch
///     count under monotone float rounding).
///
/// Under the DP's strictly-less update rule (earliest index wins ties) a
/// dominated candidate can never appear in a returned assignment: any path
/// through `j` has a path through `k` of equal bucket column, `<=` cost
/// and `<=` true peak that precedes it in enumeration order. Equality is
/// deliberately non-strict — the common case is topology-permuted level
/// orderings with tied costs — but the index condition `k < j` keeps the
/// relation irreflexive and the *first* member of every batch-split class
/// always survives, so the split-class structure the DP collapses
/// predecessors into is unchanged.
pub fn dominated_candidates(
    strategies: &[Strategy],
    class_costs: &[Vec<LayerCost>],
) -> Vec<bool> {
    let ns = strategies.len();
    let mut dominated = vec![false; ns];
    for j in 0..ns {
        'candidate: for k in 0..j {
            if dominated[k] || strategies[k].batch_split() != strategies[j].batch_split() {
                // Transitivity makes skipping dominated dominators safe:
                // whatever dominates k also dominates j.
                continue;
            }
            for row in class_costs {
                let (a, b) = (&row[k], &row[j]);
                let weight_equal = a.mem.o_ms.to_bits() == b.mem.o_ms.to_bits()
                    && a.mem.o_f.to_bits() == b.mem.o_f.to_bits();
                let dominates = weight_equal
                    && a.mem.o_b <= b.mem.o_b
                    && a.fwd + a.bwd <= b.fwd + b.bwd
                    && a.bwd_sync - a.bwd <= b.bwd_sync - b.bwd;
                if !dominates {
                    continue 'candidate;
                }
            }
            dominated[j] = true;
            break;
        }
    }
    dominated
}

/// Total candidate count across all PP degrees for `n` devices — the
/// "44 strategies for 8 GPUs" quantity of paper §III-B.
pub fn total_candidates(n: usize, opts: &SpaceOptions) -> usize {
    crate::util::pow2_divisors(n)
        .into_iter()
        .map(|pp| candidate_strategies(n / pp, opts).len())
        .sum()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn paper_counts_8_gpus() {
        let full = SpaceOptions::default();
        // Per-group counts (with CKPT): G=8 -> 22, G=4 -> 14, G=2 -> 6, G=1 -> 2.
        assert_eq!(candidate_strategies(8, &full).len(), 22);
        assert_eq!(candidate_strategies(4, &full).len(), 14);
        assert_eq!(candidate_strategies(2, &full).len(), 6);
        assert_eq!(candidate_strategies(1, &full).len(), 2);
        // Paper: 44 candidates for 8 GPUs across PP degrees.
        assert_eq!(total_candidates(8, &full), 44);
        // Without CKPT: 22 (the "Galvatron" variant count in Fig. 5b).
        assert_eq!(total_candidates(8, &full.clone().no_ckpt()), 22);
        // Without Takeaway #3 pruning: 68 (paper §III-B).
        let no_t3 = SpaceOptions { takeaway3: false, ..Default::default() };
        assert_eq!(total_candidates(8, &no_t3), 68);
    }

    #[test]
    fn limited_dims_match_prior_work_counts() {
        // Paper Fig. 5(b): "both DP+TP and DP+PP have a total of 4 alternate
        // strategies on 8 GPUs" (per PP degree incl. pure forms, no ckpt).
        let dp_tp = SpaceOptions::default().with_dims(&[Dim::Dp, Dim::Tp]).no_ckpt();
        // Group 8: DP8, TP8, DP2-TP4, DP4-TP2, TP2-DP4, TP4-DP2 ... ordered:
        // sequences with product 8 over {DP,TP}.
        let g8 = candidate_strategies(8, &dp_tp);
        assert!(g8.len() >= 4);
        for s in &g8 {
            assert!(s.sdp() == 1 && !s.ckpt);
        }
        let dp_only = SpaceOptions::default().with_dims(&[Dim::Dp]).no_ckpt();
        assert_eq!(candidate_strategies(8, &dp_only).len(), 1); // DP8
    }

    #[test]
    fn all_candidates_valid_and_cover_group() {
        for g in [1usize, 2, 4, 8, 16] {
            for s in candidate_strategies(g, &SpaceOptions::default()) {
                assert!(s.is_valid(), "{s}");
                assert_eq!(s.degree(), g, "{s}");
            }
        }
    }

    #[test]
    fn no_dp_sdp_mix_after_takeaway3() {
        for s in candidate_strategies(8, &SpaceOptions::default()) {
            assert!(!(s.dp() > 1 && s.sdp() > 1), "{s}");
        }
        // Pre-pruning the mixes exist.
        let no_t3 = SpaceOptions { takeaway3: false, ..Default::default() };
        assert!(candidate_strategies(8, &no_t3)
            .iter()
            .any(|s| s.dp() > 1 && s.sdp() > 1));
    }

    #[test]
    fn orderings_are_distinct_candidates() {
        // Permutations capture topology placement (paper: "it is necessary
        // to consider the permutations of hybrid strategies").
        let cands = candidate_strategies(8, &SpaceOptions::default().no_ckpt());
        let dp2_tp4 = cands.iter().any(|s| s.levels == vec![(Dim::Dp, 2), (Dim::Tp, 4)]);
        let tp4_dp2 = cands.iter().any(|s| s.levels == vec![(Dim::Tp, 4), (Dim::Dp, 2)]);
        assert!(dp2_tp4 && tp4_dp2);
    }

    #[test]
    fn deterministic_order() {
        let a = candidate_strategies(8, &SpaceOptions::default());
        let b = candidate_strategies(8, &SpaceOptions::default());
        assert_eq!(a, b);
    }

    #[test]
    fn dominance_keeps_first_of_every_split_class() {
        use crate::cluster::cluster_by_name;
        use crate::cost::CostEstimator;
        use crate::model::model_by_name;
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 1, 1.3);
        let cands = candidate_strategies(8, &SpaceOptions::default());
        let classes = crate::search::engine::layer_classes(&model);
        let n_classes = *classes.iter().max().unwrap() as usize + 1;
        let rows: Vec<Vec<LayerCost>> = (0..n_classes)
            .map(|c| {
                let rep = classes.iter().position(|&x| x as usize == c).unwrap();
                cands
                    .iter()
                    .map(|s| est.layer_cost(&model.layers[rep], s, 4.0, model.extra_params(rep)))
                    .collect()
            })
            .collect();
        let dom = dominated_candidates(&cands, &rows);
        // titan8's saturated bus makes topology-permuted orderings tie.
        assert!(dom.iter().any(|&d| d), "expected dominated ordering permutations");
        // The first member of each batch-split class must survive, so the
        // DP's split-class structure is unchanged by pruning.
        let mut seen = std::collections::HashSet::new();
        for (i, s) in cands.iter().enumerate() {
            if seen.insert(s.batch_split()) {
                assert!(!dom[i], "first of split class {} pruned", s.batch_split());
            }
        }
        // Never dominated by itself or a later candidate: an all-distinct
        // catalog (one per split) prunes nothing.
        let one_per_split: Vec<Strategy> = {
            let mut seen = std::collections::HashSet::new();
            cands.iter().filter(|s| seen.insert(s.batch_split())).cloned().collect()
        };
        let rows1: Vec<Vec<LayerCost>> = rows
            .iter()
            .map(|row| {
                let mut seen = std::collections::HashSet::new();
                cands
                    .iter()
                    .zip(row)
                    .filter(|(s, _)| seen.insert(s.batch_split()))
                    .map(|(_, c)| *c)
                    .collect()
            })
            .collect();
        let dom1 = dominated_candidates(&one_per_split, &rows1);
        assert!(dom1.iter().all(|&d| !d), "distinct splits can never dominate each other");
    }

    #[test]
    fn scales_to_64_gpus() {
        let n = total_candidates(64, &SpaceOptions::default());
        assert!(n > 44, "64-GPU space must be larger: {n}");
        // Still far below the unpruned combinatorial space.
        let no_t3 = SpaceOptions { takeaway3: false, ..Default::default() };
        assert!(n < total_candidates(64, &no_t3));
    }
}
