//! Galvatron-Base optimization workflow (paper §IV-A, Algorithm 1).
//!
//! Sweep the global batch size upward; for every candidate PP degree,
//! partition the model, run the stage-level DP search (dp.rs) under the
//! device memory budget, compose the pipeline cost (Eq. 9), and track the
//! best throughput until everything OOMs.
//!
//! The sweep itself executes on the parallel memoized
//! [`crate::search::engine::SearchEngine`]; this module keeps the
//! configuration type, the uncached single-point reference evaluator
//! ([`evaluate_partition`]) and the `optimize` front door.

use crate::cluster::ClusterSpec;
use crate::cost::pipeline::{plan_cost_full, PlanCost, Schedule};
use crate::cost::{CostEstimator, CostModel};
use crate::model::{ModelProfile, TrainConfig};
use crate::parallel::memory::LayerMemory;
use crate::parallel::{ParallelPlan, Strategy};
use crate::util::{pow2_divisors, MIB};

use super::decision_tree::{candidate_strategies, SpaceOptions};
use super::dp::{dp_search, DpInput};
use super::engine::{CellAlgo, SearchEngine, SearchTrace};

/// Everything that configures one optimizer run.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Search-space construction options (dims, ckpt, pruning).
    pub space: SpaceOptions,
    /// Pipeline schedule for memory accounting.
    pub schedule: Schedule,
    /// If set, bypass enumeration: the only candidate strategy per stage
    /// group (used by pure/expert baselines). Degree must equal group size.
    pub fixed_strategy: Option<Strategy>,
    /// PP degrees to explore; `None` = all powers of two up to N.
    pub pp_degrees: Option<Vec<usize>>,
    /// Compute/communication contention factor (§V).
    pub overlap_slowdown: f64,
    /// DP memory discretization (bytes).
    pub granularity: f64,
    /// Largest global batch size to consider.
    pub max_batch: usize,
    /// Stop after this many consecutive infeasible batch sizes once any
    /// feasible plan was found. Patience is counted over *ordered* batch
    /// sizes (the sweep order), never over completion order — the parallel
    /// engine's reduction and a sequential sweep stop at the same batch.
    pub patience: usize,
    /// Cap on the microbatch count (gradient-accumulation depth). Pure
    /// single-shot baselines (DDP / Megatron-TP / FSDP as benchmarked in
    /// the paper) use `Some(1)`; `None` = unbounded.
    pub microbatch_limit: Option<usize>,
    /// Worker threads for the (batch × PP) cell fan-out. `None` (or
    /// `Some(0)`) resolves via `GALVATRON_THREADS` or the machine's
    /// available parallelism; results are identical for every value.
    pub threads: Option<usize>,
    /// Training numerics (dtype/optimizer/ZeRO) for the memory accounting.
    /// The default (fp32 + Adam, unsharded) keeps plans byte-identical to
    /// the pre-spec planner.
    pub train: TrainConfig,
    /// Cost-model backend every estimator of this run binds to. The
    /// default analytic backend keeps plans byte-identical to the
    /// pre-backend planner; a calibrated backend prices the same search
    /// from a loaded [`crate::cost::ProfileDb`].
    pub cost_model: CostModel,
    /// Directory of the persistent planning cache
    /// ([`crate::search::engine::persist`]). `None` (the default) keeps
    /// every run self-contained; with a directory, the engine warm-starts
    /// its cost tables from compatible prior runs and flushes what it
    /// learned. Never changes a plan — only its wall time.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Cold-path pruning (dominance pruning, DP reachability bounds, and
    /// the lower-bound evaluation skip). `None` (the default) resolves at
    /// engine construction: on, unless the `GALVATRON_NO_PRUNE` environment
    /// variable disables it. Pruning never changes an artifact byte — every
    /// skipped candidate is provably dominated or beaten — only wall time,
    /// so this knob exists for benchmarking and byte-identity CI checks.
    pub prune: Option<bool>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            space: SpaceOptions::default(),
            schedule: Schedule::OneFOneB,
            fixed_strategy: None,
            pp_degrees: None,
            overlap_slowdown: crate::cost::DEFAULT_OVERLAP_SLOWDOWN,
            granularity: 64.0 * MIB,
            max_batch: 4096,
            patience: 3,
            microbatch_limit: None,
            threads: None,
            train: TrainConfig::default(),
            cost_model: CostModel::Analytic,
            cache_dir: None,
            prune: None,
        }
    }
}

/// A search result: the plan plus its estimated cost.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub plan: ParallelPlan,
    pub cost: PlanCost,
}

impl SearchOutcome {
    pub fn throughput(&self) -> f64 {
        self.cost.throughput
    }
}

/// Per-layer diagnostics used by the BMW partition adjustment.
#[derive(Debug, Clone)]
pub struct LayerDiag {
    /// Per-microbatch fwd+bwd time of the layer under its chosen strategy.
    pub time: f64,
    pub mem: LayerMemory,
}

/// Evaluate one (batch, pp, microbatches, partition) point: run the DP per
/// stage and compose. Returns the feasible outcome + per-layer diagnostics.
///
/// This is the *uncached reference* evaluator: it rebuilds the candidate
/// catalog and estimator per call. The engine's hot path uses the memoized
/// equivalent in `search::engine`; the cache-consistency tests pin the two
/// to identical results.
pub fn evaluate_partition(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
    batch: usize,
    pp: usize,
    microbatches: usize,
    partition: &[usize],
) -> Option<(SearchOutcome, Vec<LayerDiag>)> {
    let n = cluster.n_devices();
    debug_assert_eq!(n % pp, 0);
    let group = n / pp;
    // Identity stage→slot placement: stage s runs on cluster slot s, with
    // that slot's island budget and FLOP rate (all slots identical on a
    // homogeneous cluster). The engine's cached path additionally explores
    // capacity-ranked placements.
    let sites = cluster.stage_sites(pp);
    let ests: Vec<CostEstimator> = sites
        .iter()
        .map(|site| {
            CostEstimator::with_site(cluster, pp, cfg.overlap_slowdown, site.clone())
                .with_train(cfg.train)
                .with_cost_model(cfg.cost_model.clone())
        })
        .collect();
    let b_m = batch as f64 / microbatches as f64;

    let candidates = stage_candidates(cfg, group);
    if candidates.is_empty() {
        return None;
    }

    let mut strategies: Vec<Strategy> = Vec::with_capacity(model.n_layers());
    let mut start = 0usize;
    for (s, &count) in partition.iter().enumerate() {
        let layers = &model.layers[start..start + count];
        let extra: Vec<f64> = (start..start + count).map(|i| model.extra_params(i)).collect();
        let live = cfg.schedule.live_microbatches(s, pp, microbatches);
        let res = dp_search(&DpInput {
            layers,
            extra_params: &extra,
            strategies: &candidates,
            costs: &ests[s],
            layer_offset: start,
            b_m,
            microbatches,
            live_mb: live,
            mem_budget: sites[s].gpu.mem_bytes,
            granularity: cfg.granularity,
        })?;
        strategies.extend(res.strategies);
        start += count;
    }

    let plan = ParallelPlan {
        pp,
        partition: partition.to_vec(),
        strategies,
        batch,
        microbatches,
        stage_slots: if cluster.is_homogeneous() { None } else { Some((0..pp).collect()) },
    };
    let cost = plan_cost_full(
        model,
        cluster,
        &plan,
        cfg.schedule,
        cfg.overlap_slowdown,
        cfg.train,
        &cfg.cost_model,
    );
    if !cost.feasible {
        return None;
    }

    // Per-layer diagnostics for partition adjustment (priced on each
    // layer's assigned stage site).
    let mut diags = Vec::with_capacity(model.n_layers());
    let mut start = 0usize;
    for (s, &count) in partition.iter().enumerate() {
        for i in start..start + count {
            let extra = model.extra_params(i);
            let c = ests[s].layer_cost(&model.layers[i], &plan.strategies[i], b_m, extra);
            diags.push(LayerDiag { time: c.fwd + c.bwd, mem: c.mem });
        }
        start += count;
    }
    Some((SearchOutcome { plan, cost }, diags))
}

/// Candidate strategies for one stage group of `group` devices under this
/// configuration — the single source of truth shared by the uncached
/// reference evaluator and the engine's per-PP catalogs. A
/// `fixed_strategy` whose degree does not match the group yields an empty
/// catalog (the PP degree is simply not usable by that baseline).
pub fn stage_candidates(cfg: &SearchConfig, group: usize) -> Vec<Strategy> {
    match &cfg.fixed_strategy {
        Some(s) => {
            let mut v = Vec::new();
            if s.degree() == group {
                v.push(s.clone());
                if cfg.space.allow_ckpt {
                    let mut ck = s.clone();
                    ck.ckpt = true;
                    v.push(ck);
                }
            }
            v
        }
        None => candidate_strategies(group, &cfg.space),
    }
}

/// PP degrees to explore for a model/cluster pair.
pub fn pp_degrees(model: &ModelProfile, cluster: &ClusterSpec, cfg: &SearchConfig) -> Vec<usize> {
    match &cfg.pp_degrees {
        Some(v) => v.clone(),
        None => pow2_divisors(cluster.n_devices())
            .into_iter()
            .filter(|&p| p <= model.n_layers())
            .collect(),
    }
}

/// Galvatron-Base (Algorithm 1): even-layer pipeline partition, batch-size
/// sweep, DP per stage, best throughput wins. Runs on the parallel
/// memoized engine; see [`optimize_traced`] for the search diagnostics.
pub fn optimize(model: &ModelProfile, cluster: &ClusterSpec, cfg: &SearchConfig) -> Option<SearchOutcome> {
    optimize_traced(model, cluster, cfg).0
}

/// [`optimize`] plus the engine's structured [`SearchTrace`].
pub fn optimize_traced(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
) -> (Option<SearchOutcome>, SearchTrace) {
    SearchEngine::new(model, cluster, cfg, CellAlgo::Even).run()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::util::GIB;

    fn quick_cfg() -> SearchConfig {
        SearchConfig { max_batch: 64, ..Default::default() }
    }

    #[test]
    fn finds_plan_for_bert_on_titan8() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let out = optimize(&model, &cluster, &quick_cfg()).expect("feasible plan");
        out.plan.validate(32, 8).unwrap();
        assert!(out.throughput() > 0.0);
        assert!(out.cost.feasible);
    }

    #[test]
    fn more_memory_never_hurts() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cfg = quick_cfg();
        let t8 = optimize(&model, &cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB), &cfg)
            .map(|o| o.throughput())
            .unwrap_or(0.0);
        let t16 = optimize(&model, &cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB), &cfg)
            .map(|o| o.throughput())
            .unwrap_or(0.0);
        assert!(t16 >= t8 * 0.999, "t16 {t16} < t8 {t8}");
    }

    #[test]
    fn tiny_budget_returns_none_or_small() {
        let model = model_by_name("bert-huge-48").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(0.5 * GIB);
        assert!(optimize(&model, &cluster, &quick_cfg()).is_none());
    }

    #[test]
    fn fixed_strategy_restricts_plan() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let cfg = SearchConfig {
            fixed_strategy: Some(Strategy::single(crate::parallel::Dim::Sdp, 8, false)),
            pp_degrees: Some(vec![1]),
            space: SpaceOptions::default().no_ckpt(),
            max_batch: 64,
            ..Default::default()
        };
        let out = optimize(&model, &cluster, &cfg).expect("sdp fits");
        assert!(out.plan.strategies.iter().all(|s| s.sdp() == 8 && !s.ckpt));
        assert_eq!(out.plan.pp, 1);
    }

    #[test]
    fn ckpt_space_enables_larger_batches() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB);
        let with = optimize(&model, &cluster, &SearchConfig { max_batch: 128, ..Default::default() });
        let without = optimize(
            &model,
            &cluster,
            &SearchConfig { max_batch: 128, space: SpaceOptions::default().no_ckpt(), ..Default::default() },
        );
        let bw = with.as_ref().map(|o| o.plan.batch).unwrap_or(0);
        let bo = without.as_ref().map(|o| o.plan.batch).unwrap_or(0);
        assert!(bw >= bo, "ckpt batch {bw} < no-ckpt batch {bo}");
    }
}
