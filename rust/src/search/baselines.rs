//! Every baseline the paper compares against (§VII-A):
//!
//!   * PyTorch DDP (pure DP)            * Megatron (pure TP)
//!   * PyTorch GPipe (pure PP)          * FSDP/ZeRO-3 (pure SDP)
//!   * DeepSpeed 3D (expert 2-way DP×TP×PP)
//!   * Galvatron (DP+TP), Galvatron (DP+PP)  — limited-dimension automatic
//!   * Galvatron (no CKPT), Galvatron-Base (+CKPT)
//!   * Galvatron (1F1B+Bi-obj), Galvatron-BMW (full)
//!   * Alpa-like (DP xor SDP globally + TP + PP, no CKPT) — Table VI
//!   * 1F1B+Mem / 1F1B+Time partition ablations — Table V

use crate::cluster::ClusterSpec;
use crate::cost::pipeline::Schedule;
use crate::model::ModelProfile;
use crate::parallel::Dim;
use crate::search::base::{evaluate_partition, optimize, SearchConfig, SearchOutcome};
use crate::search::bmw::{memory_balanced_partition, optimize_bmw};
use crate::search::decision_tree::SpaceOptions;
use crate::search::partition::balanced_partition;
use crate::search::levels;

/// All strategy names, in the row order of Table II.
pub fn method_names() -> Vec<&'static str> {
    vec![
        "PyTorch DDP (DP)",
        "Megatron (TP)",
        "PyTorch GPipe (PP)",
        "FSDP/ZeRO-3 (SDP)",
        "DeepSpeed 3D",
        "Galvatron (DP+TP)",
        "Galvatron (DP+PP)",
        "Galvatron",
        "Galvatron-Base",
        "Galvatron (1F1B+Bi-obj)",
        "Galvatron-BMW",
    ]
}

/// Run a named method; `None` result means OOM everywhere (paper's "OOM").
pub fn run_method(
    name: &str,
    model: &ModelProfile,
    cluster: &ClusterSpec,
    max_batch: usize,
) -> Option<SearchOutcome> {
    let n = cluster.n_devices;
    let base = SearchConfig { max_batch, ..Default::default() };
    match name {
        "PyTorch DDP (DP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                fixed_strategy: Some(levels(&[(Dim::Dp, n)])),
                pp_degrees: Some(vec![1]),
                space: SpaceOptions::default().no_ckpt(),
                microbatch_limit: Some(1),
                ..base
            },
        ),
        "Megatron (TP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                fixed_strategy: Some(levels(&[(Dim::Tp, n)])),
                pp_degrees: Some(vec![1]),
                space: SpaceOptions::default().no_ckpt(),
                microbatch_limit: Some(1),
                ..base
            },
        ),
        // PyTorch GPipe re-materializes activations per microbatch (its
        // documented default), so the CKPT variant stays in the space.
        "PyTorch GPipe (PP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                fixed_strategy: Some(crate::parallel::Strategy::serial(false)),
                pp_degrees: Some(vec![n.min(model.n_layers())]),
                schedule: Schedule::GPipe,
                ..base
            },
        ),
        "FSDP/ZeRO-3 (SDP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                fixed_strategy: Some(levels(&[(Dim::Sdp, n)])),
                pp_degrees: Some(vec![1]),
                space: SpaceOptions::default().no_ckpt(),
                microbatch_limit: Some(1),
                ..base
            },
        ),
        // Official suggestion: 2-way DP x 2-way TP x PP over the rest
        // (https://github.com/microsoft/Megatron-DeepSpeed pretrain_bert).
        "DeepSpeed 3D" => {
            let pp = (n / 4).max(1).min(model.n_layers());
            optimize(
                model,
                cluster,
                &SearchConfig {
                    fixed_strategy: Some(levels(&[(Dim::Dp, 2), (Dim::Tp, 2)])),
                    pp_degrees: Some(vec![pp]),
                    space: SpaceOptions::default().no_ckpt(),
                    ..base
                },
            )
        }
        // OptCNN/FlexFlow-era DP+TP auto-parallelism: no pipeline, no
        // gradient accumulation.
        "Galvatron (DP+TP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                space: SpaceOptions::default().with_dims(&[Dim::Dp, Dim::Tp]).no_ckpt(),
                pp_degrees: Some(vec![1]),
                microbatch_limit: Some(1),
                ..base
            },
        ),
        "Galvatron (DP+PP)" => optimize(
            model,
            cluster,
            &SearchConfig {
                space: SpaceOptions::default().with_dims(&[Dim::Dp]).no_ckpt(),
                ..base
            },
        ),
        "Galvatron" => optimize(
            model,
            cluster,
            &SearchConfig { space: SpaceOptions::default().no_ckpt(), ..base },
        ),
        "Galvatron-Base" => optimize(model, cluster, &base),
        "Galvatron (1F1B+Bi-obj)" => optimize_bmw(
            model,
            cluster,
            &SearchConfig { space: SpaceOptions::default().no_ckpt(), ..base },
        ),
        "Galvatron-BMW" => optimize_bmw(model, cluster, &base),
        // Alpa treats SDP as a global alternative to DP (paper §VII-D):
        // best of two restricted searches, no CKPT.
        "Alpa" => {
            let a = optimize(
                model,
                cluster,
                &SearchConfig {
                    space: SpaceOptions::default().with_dims(&[Dim::Dp, Dim::Tp]).no_ckpt(),
                    ..base.clone()
                },
            );
            let b = optimize(
                model,
                cluster,
                &SearchConfig {
                    space: SpaceOptions::default().with_dims(&[Dim::Sdp, Dim::Tp]).no_ckpt(),
                    ..base
                },
            );
            match (a, b) {
                (Some(x), Some(y)) => Some(if x.throughput() >= y.throughput() { x } else { y }),
                (x, y) => x.or(y),
            }
        }
        _ => panic!("unknown method {name:?}"),
    }
}

/// Table V ablations: fixed memory-balanced or time-balanced partitions
/// (no adjustment loop), CKPT disabled, 1F1B schedule.
pub fn run_partition_ablation(
    which: &str, // "mem" | "time"
    model: &ModelProfile,
    cluster: &ClusterSpec,
    max_batch: usize,
) -> Option<SearchOutcome> {
    let cfg = SearchConfig {
        space: SpaceOptions::default().no_ckpt(),
        max_batch,
        ..Default::default()
    };
    let n_layers = model.n_layers();
    let flops_w: Vec<f64> = model.layers.iter().map(|l| l.flops_fwd).collect();
    let mut best: Option<SearchOutcome> = None;
    let mut infeasible_streak = 0usize;
    for batch in crate::search::batch_candidates(max_batch) {
        let mut any = false;
        for pp in crate::search::base::pp_degrees(model, cluster, &cfg) {
            if pp < 2 {
                continue;
            }
            let group = cluster.n_devices / pp;
            for m in crate::search::microbatch_candidates(batch, pp) {
                let partition = match which {
                    "time" => balanced_partition(&flops_w, pp),
                    "mem" => {
                        let b_m = batch as f64 / m as f64;
                        let act_w: Vec<f64> = model
                            .layers
                            .iter()
                            .map(|l| l.act_bytes * b_m / group as f64)
                            .collect();
                        let ms_w: Vec<f64> = (0..n_layers)
                            .map(|i| (model.layers[i].params + model.extra_params(i)) * 16.0 / group as f64)
                            .collect();
                        memory_balanced_partition(&act_w, &ms_w, pp, m, cfg.schedule)
                    }
                    _ => panic!("which must be mem|time"),
                };
                if let Some((out, _)) = evaluate_partition(model, cluster, &cfg, batch, pp, m, &partition) {
                    any = true;
                    if best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                        best = Some(out);
                    }
                }
            }
        }
        if any {
            infeasible_streak = 0;
        } else if best.is_some() {
            infeasible_streak += 1;
            if infeasible_streak >= cfg.patience {
                break;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::util::GIB;

    fn setup(budget: f64) -> (ModelProfile, ClusterSpec) {
        (
            model_by_name("bert-huge-32").unwrap(),
            cluster_by_name("titan8").unwrap().with_memory_budget(budget * GIB),
        )
    }

    #[test]
    fn ddp_ooms_at_8g_like_paper() {
        // Table II: PyTorch DDP OOMs for BERT-Huge-32 at 8G and 12G.
        let (model, cluster) = setup(8.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_none());
        let (model, cluster) = setup(12.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_none());
        // ... and fits at 16G.
        let (model, cluster) = setup(16.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_some());
    }

    #[test]
    fn pure_strategies_produce_pure_plans() {
        let (model, cluster) = setup(16.0);
        let tp = run_method("Megatron (TP)", &model, &cluster, 32).unwrap();
        assert!(tp.plan.strategies.iter().all(|s| s.tp() == 8));
        let sdp = run_method("FSDP/ZeRO-3 (SDP)", &model, &cluster, 32).unwrap();
        assert!(sdp.plan.strategies.iter().all(|s| s.sdp() == 8));
        let pp = run_method("PyTorch GPipe (PP)", &model, &cluster, 32).unwrap();
        assert_eq!(pp.plan.pp, 8);
        assert!(pp.plan.strategies.iter().all(|s| s.degree() == 1));
    }

    #[test]
    fn deepspeed_3d_shape() {
        let (model, cluster) = setup(16.0);
        let out = run_method("DeepSpeed 3D", &model, &cluster, 32).unwrap();
        assert_eq!(out.plan.pp, 2);
        assert!(out.plan.strategies.iter().all(|s| s.dp() == 2 && s.tp() == 2));
    }

    #[test]
    fn galvatron_beats_pure_baselines() {
        // The paper's headline: the automatic hybrid beats every pure
        // parallelism at the same budget.
        let (model, cluster) = setup(12.0);
        let gal = run_method("Galvatron", &model, &cluster, 64)
            .map(|o| o.throughput())
            .unwrap_or(0.0);
        for pure in ["PyTorch DDP (DP)", "Megatron (TP)", "FSDP/ZeRO-3 (SDP)"] {
            let t = run_method(pure, &model, &cluster, 64)
                .map(|o| o.throughput())
                .unwrap_or(0.0);
            assert!(gal >= t * 0.999, "{pure}: galvatron {gal} < {t}");
        }
    }

    #[test]
    fn partition_ablations_run() {
        let model = model_by_name("t5-512/4-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let mem = run_partition_ablation("mem", &model, &cluster, 32);
        let time = run_partition_ablation("time", &model, &cluster, 32);
        // Memory-balanced supports at least the batch of time-balanced.
        if let (Some(m), Some(t)) = (&mem, &time) {
            assert!(m.plan.batch >= t.plan.batch / 2, "mem {} time {}", m.plan.batch, t.plan.batch);
        }
    }
}
