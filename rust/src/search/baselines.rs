//! Compat shims over the typed strategy catalog in [`crate::api`].
//!
//! Historically this module dispatched every baseline of the paper
//! (§VII-A) on magic name strings. The configurations now live on
//! [`MethodSpec`]; the name-based entry points below remain so the
//! table/figure regenerators, benches, and downstream callers keep
//! working unchanged — same names, same results.

use crate::api::{MethodSpec, PartitionPolicy};
use crate::cluster::ClusterSpec;
use crate::model::ModelProfile;
use crate::search::base::SearchOutcome;

/// All strategy names, in the row order of Table II.
pub fn method_names() -> Vec<&'static str> {
    MethodSpec::paper_table_specs().iter().map(|s| s.canonical_name()).collect()
}

/// Run a named method; `None` result means OOM everywhere (paper's "OOM").
///
/// Panics on unknown names (with a did-you-mean hint) — library users
/// should prefer [`MethodSpec::parse`] + [`MethodSpec::run`], which
/// return typed errors instead.
pub fn run_method(
    name: &str,
    model: &ModelProfile,
    cluster: &ClusterSpec,
    max_batch: usize,
) -> Option<SearchOutcome> {
    let spec = MethodSpec::parse(name).unwrap_or_else(|e| panic!("{e}"));
    spec.run(model, cluster, max_batch)
}

/// Table V ablations: fixed memory-balanced or time-balanced partitions
/// (no adjustment loop), CKPT disabled, 1F1B schedule.
pub fn run_partition_ablation(
    which: &str, // "mem" | "time"
    model: &ModelProfile,
    cluster: &ClusterSpec,
    max_batch: usize,
) -> Option<SearchOutcome> {
    let policy = match which {
        "mem" => PartitionPolicy::Memory,
        "time" => PartitionPolicy::Time,
        _ => panic!("which must be mem|time, got {which:?}"),
    };
    MethodSpec::Partition(policy).run(model, cluster, max_batch)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::util::GIB;

    fn setup(budget: f64) -> (ModelProfile, ClusterSpec) {
        (
            model_by_name("bert-huge-32").unwrap(),
            cluster_by_name("titan8").unwrap().with_memory_budget(budget * GIB),
        )
    }

    #[test]
    fn ddp_ooms_at_8g_like_paper() {
        // Table II: PyTorch DDP OOMs for BERT-Huge-32 at 8G and 12G.
        let (model, cluster) = setup(8.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_none());
        let (model, cluster) = setup(12.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_none());
        // ... and fits at 16G.
        let (model, cluster) = setup(16.0);
        assert!(run_method("PyTorch DDP (DP)", &model, &cluster, 64).is_some());
    }

    #[test]
    fn pure_strategies_produce_pure_plans() {
        let (model, cluster) = setup(16.0);
        let tp = run_method("Megatron (TP)", &model, &cluster, 32).unwrap();
        assert!(tp.plan.strategies.iter().all(|s| s.tp() == 8));
        let sdp = run_method("FSDP/ZeRO-3 (SDP)", &model, &cluster, 32).unwrap();
        assert!(sdp.plan.strategies.iter().all(|s| s.sdp() == 8));
        let pp = run_method("PyTorch GPipe (PP)", &model, &cluster, 32).unwrap();
        assert_eq!(pp.plan.pp, 8);
        assert!(pp.plan.strategies.iter().all(|s| s.degree() == 1));
    }

    #[test]
    fn deepspeed_3d_shape() {
        let (model, cluster) = setup(16.0);
        let out = run_method("DeepSpeed 3D", &model, &cluster, 32).unwrap();
        assert_eq!(out.plan.pp, 2);
        assert!(out.plan.strategies.iter().all(|s| s.dp() == 2 && s.tp() == 2));
    }

    #[test]
    fn galvatron_beats_pure_baselines() {
        // The paper's headline: the automatic hybrid beats every pure
        // parallelism at the same budget.
        let (model, cluster) = setup(12.0);
        let gal = run_method("Galvatron", &model, &cluster, 64)
            .map(|o| o.throughput())
            .unwrap_or(0.0);
        for pure in ["PyTorch DDP (DP)", "Megatron (TP)", "FSDP/ZeRO-3 (SDP)"] {
            let t = run_method(pure, &model, &cluster, 64)
                .map(|o| o.throughput())
                .unwrap_or(0.0);
            assert!(gal >= t * 0.999, "{pure}: galvatron {gal} < {t}");
        }
    }

    #[test]
    fn shim_matches_typed_catalog() {
        // The name shim and the typed API must be the same planner.
        let (model, cluster) = setup(12.0);
        let by_name = run_method("Galvatron-BMW", &model, &cluster, 32).unwrap();
        let by_spec = MethodSpec::Bmw { ckpt: true }.run(&model, &cluster, 32).unwrap();
        assert_eq!(by_name.plan, by_spec.plan);
        assert_eq!(by_name.throughput(), by_spec.throughput());
    }

    #[test]
    #[should_panic(expected = "unknown method")]
    fn unknown_method_panics_with_hint() {
        let (model, cluster) = setup(16.0);
        run_method("Galvatron-BWM", &model, &cluster, 8);
    }

    #[test]
    fn partition_ablations_run() {
        let model = model_by_name("t5-512/4-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let mem = run_partition_ablation("mem", &model, &cluster, 32);
        let time = run_partition_ablation("time", &model, &cluster, 32);
        // Memory-balanced supports at least the batch of time-balanced.
        if let (Some(m), Some(t)) = (&mem, &time) {
            assert!(m.plan.batch >= t.plan.batch / 2, "mem {} time {}", m.plan.batch, t.plan.batch);
        }
    }
}
