//! Galvatron-BMW: bi-objective optimization of pipeline workload balance
//! (paper §IV-B, Algorithm 2, Appendix B).
//!
//! Starting from the memory-balanced partition p_m, iteratively cut the
//! workload of the slowest stage by moving its boundary layer to an
//! adjacent stage, accepting a new partition p' only if
//!   (1) its max stage time does not exceed the previous maximum,
//!   (2) its stage memories fit the budget,
//!   (3) its stage memories do not exceed the max stage memory of the
//!       time-balanced partition p_t,
//! which guarantees the Eq. 7/8 sandwich: alpha_t(p_m) <= alpha_t(p') <=
//! alpha_t(p_t) and alpha_m(p_t) <= alpha_m(p') <= alpha_m(p_m).

use crate::cluster::ClusterSpec;
use crate::cost::pipeline::Schedule;
use crate::model::ModelProfile;
use crate::parallel::memory::stage_peak_memory;
use crate::util::GIB;

use super::base::{LayerDiag, SearchConfig, SearchOutcome};
use super::engine::{CellAlgo, SearchEngine, SearchTrace};
use super::partition::{even_partition, min_bottleneck_partition};

/// Memory-balanced partition p_m with 1F1B live-microbatch awareness:
/// stage s of P keeps (P - s) microbatches of activations live, so the
/// greedy sweep weighs layer activations by the stage's live count.
pub fn memory_balanced_partition(
    act_weights: &[f64],
    ms_weights: &[f64],
    stages: usize,
    microbatches: usize,
    schedule: Schedule,
) -> Vec<usize> {
    let n = act_weights.len();
    assert_eq!(ms_weights.len(), n);
    assert!(stages >= 1 && stages <= n);
    if stages == 1 {
        return vec![n];
    }
    // Binary search the memory bottleneck.
    let total_hi: f64 = (0..n)
        .map(|i| act_weights[i] * stages as f64 + ms_weights[i])
        .sum();
    let (mut lo, mut hi) = (0.0f64, total_hi);
    let feasible = |cap: f64| -> Option<Vec<usize>> {
        let mut counts = Vec::with_capacity(stages);
        let mut i = 0usize;
        for s in 0..stages {
            let live = schedule.live_microbatches(s, stages, microbatches) as f64;
            let remaining_stages = stages - s - 1;
            let mut acc = 0.0;
            let mut taken = 0usize;
            while i < n {
                // Leave at least one layer per remaining stage.
                if n - i <= remaining_stages {
                    break;
                }
                let w = act_weights[i] * live + ms_weights[i];
                if taken > 0 && acc + w > cap {
                    break;
                }
                acc += w;
                taken += 1;
                i += 1;
            }
            if taken == 0 {
                return None;
            }
            counts.push(taken);
        }
        if i == n {
            Some(counts)
        } else {
            None
        }
    };
    let mut best = None;
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if let Some(c) = feasible(mid) {
            best = Some(c);
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let counts = best.unwrap_or_else(|| even_partition(n, stages));
    debug_assert_eq!(counts.iter().sum::<usize>(), n);
    counts
}

/// Memory-balanced partition against a *per-stage budget vector*: stage
/// `s` may hold weight in proportion to `budgets[s]` (its assigned
/// island's memory capacity), so the optimization balances *utilization*
/// `weight_s / budgets[s]` instead of raw bytes — the Eq. 7/8 p_m
/// re-derived for heterogeneous clusters. Because the per-layer weight
/// depends on the stage it lands in (live multiplier AND budget), the
/// bottleneck is minimized exactly with an O(P·n²) interval DP rather
/// than the homogeneous bisection (whose greedy is only correct for
/// uniform allowances). A uniform budget vector delegates to
/// [`memory_balanced_partition`] bit-for-bit, keeping the homogeneous
/// planner byte-identical.
pub fn memory_balanced_partition_budgeted(
    act_weights: &[f64],
    ms_weights: &[f64],
    stages: usize,
    microbatches: usize,
    schedule: Schedule,
    budgets: &[f64],
) -> Vec<usize> {
    assert_eq!(budgets.len(), stages);
    if budgets.windows(2).all(|w| w[0] == w[1]) {
        return memory_balanced_partition(act_weights, ms_weights, stages, microbatches, schedule);
    }
    let n = act_weights.len();
    assert_eq!(ms_weights.len(), n);
    assert!(stages >= 1 && stages <= n);
    let live: Vec<f64> = (0..stages)
        .map(|s| schedule.live_microbatches(s, stages, microbatches) as f64)
        .collect();
    let stage_cost = move |s: usize, j: usize, i: usize, pa: &[f64], pm: &[f64]| -> f64 {
        ((pa[i] - pa[j]) * live[s] + (pm[i] - pm[j])) / budgets[s]
    };
    min_bottleneck_partition(n, stages, act_weights, ms_weights, &stage_cost)
}

/// Proxy stage times/memories for a candidate partition, reusing the
/// per-layer diagnostics from the most recent full search (the validation
/// step of Algorithm 2 line 14 — cheap, no DP re-run). Public so the
/// property suite can drive the Eq. 7/8 sandwich directly.
pub fn proxy_stage_stats(
    diags: &[LayerDiag],
    partition: &[usize],
    microbatches: usize,
    schedule: Schedule,
) -> (Vec<f64>, Vec<f64>) {
    let p = partition.len();
    let mut times = Vec::with_capacity(p);
    let mut mems = Vec::with_capacity(p);
    let mut start = 0usize;
    for (s, &c) in partition.iter().enumerate() {
        let t: f64 = diags[start..start + c].iter().map(|d| d.time).sum();
        let live = schedule.live_microbatches(s, p, microbatches);
        let layer_mems: Vec<_> = diags[start..start + c].iter().map(|d| d.mem).collect();
        times.push(t);
        mems.push(stage_peak_memory(&layer_mems, live));
        start += c;
    }
    (times, mems)
}

/// One adjustment step: move a boundary layer out of the slowest stage.
/// Returns candidate partitions (shrink-left and shrink-right variants).
/// Public so the property suite can replay Algorithm 2's loop.
pub fn adjust_candidates(partition: &[usize], slowest: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    if partition[slowest] <= 1 {
        return out;
    }
    if slowest > 0 {
        // Give the slowest stage's first layer to the previous stage.
        let mut p = partition.to_vec();
        p[slowest] -= 1;
        p[slowest - 1] += 1;
        out.push(p);
    }
    if slowest + 1 < partition.len() {
        // Give the slowest stage's last layer to the next stage.
        let mut p = partition.to_vec();
        p[slowest] -= 1;
        p[slowest + 1] += 1;
        out.push(p);
    }
    out
}

/// Galvatron-BMW (Algorithm 2): Galvatron-Base plus bi-objective pipeline
/// partition optimization. The (batch × PP) sweep and the per-cell
/// boundary-adjustment queue run on the parallel memoized engine
/// (`search::engine::cells::eval_bmw_cell`).
pub fn optimize_bmw(model: &ModelProfile, cluster: &ClusterSpec, cfg: &SearchConfig) -> Option<SearchOutcome> {
    optimize_bmw_traced(model, cluster, cfg).0
}

/// [`optimize_bmw`] plus the engine's structured [`SearchTrace`].
pub fn optimize_bmw_traced(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
) -> (Option<SearchOutcome>, SearchTrace) {
    SearchEngine::new(model, cluster, cfg, CellAlgo::Bmw).run()
}

/// Report the two balance degrees of an outcome (Eq. 6), for Table V.
pub fn balance_degrees(out: &SearchOutcome) -> (f64, f64) {
    (out.cost.alpha_t, out.cost.alpha_m)
}

/// Pretty string for a partition, e.g. "[14,18]".
pub fn partition_str(p: &[usize]) -> String {
    format!(
        "[{}]",
        p.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    )
}

/// Memory budget helper for tables.
pub fn gb(bytes: f64) -> f64 {
    bytes / GIB
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;

    #[test]
    fn memory_balanced_accounts_for_1f1b_live() {
        // Uniform layers, 4 stages, many microbatches: stage 0 holds 4
        // live microbatches, stage 3 holds 1 -> deeper stages get MORE
        // layers (paper Fig. 4 memory-balanced pipelines).
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        let p = memory_balanced_partition(&act, &ms, 4, 8, Schedule::OneFOneB);
        assert_eq!(p.iter().sum::<usize>(), 32);
        assert!(
            p[3] > p[0],
            "deeper stages must take more layers under 1F1B: {p:?}"
        );
    }

    #[test]
    fn memory_balanced_gpipe_is_even_for_uniform() {
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        let p = memory_balanced_partition(&act, &ms, 4, 8, Schedule::GPipe);
        assert_eq!(p, vec![8, 8, 8, 8]);
    }

    #[test]
    fn budgeted_partition_uniform_budgets_delegate() {
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        for m in [1usize, 4, 8] {
            for sched in [Schedule::OneFOneB, Schedule::GPipe] {
                let plain = memory_balanced_partition(&act, &ms, 4, m, sched);
                let budgeted = memory_balanced_partition_budgeted(
                    &act,
                    &ms,
                    4,
                    m,
                    sched,
                    &[16.0 * GIB; 4],
                );
                assert_eq!(plain, budgeted);
            }
        }
    }

    #[test]
    fn budgeted_partition_loads_large_budget_stages() {
        // GPipe (uniform live counts) so only the budgets differ: the
        // 80G stage must take more layers than a 24G stage.
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        let budgets = [24.0 * GIB, 80.0 * GIB];
        let p = memory_balanced_partition_budgeted(&act, &ms, 2, 4, Schedule::GPipe, &budgets);
        assert_eq!(p.iter().sum::<usize>(), 32);
        assert!(p[1] > p[0], "80G stage must hold more layers: {p:?}");
    }

    #[test]
    fn adjustment_candidates_move_one_layer() {
        let cands = adjust_candidates(&[8, 8, 8, 8], 1);
        assert_eq!(cands.len(), 2);
        assert!(cands.contains(&vec![9, 7, 8, 8]));
        assert!(cands.contains(&vec![8, 7, 9, 8]));
        assert!(adjust_candidates(&[1, 31], 0).is_empty());
    }

    #[test]
    fn bmw_beats_or_matches_base() {
        let model = model_by_name("t5-512/4-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB);
        let cfg = SearchConfig { max_batch: 32, ..Default::default() };
        let base = super::super::base::optimize(&model, &cluster, &cfg).map(|o| o.throughput());
        let bmw = optimize_bmw(&model, &cluster, &cfg).map(|o| o.throughput());
        match (base, bmw) {
            (Some(b), Some(w)) => assert!(w >= b * 0.98, "bmw {w} << base {b}"),
            (None, _) => {}
            (Some(b), None) => panic!("bmw lost feasibility that base had ({b})"),
        }
    }

    #[test]
    fn bmw_outcome_valid() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(12.0 * GIB);
        let cfg = SearchConfig { max_batch: 32, ..Default::default() };
        if let Some(out) = optimize_bmw(&model, &cluster, &cfg) {
            out.plan.validate(32, 8).unwrap();
            assert!(out.cost.feasible);
            let (at, am) = balance_degrees(&out);
            let bound = 1.0 - 1.0 / out.plan.pp as f64;
            assert!(at >= 0.0 && at <= bound + 1e-9);
            assert!(am >= 0.0 && am <= bound + 1e-9);
        }
    }
}
