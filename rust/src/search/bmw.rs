//! Galvatron-BMW: bi-objective optimization of pipeline workload balance
//! (paper §IV-B, Algorithm 2, Appendix B).
//!
//! Starting from the memory-balanced partition p_m, iteratively cut the
//! workload of the slowest stage by moving its boundary layer to an
//! adjacent stage, accepting a new partition p' only if
//!   (1) its max stage time does not exceed the previous maximum,
//!   (2) its stage memories fit the budget,
//!   (3) its stage memories do not exceed the max stage memory of the
//!       time-balanced partition p_t,
//! which guarantees the Eq. 7/8 sandwich: alpha_t(p_m) <= alpha_t(p') <=
//! alpha_t(p_t) and alpha_m(p_t) <= alpha_m(p') <= alpha_m(p_m).

use std::collections::VecDeque;

use crate::cluster::ClusterSpec;
use crate::cost::pipeline::Schedule;
use crate::model::ModelProfile;
use crate::parallel::memory::stage_peak_memory;
use crate::util::GIB;

use super::base::{evaluate_partition, pp_degrees, LayerDiag, SearchConfig, SearchOutcome};
use super::partition::{balanced_partition, even_partition};

/// Memory-balanced partition p_m with 1F1B live-microbatch awareness:
/// stage s of P keeps (P - s) microbatches of activations live, so the
/// greedy sweep weighs layer activations by the stage's live count.
pub fn memory_balanced_partition(
    act_weights: &[f64],
    ms_weights: &[f64],
    stages: usize,
    microbatches: usize,
    schedule: Schedule,
) -> Vec<usize> {
    let n = act_weights.len();
    assert_eq!(ms_weights.len(), n);
    assert!(stages >= 1 && stages <= n);
    if stages == 1 {
        return vec![n];
    }
    // Binary search the memory bottleneck.
    let stage_weight = |s: usize, range: std::ops::Range<usize>| -> f64 {
        let live = schedule.live_microbatches(s, stages, microbatches) as f64;
        range
            .map(|i| act_weights[i] * live + ms_weights[i])
            .sum()
    };
    let total_hi: f64 = (0..n)
        .map(|i| act_weights[i] * stages as f64 + ms_weights[i])
        .sum();
    let (mut lo, mut hi) = (0.0f64, total_hi);
    let feasible = |cap: f64| -> Option<Vec<usize>> {
        let mut counts = Vec::with_capacity(stages);
        let mut i = 0usize;
        for s in 0..stages {
            let live = schedule.live_microbatches(s, stages, microbatches) as f64;
            let remaining_stages = stages - s - 1;
            let mut acc = 0.0;
            let mut taken = 0usize;
            while i < n {
                // Leave at least one layer per remaining stage.
                if n - i <= remaining_stages {
                    break;
                }
                let w = act_weights[i] * live + ms_weights[i];
                if taken > 0 && acc + w > cap {
                    break;
                }
                acc += w;
                taken += 1;
                i += 1;
            }
            if taken == 0 {
                return None;
            }
            counts.push(taken);
        }
        if i == n {
            Some(counts)
        } else {
            None
        }
    };
    let mut best = None;
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if let Some(c) = feasible(mid) {
            best = Some(c);
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let counts = best.unwrap_or_else(|| even_partition(n, stages));
    debug_assert_eq!(counts.iter().sum::<usize>(), n);
    // Silence unused warning in release builds.
    let _ = stage_weight;
    counts
}

/// Proxy stage times/memories for a candidate partition, reusing the
/// per-layer diagnostics from the most recent full search (the validation
/// step of Algorithm 2 line 14 — cheap, no DP re-run).
fn proxy_stage_stats(
    diags: &[LayerDiag],
    partition: &[usize],
    microbatches: usize,
    schedule: Schedule,
) -> (Vec<f64>, Vec<f64>) {
    let p = partition.len();
    let mut times = Vec::with_capacity(p);
    let mut mems = Vec::with_capacity(p);
    let mut start = 0usize;
    for (s, &c) in partition.iter().enumerate() {
        let t: f64 = diags[start..start + c].iter().map(|d| d.time).sum();
        let live = schedule.live_microbatches(s, p, microbatches);
        let layer_mems: Vec<_> = diags[start..start + c].iter().map(|d| d.mem).collect();
        times.push(t);
        mems.push(stage_peak_memory(&layer_mems, live));
        start += c;
    }
    (times, mems)
}

/// One adjustment step: move a boundary layer out of the slowest stage.
/// Returns candidate partitions (shrink-left and shrink-right variants).
fn adjust_candidates(partition: &[usize], slowest: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    if partition[slowest] <= 1 {
        return out;
    }
    if slowest > 0 {
        // Give the slowest stage's first layer to the previous stage.
        let mut p = partition.to_vec();
        p[slowest] -= 1;
        p[slowest - 1] += 1;
        out.push(p);
    }
    if slowest + 1 < partition.len() {
        // Give the slowest stage's last layer to the next stage.
        let mut p = partition.to_vec();
        p[slowest] -= 1;
        p[slowest + 1] += 1;
        out.push(p);
    }
    out
}

/// Galvatron-BMW (Algorithm 2): Galvatron-Base plus bi-objective pipeline
/// partition optimization.
pub fn optimize_bmw(model: &ModelProfile, cluster: &ClusterSpec, cfg: &SearchConfig) -> Option<SearchOutcome> {
    let mut best: Option<SearchOutcome> = None;
    let mut infeasible_streak = 0usize;
    let n_layers = model.n_layers();

    let flops_w: Vec<f64> = model.layers.iter().map(|l| l.flops_fwd).collect();

    for batch in super::batch_candidates(cfg.max_batch) {
        let mut any_feasible = false;
        for pp in pp_degrees(model, cluster, cfg) {
            if pp < 2 && cfg.pp_degrees.is_none() {
                // Algorithm 2 line 5 iterates P in {2,4,...}; P=1 has no
                // pipeline to balance — still evaluate it via the even path
                // so pure intra-stage plans are not lost.
                for m in super::microbatch_candidates(batch, 1) {
                    if let Some((out, _)) =
                        evaluate_partition(model, cluster, cfg, batch, 1, m, &[n_layers])
                    {
                        any_feasible = true;
                        if best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                            best = Some(out);
                        }
                    }
                }
                continue;
            }
            let group = cluster.n_devices / pp;
            for m in super::microbatch_candidates(batch, pp) {
                let b_m = batch as f64 / m as f64;
                // Strategy-agnostic per-layer weights for the initial
                // partitions (Strategy_Init: memory under an even split of
                // states across the group).
                let act_w: Vec<f64> = model
                    .layers
                    .iter()
                    .map(|l| l.act_bytes * b_m / group as f64)
                    .collect();
                let ms_w: Vec<f64> = (0..n_layers)
                    .map(|i| {
                        (model.layers[i].params + model.extra_params(i)) * 16.0 / group as f64
                    })
                    .collect();
                let p_m = memory_balanced_partition(&act_w, &ms_w, pp, m, cfg.schedule);
                let p_t = balanced_partition(&flops_w, pp);

                let mut queue: VecDeque<Vec<usize>> = VecDeque::new();
                let mut visited: Vec<Vec<usize>> = Vec::new();
                // Seed with p_m (Algorithm 2 line 7); also evaluate the
                // even and time-balanced partitions so BMW's answer is
                // never worse than Galvatron-Base's for the same (B,P,m).
                queue.push_back(p_m.clone());
                queue.push_back(even_partition(n_layers, pp));
                queue.push_back(p_t.clone());
                let max_iters = 4 * n_layers;
                let mut iters = 0usize;
                let mut local_best_tp = f64::NEG_INFINITY;
                let mut stale = 0usize;

                while let Some(part) = queue.pop_front() {
                    iters += 1;
                    if iters > max_iters {
                        break;
                    }
                    if visited.contains(&part) {
                        continue;
                    }
                    visited.push(part.clone());
                    let Some((out, diags)) =
                        evaluate_partition(model, cluster, cfg, batch, pp, m, &part)
                    else {
                        continue;
                    };
                    any_feasible = true;
                    if out.throughput() > local_best_tp {
                        local_best_tp = out.throughput();
                        stale = 0;
                    } else {
                        stale += 1;
                        if stale > 6 {
                            break;
                        }
                    }
                    if best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                        best = Some(out.clone());
                    }

                    // Adjustment (Algorithm 2 line 13-15).
                    let (times, _mems) = proxy_stage_stats(&diags, &part, m, cfg.schedule);
                    let c_max = times.iter().cloned().fold(0.0, f64::max);
                    let slowest = times
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap();
                    // Validation limit (3): max stage memory under p_t.
                    let (_, mems_pt) = proxy_stage_stats(&diags, &p_t, m, cfg.schedule);
                    let mem_cap_pt = mems_pt.iter().cloned().fold(0.0, f64::max);
                    for cand in adjust_candidates(&part, slowest) {
                        if visited.contains(&cand) {
                            continue;
                        }
                        let (t2, m2) = proxy_stage_stats(&diags, &cand, m, cfg.schedule);
                        let cond1 = t2.iter().cloned().fold(0.0, f64::max) <= c_max + 1e-12;
                        let cond2 = m2.iter().all(|&x| x <= cluster.gpu.mem_bytes);
                        let cond3 = m2.iter().all(|&x| x <= mem_cap_pt.max(cluster.gpu.mem_bytes));
                        if cond1 && cond2 && cond3 {
                            queue.push_back(cand);
                        }
                    }
                }
            }
        }
        if any_feasible {
            infeasible_streak = 0;
        } else if best.is_some() {
            infeasible_streak += 1;
            if infeasible_streak >= cfg.patience {
                break;
            }
        }
    }
    best
}

/// Report the two balance degrees of an outcome (Eq. 6), for Table V.
pub fn balance_degrees(out: &SearchOutcome) -> (f64, f64) {
    (out.cost.alpha_t, out.cost.alpha_m)
}

/// Pretty string for a partition, e.g. "[14,18]".
pub fn partition_str(p: &[usize]) -> String {
    format!(
        "[{}]",
        p.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
    )
}

/// Memory budget helper for tables.
pub fn gb(bytes: f64) -> f64 {
    bytes / GIB
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;

    #[test]
    fn memory_balanced_accounts_for_1f1b_live() {
        // Uniform layers, 4 stages, many microbatches: stage 0 holds 4
        // live microbatches, stage 3 holds 1 -> deeper stages get MORE
        // layers (paper Fig. 4 memory-balanced pipelines).
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        let p = memory_balanced_partition(&act, &ms, 4, 8, Schedule::OneFOneB);
        assert_eq!(p.iter().sum::<usize>(), 32);
        assert!(
            p[3] > p[0],
            "deeper stages must take more layers under 1F1B: {p:?}"
        );
    }

    #[test]
    fn memory_balanced_gpipe_is_even_for_uniform() {
        let act = vec![100.0; 32];
        let ms = vec![1.0; 32];
        let p = memory_balanced_partition(&act, &ms, 4, 8, Schedule::GPipe);
        assert_eq!(p, vec![8, 8, 8, 8]);
    }

    #[test]
    fn adjustment_candidates_move_one_layer() {
        let cands = adjust_candidates(&[8, 8, 8, 8], 1);
        assert_eq!(cands.len(), 2);
        assert!(cands.contains(&vec![9, 7, 8, 8]));
        assert!(cands.contains(&vec![8, 7, 9, 8]));
        assert!(adjust_candidates(&[1, 31], 0).is_empty());
    }

    #[test]
    fn bmw_beats_or_matches_base() {
        let model = model_by_name("t5-512/4-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB);
        let cfg = SearchConfig { max_batch: 32, ..Default::default() };
        let base = super::super::base::optimize(&model, &cluster, &cfg).map(|o| o.throughput());
        let bmw = optimize_bmw(&model, &cluster, &cfg).map(|o| o.throughput());
        match (base, bmw) {
            (Some(b), Some(w)) => assert!(w >= b * 0.98, "bmw {w} << base {b}"),
            (None, _) => {}
            (Some(b), None) => panic!("bmw lost feasibility that base had ({b})"),
        }
    }

    #[test]
    fn bmw_outcome_valid() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(12.0 * GIB);
        let cfg = SearchConfig { max_batch: 32, ..Default::default() };
        if let Some(out) = optimize_bmw(&model, &cluster, &cfg) {
            out.plan.validate(32, 8).unwrap();
            assert!(out.cost.feasible);
            let (at, am) = balance_degrees(&out);
            let bound = 1.0 - 1.0 / out.plan.pp as f64;
            assert!(at >= 0.0 && at <= bound + 1e-9);
            assert!(am >= 0.0 && am <= bound + 1e-9);
        }
    }
}
