//! Pipeline partition construction: even, memory-balanced (p_m) and
//! time-balanced (p_t) contiguous partitions (paper §IV-B).
//!
//! Balanced partitions minimize the maximum stage weight over contiguous
//! layer chunks — solved exactly with binary search over the bottleneck +
//! a greedy feasibility sweep (classic linear-partitioning).

/// Split `n_layers` into `stages` contiguous chunks as evenly as possible.
pub fn even_partition(n_layers: usize, stages: usize) -> Vec<usize> {
    assert!(stages >= 1 && stages <= n_layers);
    let base = n_layers / stages;
    let rem = n_layers % stages;
    (0..stages).map(|i| base + usize::from(i < rem)).collect()
}

/// Contiguous partition of `weights` into `stages` parts minimizing the
/// maximum part sum. Returns layer counts per stage (every stage >= 1).
pub fn balanced_partition(weights: &[f64], stages: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(stages >= 1 && stages <= n);
    if stages == 1 {
        return vec![n];
    }
    let total: f64 = weights.iter().sum();
    let maxw = weights.iter().cloned().fold(0.0, f64::max);
    let (mut lo, mut hi) = (maxw, total);
    // Binary search the bottleneck to within a tiny relative tolerance.
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if feasible(weights, stages, mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // Greedy fill at the found bottleneck, then pad so every stage is
    // non-empty (move boundaries back from the right).
    let mut cuts = greedy_cuts(weights, stages, hi * (1.0 + 1e-12));
    while cuts.len() < stages - 1 {
        // Fewer parts needed than allowed: split the largest part.
        let counts = cuts_to_counts(&cuts, n);
        let (mut best, mut best_i) = (0usize, 0usize);
        let mut start = 0;
        for (i, c) in counts.iter().enumerate() {
            if *c > best {
                best = *c;
                best_i = i;
            }
            start += c;
        }
        let _ = start;
        let part_start: usize = counts[..best_i].iter().sum();
        cuts.push(part_start + counts[best_i] / 2);
        cuts.sort_unstable();
    }
    cuts_to_counts(&cuts, n)
}

/// Contiguous partition of `weights` into `stages` parts minimizing the
/// maximum *normalized* part time `part_sum / rates[s]` — the
/// time-balanced partition p_t on a heterogeneous pipeline whose stage `s`
/// runs at `rates[s]` FLOP/s. Uniform rates delegate to
/// [`balanced_partition`] bit-for-bit (the homogeneous degenerate case);
/// otherwise the bottleneck is minimized exactly by
/// [`min_bottleneck_partition`] (the homogeneous greedy is only correct
/// for uniform stage allowances).
pub fn rated_balanced_partition(weights: &[f64], stages: usize, rates: &[f64]) -> Vec<usize> {
    assert_eq!(rates.len(), stages);
    if rates.windows(2).all(|w| w[0] == w[1]) {
        return balanced_partition(weights, stages);
    }
    let n = weights.len();
    let zeros = vec![0.0f64; n];
    let stage_cost = move |s: usize, j: usize, i: usize, pw: &[f64], _pz: &[f64]| -> f64 {
        (pw[i] - pw[j]) / rates[s]
    };
    min_bottleneck_partition(n, stages, weights, &zeros, &stage_cost)
}

/// Exact min-bottleneck contiguous partition of `n` layers into `stages`
/// non-empty parts, where the cost of layers `[j, i)` on stage `s` is
/// `stage_cost(s, j, i, prefix_a, prefix_b)` over prefix sums of the two
/// weight vectors (stage-dependent costs — per-island budgets or FLOP
/// rates — need this interval DP; the classic bisection+greedy above is
/// only optimal when every stage shares one allowance). O(stages·n²);
/// ties resolve to the earliest cut, so results are deterministic.
pub fn min_bottleneck_partition(
    n: usize,
    stages: usize,
    weights_a: &[f64],
    weights_b: &[f64],
    stage_cost: &dyn Fn(usize, usize, usize, &[f64], &[f64]) -> f64,
) -> Vec<usize> {
    assert!(stages >= 1 && stages <= n);
    if stages == 1 {
        return vec![n];
    }
    let mut pa = vec![0.0f64; n + 1];
    let mut pb = vec![0.0f64; n + 1];
    for i in 0..n {
        pa[i + 1] = pa[i] + weights_a[i];
        pb[i + 1] = pb[i] + weights_b[i];
    }
    const INF: f64 = f64::INFINITY;
    // dp[i]: min bottleneck covering the first i layers with the stages
    // processed so far; parent[s][i]: the cut j achieving it at stage s.
    let mut dp = vec![INF; n + 1];
    let mut parent = vec![vec![0usize; n + 1]; stages];
    // Stage 0 covers [0, i), leaving at least one layer per later stage.
    for i in 1..=(n - (stages - 1)) {
        dp[i] = stage_cost(0, 0, i, &pa, &pb);
    }
    for s in 1..stages {
        let mut next = vec![INF; n + 1];
        let remaining = stages - 1 - s;
        // Stage s ends at i: >= s layers before it, `remaining` after it.
        for i in (s + 1)..=(n - remaining) {
            let mut best = INF;
            let mut best_j = 0usize;
            for j in s..i {
                if !dp[j].is_finite() {
                    continue;
                }
                let c = dp[j].max(stage_cost(s, j, i, &pa, &pb));
                if c < best {
                    best = c;
                    best_j = j;
                }
            }
            next[i] = best;
            parent[s][i] = best_j;
        }
        dp = next;
    }
    // Backtrack cuts from the full cover.
    let mut counts = vec![0usize; stages];
    let mut i = n;
    for s in (1..stages).rev() {
        let j = parent[s][i];
        counts[s] = i - j;
        i = j;
    }
    counts[0] = i;
    debug_assert_eq!(counts.iter().sum::<usize>(), n);
    debug_assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
    counts
}

/// Can `weights` be split into `stages` contiguous parts each <= cap?
fn feasible(weights: &[f64], stages: usize, cap: f64) -> bool {
    let mut parts = 1;
    let mut acc: f64 = 0.0;
    for &w in weights {
        if w > cap {
            return false;
        }
        if acc + w > cap {
            parts += 1;
            acc = w;
            if parts > stages {
                return false;
            }
        } else {
            acc += w;
        }
    }
    true
}

fn greedy_cuts(weights: &[f64], stages: usize, cap: f64) -> Vec<usize> {
    let mut cuts = Vec::new();
    let mut acc = 0.0;
    let n = weights.len();
    for (i, &w) in weights.iter().enumerate() {
        if acc + w > cap && i > 0 {
            cuts.push(i);
            acc = w;
        } else {
            acc += w;
        }
        // Never leave fewer layers than stages remaining.
        if cuts.len() == stages - 1 {
            break;
        }
        let remaining_stages = stages - 1 - cuts.len();
        let remaining_layers = n - (i + 1);
        if remaining_layers == remaining_stages && i + 1 < n {
            // Force cuts so that later stages get >= 1 layer each.
            for c in (i + 1)..n {
                cuts.push(c);
                if cuts.len() == stages - 1 {
                    break;
                }
            }
            break;
        }
    }
    cuts.truncate(stages - 1);
    cuts
}

fn cuts_to_counts(cuts: &[usize], n: usize) -> Vec<usize> {
    let mut counts = Vec::with_capacity(cuts.len() + 1);
    let mut prev = 0;
    for &c in cuts {
        counts.push(c - prev);
        prev = c;
    }
    counts.push(n - prev);
    counts
}

/// Max part sum of a partition (for alpha computations / tests).
pub fn max_stage_weight(weights: &[f64], counts: &[usize]) -> f64 {
    let mut best: f64 = 0.0;
    let mut i = 0;
    for &c in counts {
        let s: f64 = weights[i..i + c].iter().sum();
        best = best.max(s);
        i += c;
    }
    best
}

/// Balance degree alpha = 1 - max/sum (Eq. 6 numerator shape).
pub fn balance_degree(weights: &[f64], counts: &[usize]) -> f64 {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    1.0 - max_stage_weight(weights, counts) / total
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn even_splits() {
        assert_eq!(even_partition(32, 4), vec![8, 8, 8, 8]);
        assert_eq!(even_partition(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(even_partition(4, 4), vec![1, 1, 1, 1]);
    }

    #[test]
    fn balanced_uniform_equals_even() {
        let w = vec![1.0; 32];
        assert_eq!(balanced_partition(&w, 4), vec![8, 8, 8, 8]);
    }

    #[test]
    fn balanced_heterogeneous() {
        // Heavy head: [8,1,1,1,1,1,1,1] into 2 -> [1,7] puts the heavy
        // layer alone.
        let w = vec![8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let counts = balanced_partition(&w, 2);
        assert_eq!(counts.iter().sum::<usize>(), 8);
        assert_eq!(max_stage_weight(&w, &counts), 8.0);
    }

    #[test]
    fn every_stage_nonempty_property() {
        // Property test: random weights, random stage counts.
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let n = rng.range(4, 40) as usize;
            let stages = rng.range(2, 8.min(n as i64)) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0 + 0.01).collect();
            let counts = balanced_partition(&w, stages);
            assert_eq!(counts.len(), stages);
            assert_eq!(counts.iter().sum::<usize>(), n);
            assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        }
    }

    #[test]
    fn balanced_beats_even_on_skewed_weights() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let n = 24;
            let w: Vec<f64> = (0..n).map(|i| if i < 4 { 20.0 } else { rng.f64() + 1.0 }).collect();
            let bal = balanced_partition(&w, 4);
            let even = even_partition(n, 4);
            assert!(
                max_stage_weight(&w, &bal) <= max_stage_weight(&w, &even) + 1e-9,
                "bal {bal:?} even {even:?}"
            );
        }
    }

    #[test]
    fn optimality_vs_bruteforce_small() {
        // Exhaustive check on small instances.
        let mut rng = Rng::new(13);
        for _ in 0..60 {
            let n = rng.range(3, 9) as usize;
            let stages = rng.range(2, n as i64) as usize;
            let w: Vec<f64> = (0..n).map(|_| (rng.below(9) + 1) as f64).collect();
            let got = max_stage_weight(&w, &balanced_partition(&w, stages));
            let best = brute_best(&w, stages);
            assert!((got - best).abs() < 1e-6, "w={w:?} stages={stages} got={got} best={best}");
        }
    }

    fn brute_best(w: &[f64], stages: usize) -> f64 {
        fn rec(w: &[f64], stages: usize) -> f64 {
            if stages == 1 {
                return w.iter().sum();
            }
            let mut best = f64::INFINITY;
            for first in 1..=(w.len() - stages + 1) {
                let head: f64 = w[..first].iter().sum();
                let rest = rec(&w[first..], stages - 1);
                best = best.min(head.max(rest));
            }
            best
        }
        rec(w, stages)
    }

    #[test]
    fn rated_uniform_delegates_to_balanced() {
        let mut rng = Rng::new(21);
        for _ in 0..40 {
            let n = rng.range(4, 24) as usize;
            let stages = rng.range(2, 6.min(n as i64)) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 5.0 + 0.1).collect();
            let rates = vec![3.0e12; stages];
            assert_eq!(
                rated_balanced_partition(&w, stages, &rates),
                balanced_partition(&w, stages)
            );
        }
    }

    #[test]
    fn rated_partition_favors_fast_stages() {
        // Uniform layers, stage 1 is 4x faster: it must take more layers.
        let w = vec![1.0; 16];
        let counts = rated_balanced_partition(&w, 2, &[1.0, 4.0]);
        assert_eq!(counts.iter().sum::<usize>(), 16);
        assert!(counts[1] > counts[0], "{counts:?}");
        // Normalized bottleneck beats the even split's.
        let norm_max = |c: &[usize], rates: &[f64]| {
            let mut best: f64 = 0.0;
            let mut i = 0;
            for (s, &cnt) in c.iter().enumerate() {
                let sum: f64 = w[i..i + cnt].iter().sum();
                best = best.max(sum / rates[s]);
                i += cnt;
            }
            best
        };
        assert!(
            norm_max(&counts, &[1.0, 4.0]) <= norm_max(&even_partition(16, 2), &[1.0, 4.0]) + 1e-9
        );
    }

    #[test]
    fn rated_partition_every_stage_nonempty() {
        let mut rng = Rng::new(33);
        for _ in 0..100 {
            let n = rng.range(4, 30) as usize;
            let stages = rng.range(2, 7.min(n as i64)) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0 + 0.01).collect();
            let rates: Vec<f64> =
                (0..stages).map(|_| [1.0, 2.0, 4.0][rng.below(3) as usize]).collect();
            let counts = rated_balanced_partition(&w, stages, &rates);
            assert_eq!(counts.len(), stages);
            assert_eq!(counts.iter().sum::<usize>(), n);
            assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        }
    }

    #[test]
    fn balance_degree_bounds() {
        let w = vec![1.0; 16];
        let alpha = balance_degree(&w, &even_partition(16, 4));
        assert!((alpha - 0.75).abs() < 1e-12); // perfect balance: 1 - 1/P
    }
}
