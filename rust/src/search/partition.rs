//! Pipeline partition construction: even, memory-balanced (p_m) and
//! time-balanced (p_t) contiguous partitions (paper §IV-B).
//!
//! Balanced partitions minimize the maximum stage weight over contiguous
//! layer chunks — solved exactly with binary search over the bottleneck +
//! a greedy feasibility sweep (classic linear-partitioning).

/// Split `n_layers` into `stages` contiguous chunks as evenly as possible.
pub fn even_partition(n_layers: usize, stages: usize) -> Vec<usize> {
    assert!(stages >= 1 && stages <= n_layers);
    let base = n_layers / stages;
    let rem = n_layers % stages;
    (0..stages).map(|i| base + usize::from(i < rem)).collect()
}

/// Contiguous partition of `weights` into `stages` parts minimizing the
/// maximum part sum. Returns layer counts per stage (every stage >= 1).
pub fn balanced_partition(weights: &[f64], stages: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(stages >= 1 && stages <= n);
    if stages == 1 {
        return vec![n];
    }
    let total: f64 = weights.iter().sum();
    let maxw = weights.iter().cloned().fold(0.0, f64::max);
    let (mut lo, mut hi) = (maxw, total);
    // Binary search the bottleneck to within a tiny relative tolerance.
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if feasible(weights, stages, mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // Greedy fill at the found bottleneck, then pad so every stage is
    // non-empty (move boundaries back from the right).
    let mut cuts = greedy_cuts(weights, stages, hi * (1.0 + 1e-12));
    while cuts.len() < stages - 1 {
        // Fewer parts needed than allowed: split the largest part.
        let counts = cuts_to_counts(&cuts, n);
        let (mut best, mut best_i) = (0usize, 0usize);
        let mut start = 0;
        for (i, c) in counts.iter().enumerate() {
            if *c > best {
                best = *c;
                best_i = i;
            }
            start += c;
        }
        let _ = start;
        let part_start: usize = counts[..best_i].iter().sum();
        cuts.push(part_start + counts[best_i] / 2);
        cuts.sort_unstable();
    }
    cuts_to_counts(&cuts, n)
}

/// Can `weights` be split into `stages` contiguous parts each <= cap?
fn feasible(weights: &[f64], stages: usize, cap: f64) -> bool {
    let mut parts = 1;
    let mut acc: f64 = 0.0;
    for &w in weights {
        if w > cap {
            return false;
        }
        if acc + w > cap {
            parts += 1;
            acc = w;
            if parts > stages {
                return false;
            }
        } else {
            acc += w;
        }
    }
    true
}

fn greedy_cuts(weights: &[f64], stages: usize, cap: f64) -> Vec<usize> {
    let mut cuts = Vec::new();
    let mut acc = 0.0;
    let n = weights.len();
    for (i, &w) in weights.iter().enumerate() {
        if acc + w > cap && i > 0 {
            cuts.push(i);
            acc = w;
        } else {
            acc += w;
        }
        // Never leave fewer layers than stages remaining.
        if cuts.len() == stages - 1 {
            break;
        }
        let remaining_stages = stages - 1 - cuts.len();
        let remaining_layers = n - (i + 1);
        if remaining_layers == remaining_stages && i + 1 < n {
            // Force cuts so that later stages get >= 1 layer each.
            for c in (i + 1)..n {
                cuts.push(c);
                if cuts.len() == stages - 1 {
                    break;
                }
            }
            break;
        }
    }
    cuts.truncate(stages - 1);
    cuts
}

fn cuts_to_counts(cuts: &[usize], n: usize) -> Vec<usize> {
    let mut counts = Vec::with_capacity(cuts.len() + 1);
    let mut prev = 0;
    for &c in cuts {
        counts.push(c - prev);
        prev = c;
    }
    counts.push(n - prev);
    counts
}

/// Max part sum of a partition (for alpha computations / tests).
pub fn max_stage_weight(weights: &[f64], counts: &[usize]) -> f64 {
    let mut best: f64 = 0.0;
    let mut i = 0;
    for &c in counts {
        let s: f64 = weights[i..i + c].iter().sum();
        best = best.max(s);
        i += c;
    }
    best
}

/// Balance degree alpha = 1 - max/sum (Eq. 6 numerator shape).
pub fn balance_degree(weights: &[f64], counts: &[usize]) -> f64 {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    1.0 - max_stage_weight(weights, counts) / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn even_splits() {
        assert_eq!(even_partition(32, 4), vec![8, 8, 8, 8]);
        assert_eq!(even_partition(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(even_partition(4, 4), vec![1, 1, 1, 1]);
    }

    #[test]
    fn balanced_uniform_equals_even() {
        let w = vec![1.0; 32];
        assert_eq!(balanced_partition(&w, 4), vec![8, 8, 8, 8]);
    }

    #[test]
    fn balanced_heterogeneous() {
        // Heavy head: [8,1,1,1,1,1,1,1] into 2 -> [1,7] puts the heavy
        // layer alone.
        let w = vec![8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let counts = balanced_partition(&w, 2);
        assert_eq!(counts.iter().sum::<usize>(), 8);
        assert_eq!(max_stage_weight(&w, &counts), 8.0);
    }

    #[test]
    fn every_stage_nonempty_property() {
        // Property test: random weights, random stage counts.
        let mut rng = Rng::new(99);
        for _ in 0..200 {
            let n = rng.range(4, 40) as usize;
            let stages = rng.range(2, 8.min(n as i64)) as usize;
            let w: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0 + 0.01).collect();
            let counts = balanced_partition(&w, stages);
            assert_eq!(counts.len(), stages);
            assert_eq!(counts.iter().sum::<usize>(), n);
            assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        }
    }

    #[test]
    fn balanced_beats_even_on_skewed_weights() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let n = 24;
            let w: Vec<f64> = (0..n).map(|i| if i < 4 { 20.0 } else { rng.f64() + 1.0 }).collect();
            let bal = balanced_partition(&w, 4);
            let even = even_partition(n, 4);
            assert!(
                max_stage_weight(&w, &bal) <= max_stage_weight(&w, &even) + 1e-9,
                "bal {bal:?} even {even:?}"
            );
        }
    }

    #[test]
    fn optimality_vs_bruteforce_small() {
        // Exhaustive check on small instances.
        let mut rng = Rng::new(13);
        for _ in 0..60 {
            let n = rng.range(3, 9) as usize;
            let stages = rng.range(2, n as i64) as usize;
            let w: Vec<f64> = (0..n).map(|_| (rng.below(9) + 1) as f64).collect();
            let got = max_stage_weight(&w, &balanced_partition(&w, stages));
            let best = brute_best(&w, stages);
            assert!((got - best).abs() < 1e-6, "w={w:?} stages={stages} got={got} best={best}");
        }
    }

    fn brute_best(w: &[f64], stages: usize) -> f64 {
        fn rec(w: &[f64], stages: usize) -> f64 {
            if stages == 1 {
                return w.iter().sum();
            }
            let mut best = f64::INFINITY;
            for first in 1..=(w.len() - stages + 1) {
                let head: f64 = w[..first].iter().sum();
                let rest = rec(&w[first..], stages - 1);
                best = best.min(head.max(rest));
            }
            best
        }
        rec(w, stages)
    }

    #[test]
    fn balance_degree_bounds() {
        let w = vec![1.0; 16];
        let alpha = balance_degree(&w, &even_partition(16, 4));
        assert!((alpha - 0.75).abs() < 1e-12); // perfect balance: 1 - 1/P
    }
}
