//! Shared memoized cost tables for the search engine.
//!
//! The sequential planner recomputed every per-layer cost `c(l, s)` at each
//! (batch, PP, microbatch, partition) cell even though the cost depends
//! only on (layer profile, strategy, microbatch size, island class).
//! [`CostCache`] memoizes both `c(l, s)` and the transform cost R across
//! *all* cells of a search run, and collapses the (typically many)
//! identical transformer layers into cost classes so a 32-layer homogeneous
//! model pays for at most two distinct layers (the embedding-bearing
//! first/head-bearing last layer being the usual second class).
//!
//! Heterogeneous clusters: a cost additionally depends on the island class
//! the stage runs on (FLOP rate, bus bandwidth, memory), so every key
//! carries the site class and the cache holds one bound estimator per
//! class. A homogeneous cluster has a single class 0 — its keys, lookup
//! counts and entries are identical to the pre-island cache.
//!
//! Thread safety: the cache is shared by every worker of the engine's
//! (batch × PP) fan-out. Values are pure functions of their key, so a
//! racing double-compute is harmless — both threads produce bit-identical
//! results and the insert path re-checks under the write lock, keeping the
//! entry count (and thus the serialized `SearchTrace` cache statistics)
//! independent of the thread count.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::cost::estimator::{CostEstimator, LayerCost, StageCosts};
use crate::model::{LayerProfile, ModelProfile};
use crate::parallel::Strategy;

/// Map each layer to a cost class: two layers share a class iff their
/// profiles *and* attributed embedding/head params are identical, making
/// memoized costs valid across layer indices within a class.
pub fn layer_classes(model: &ModelProfile) -> Vec<u32> {
    let mut reps: Vec<usize> = Vec::new(); // class id -> representative layer
    let mut classes = Vec::with_capacity(model.n_layers());
    for i in 0..model.n_layers() {
        match reps.iter().position(|&r| same_cost_profile(model, r, i)) {
            Some(c) => classes.push(c as u32),
            None => {
                classes.push(reps.len() as u32);
                reps.push(i);
            }
        }
    }
    classes
}

fn same_cost_profile(model: &ModelProfile, a: usize, b: usize) -> bool {
    let (x, y) = (&model.layers[a], &model.layers[b]);
    x.hidden == y.hidden
        && x.seq == y.seq
        && x.heads == y.heads
        && x.kv_seq == y.kv_seq
        && x.params == y.params
        && x.flops_fwd == y.flops_fwd
        && x.act_bytes == y.act_bytes
        && x.bnd_bytes == y.bnd_bytes
        && model.extra_params(a) == model.extra_params(b)
}

/// Outer key: everything except the strategy (which is matched by value in
/// the inner list, avoiding a Strategy clone per lookup). The leading u64
/// is the cost-model provenance fingerprint
/// ([`crate::cost::CostModel::cache_fingerprint`], 0 = analytic): costs
/// are pure functions of their key *and* the backend that priced them, so
/// memoized entries from different backends must never be confused.
type CellKey = (u64, u32, u32, u64, u64); // (provenance, site class, layer class, b_m bits, extra_params bits)

/// Memoizing cost source bound to one (cluster, PP, overlap, cost-model)
/// placement context — the engine builds one per PP degree, holding one
/// estimator per island site class of that degree.
pub struct CostCache {
    /// Site-class-bound estimators, indexed by `StageSite::class`.
    ests: Vec<CostEstimator>,
    classes: Vec<u32>,
    /// Cost-model fingerprint of the bound estimators (folded into keys).
    provenance: u64,
    layer_costs: RwLock<HashMap<CellKey, Vec<(Strategy, LayerCost)>>>,
    /// (provenance, site class, layer class, b_m bits) ->
    /// [(prev batch-split, cur batch-split), R].
    transforms: RwLock<HashMap<(u64, u32, u32, u64), Vec<((usize, usize), f64)>>>,
    lookups: AtomicU64,
}

impl CostCache {
    /// Single-site cache (homogeneous context; the one estimator is class
    /// 0). Kept as the simple constructor for tests and library users.
    pub fn new(est: CostEstimator, classes: Vec<u32>) -> CostCache {
        Self::with_sites(vec![est], classes)
    }

    /// Cache over one estimator per island site class.
    pub fn with_sites(ests: Vec<CostEstimator>, classes: Vec<u32>) -> CostCache {
        assert!(!ests.is_empty());
        let provenance = ests[0].cost_model.cache_fingerprint();
        debug_assert!(
            ests.iter().all(|e| e.cost_model.cache_fingerprint() == provenance),
            "every site estimator of one cache must share a cost-model backend"
        );
        CostCache {
            ests,
            classes,
            provenance,
            layer_costs: RwLock::new(HashMap::new()),
            transforms: RwLock::new(HashMap::new()),
            lookups: AtomicU64::new(0),
        }
    }

    /// The underlying (uncached) estimator for `site_class`.
    pub fn estimator(&self, site_class: u32) -> &CostEstimator {
        &self.ests[site_class as usize]
    }

    /// A [`StageCosts`] view bound to one island site class — what the
    /// stage-level DP of a stage placed on that class consumes.
    pub fn site_costs(&self, site_class: u32) -> SiteCosts<'_> {
        debug_assert!((site_class as usize) < self.ests.len());
        SiteCosts { cache: self, site: site_class }
    }

    /// Total memoized lookups served (layer costs + transforms). The per-key
    /// work of every search cell is fixed, so this is deterministic across
    /// thread counts.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Distinct entries resident (the union of keys touched — also
    /// deterministic across thread counts; see module docs on races).
    pub fn entries(&self) -> u64 {
        let lc: usize = self.layer_costs.read().unwrap_or_else(std::sync::PoisonError::into_inner).values().map(Vec::len).sum();
        let tc: usize = self.transforms.read().unwrap_or_else(std::sync::PoisonError::into_inner).values().map(Vec::len).sum();
        (lc + tc) as u64
    }

    fn class_of(&self, layer_idx: usize) -> u32 {
        self.classes[layer_idx]
    }

    fn layer_cost_for(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let class = self.class_of(layer_idx);
        let key: CellKey = (self.provenance, site, class, b_m.to_bits(), extra_params.to_bits());
        if let Some(row) = self.layer_costs.read().unwrap_or_else(std::sync::PoisonError::into_inner).get(&key) {
            if let Some((_, c)) = row.iter().find(|(s, _)| s == strategy) {
                return *c;
            }
        }
        let c = self.ests[site as usize].layer_cost(layer, strategy, b_m, extra_params);
        let mut map = self.layer_costs.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let row = map.entry(key).or_default();
        // Re-check: another worker may have inserted while we computed.
        if !row.iter().any(|(s, _)| s == strategy) {
            row.push((strategy.clone(), c));
        }
        c
    }

    fn transform_cost_for(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        // R depends on the strategies only through their batch-split degrees
        // (parallel::transform) and on the group's slowest link, which is
        // fixed per site class (all catalog strategies span the full stage
        // group), so splits are a sufficient key.
        let splits = (prev.batch_split(), cur.batch_split());
        let key = (self.provenance, site, self.class_of(layer_idx), b_m.to_bits());
        if let Some(row) = self.transforms.read().unwrap_or_else(std::sync::PoisonError::into_inner).get(&key) {
            if let Some((_, r)) = row.iter().find(|(sp, _)| *sp == splits) {
                return *r;
            }
        }
        let r = self.ests[site as usize].transform_cost(layer, prev, cur, b_m);
        let mut map = self.transforms.write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let row = map.entry(key).or_default();
        if !row.iter().any(|(sp, _)| *sp == splits) {
            row.push((splits, r));
        }
        r
    }
}

/// [`StageCosts`] for a bare `CostCache`: the degenerate single-class view
/// (site class 0) — exactly the homogeneous cache's behavior.
impl StageCosts for CostCache {
    fn layer_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.layer_cost_for(0, layer_idx, layer, strategy, b_m, extra_params)
    }

    fn transform_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.transform_cost_for(0, layer_idx, layer, prev, cur, b_m)
    }
}

/// A shared cache viewed from one island site class: the `StageCosts`
/// source handed to the stage-level DP of a stage placed on that class.
pub struct SiteCosts<'a> {
    cache: &'a CostCache,
    site: u32,
}

impl StageCosts for SiteCosts<'_> {
    fn layer_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.cache.layer_cost_for(self.site, layer_idx, layer, strategy, b_m, extra_params)
    }

    fn transform_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.cache.transform_cost_for(self.site, layer_idx, layer, prev, cur, b_m)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::search::decision_tree::{candidate_strategies, SpaceOptions};

    #[test]
    fn homogeneous_layers_collapse_to_few_classes() {
        let model = model_by_name("bert-huge-32").unwrap();
        let classes = layer_classes(&model);
        assert_eq!(classes.len(), 32);
        let distinct = classes.iter().max().unwrap() + 1;
        // Interior layers identical; first/last differ via embeddings/head.
        assert!(distinct <= 3, "expected <=3 classes, got {distinct}: {classes:?}");
        assert_eq!(classes[1], classes[2]);
    }

    #[test]
    fn cached_equals_direct_and_counts_stats() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 2, 1.3);
        let cache = CostCache::new(est.clone(), layer_classes(&model));
        let cands = candidate_strategies(4, &SpaceOptions::default());
        for (i, layer) in model.layers.iter().enumerate().take(3) {
            for s in &cands {
                let direct = est.layer_cost(layer, s, 4.0, model.extra_params(i));
                let cached = cache.layer_cost_at(i, layer, s, 4.0, model.extra_params(i));
                assert_eq!(direct, cached);
                // Second call is a hit and returns the identical value.
                assert_eq!(cache.layer_cost_at(i, layer, s, 4.0, model.extra_params(i)), direct);
            }
        }
        let lookups = cache.lookups();
        let entries = cache.entries();
        assert!(lookups > entries, "lookups {lookups} entries {entries}");
        // Layers 1 and 2 share a class, so entries reflect classes not layers.
        assert!(entries <= 2 * cands.len() as u64);
    }

    #[test]
    fn transform_cache_matches_direct() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 1, 1.3);
        let cache = CostCache::new(est.clone(), layer_classes(&model));
        let cands = candidate_strategies(8, &SpaceOptions::default().no_ckpt());
        for prev in &cands {
            for cur in &cands {
                let direct = est.transform_cost(&model.layers[1], prev, cur, 8.0);
                let cached = cache.transform_cost_at(1, &model.layers[1], prev, cur, 8.0);
                assert_eq!(direct, cached, "{prev} -> {cur}");
            }
        }
    }

    #[test]
    fn calibrated_cache_matches_its_backend_not_analytic() {
        use crate::cost::{CostModel, ProfileDb};
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        // A DB claiming half the nominal FLOP rate everywhere.
        let mut db = ProfileDb::synthetic(&cluster);
        let half = db.ref_flops / 2.0;
        for s in &mut db.layers {
            s.effective_flops = half;
        }
        let backend = CostModel::calibrated(db);
        let analytic = CostEstimator::new(&cluster, 1, 1.3);
        let calibrated =
            CostEstimator::new(&cluster, 1, 1.3).with_cost_model(backend.clone());
        let cache_a = CostCache::new(analytic.clone(), layer_classes(&model));
        let cache_c = CostCache::new(calibrated.clone(), layer_classes(&model));
        let s = crate::parallel::Strategy::serial(false);
        let a = cache_a.layer_cost_at(1, &model.layers[1], &s, 4.0, 0.0);
        let c = cache_c.layer_cost_at(1, &model.layers[1], &s, 4.0, 0.0);
        assert_eq!(a, analytic.layer_cost(&model.layers[1], &s, 4.0, 0.0));
        assert_eq!(c, calibrated.layer_cost(&model.layers[1], &s, 4.0, 0.0));
        assert!(c.fwd > a.fwd, "calibrated {} must exceed analytic {}", c.fwd, a.fwd);
        // The provenance fingerprints keep the key spaces disjoint.
        assert_ne!(backend.cache_fingerprint(), 0);
    }

    #[test]
    fn site_classes_are_cached_independently() {
        // hetero4 at PP=2 has two site classes (TITAN vs A100-80G): the
        // memoized cost of the same (layer, strategy, b_m) must differ by
        // class and match each class's direct estimator.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("hetero4").unwrap();
        let sites = cluster.stage_sites(2);
        assert_ne!(sites[0].class, sites[1].class);
        let ests: Vec<CostEstimator> = sites
            .iter()
            .map(|s| CostEstimator::with_site(&cluster, 2, 1.3, s.clone()))
            .collect();
        let cache = CostCache::with_sites(ests.clone(), layer_classes(&model));
        let cands = candidate_strategies(2, &SpaceOptions::default().no_ckpt());
        for s in &cands {
            let slow = cache.site_costs(0).layer_cost_at(1, &model.layers[1], s, 4.0, 0.0);
            let fast = cache.site_costs(1).layer_cost_at(1, &model.layers[1], s, 4.0, 0.0);
            assert_eq!(slow, ests[0].layer_cost(&model.layers[1], s, 4.0, 0.0));
            assert_eq!(fast, ests[1].layer_cost(&model.layers[1], s, 4.0, 0.0));
            assert!(slow.fwd > fast.fwd, "TITAN must be slower: {} vs {}", slow.fwd, fast.fwd);
        }
    }
}
