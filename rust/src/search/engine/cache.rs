//! Shared memoized cost tables for the search engine.
//!
//! The sequential planner recomputed every per-layer cost `c(l, s)` at each
//! (batch, PP, microbatch, partition) cell even though the cost depends
//! only on (layer profile, strategy, microbatch size, island class).
//! [`CostCache`] memoizes both `c(l, s)` and the transform cost R across
//! *all* cells of a search run, and collapses the (typically many)
//! identical transformer layers into cost classes so a 32-layer homogeneous
//! model pays for at most two distinct layers (the embedding-bearing
//! first/head-bearing last layer being the usual second class).
//!
//! Keys are fully flat: the strategy is packed into a `u64`
//! ([`strategy_key`] — ordered levels + CKPT bit, injective for the whole
//! catalog space), so every lookup is one `HashMap` probe instead of the
//! former linear scan of a `Vec<(Strategy, LayerCost)>` row under the read
//! lock. The same packed keys are what [`super::persist`] serializes.
//!
//! Heterogeneous clusters: a cost additionally depends on the island class
//! the stage runs on (FLOP rate, bus bandwidth, memory), so every key
//! carries the site class and the cache holds one bound estimator per
//! class. A homogeneous cluster has a single class 0 — its keys, lookup
//! counts and entries are identical to the pre-island cache. Since
//! [`crate::cost::CostEstimator::layer_cost`] never reads the PP binding
//! (only p2p pricing does, and p2p is never cached), the engine shares one
//! cache across every PP degree of a run, with site classes deduplicated
//! run-wide. Keys carry the microbatch size `b_m` — not the global batch —
//! so adjacent batch sizes of the sweep reuse each other's entries too.
//!
//! Thread safety: the cache is shared by every worker of the engine's
//! (batch × PP) fan-out. Values are pure functions of their key, so a
//! racing double-compute is harmless — both threads produce bit-identical
//! results and the insert path re-checks under the write lock, keeping the
//! entry count (and thus the serialized `SearchTrace` cache statistics)
//! independent of the thread count.
//!
//! Persistence: [`CostCache::attach_persist`] loads a prior run's tables
//! (translated from stable site fingerprints to this run's class ids) as a
//! read-only second level consulted on an in-memory miss. A disk hit is
//! inserted into the in-memory map exactly like a computed value, so the
//! lookup/entry counters — and therefore the serialized trace — are
//! byte-identical warm vs cold. [`CostCache::flush_persist`] merges the
//! run's tables back to disk.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::cost::estimator::{CostEstimator, LayerCost, StageCosts};
use crate::model::{LayerProfile, ModelProfile};
use crate::parallel::{Dim, Strategy};
use crate::search::decision_tree::dominated_candidates;
use crate::search::dp::DpResult;

use super::persist::PersistHandle;

/// Map each layer to a cost class: two layers share a class iff their
/// profiles *and* attributed embedding/head params are identical, making
/// memoized costs valid across layer indices within a class.
pub fn layer_classes(model: &ModelProfile) -> Vec<u32> {
    let mut reps: Vec<usize> = Vec::new(); // class id -> representative layer
    let mut classes = Vec::with_capacity(model.n_layers());
    for i in 0..model.n_layers() {
        match reps.iter().position(|&r| same_cost_profile(model, r, i)) {
            Some(c) => classes.push(c as u32),
            None => {
                classes.push(reps.len() as u32);
                reps.push(i);
            }
        }
    }
    classes
}

fn same_cost_profile(model: &ModelProfile, a: usize, b: usize) -> bool {
    let (x, y) = (&model.layers[a], &model.layers[b]);
    x.hidden == y.hidden
        && x.seq == y.seq
        && x.heads == y.heads
        && x.kv_seq == y.kv_seq
        && x.params == y.params
        && x.flops_fwd == y.flops_fwd
        && x.act_bytes == y.act_bytes
        && x.bnd_bytes == y.bnd_bytes
        && model.extra_params(a) == model.extra_params(b)
}

/// Pack a [`Strategy`] into a `u64` key: bit 0 is the CKPT flag, then one
/// byte per level (outermost first) holding `(dim_tag << 6) | log2(degree)`.
/// Level *order* matters to cost (outer levels ride slower links), degrees
/// are powers of two ≥ 2 and dim tags are nonzero, so every level byte is
/// nonzero and the packing is injective for up to 7 levels (the catalog
/// has at most 3: the distinct dims DP/SDP/TP).
pub(crate) fn strategy_key(s: &Strategy) -> u64 {
    debug_assert!(s.levels.len() <= 7, "strategy has more levels than the packed key holds");
    let mut k: u64 = u64::from(s.ckpt);
    for (i, (dim, degree)) in s.levels.iter().enumerate().take(7) {
        let tag: u64 = match dim {
            Dim::Dp => 1,
            Dim::Sdp => 2,
            Dim::Tp => 3,
        };
        let byte = (tag << 6) | (degree.trailing_zeros() as u64 & 0x3f);
        k |= byte << (8 * (i as u64 + 1));
    }
    k
}

/// Flat key of one memoized layer cost. The leading u64 is the cost-model
/// provenance fingerprint ([`crate::cost::CostModel::cache_fingerprint`],
/// 0 = analytic): costs are pure functions of their key *and* the backend
/// that priced them, so memoized entries from different backends must
/// never be confused.
pub(crate) type LayerKey = (u64, u32, u32, u64, u64, u64); // (provenance, site class, layer class, b_m bits, extra_params bits, strategy key)

/// Flat key of one memoized transform cost R. The trailing u64 packs the
/// (prev, cur) batch-split degrees: R depends on the strategies only
/// through their splits (parallel::transform) and on the group's slowest
/// link, which is fixed per site class.
pub(crate) type TransformKey = (u64, u32, u32, u64, u64); // (provenance, site class, layer class, b_m bits, packed splits)

pub(crate) fn pack_splits(prev: usize, cur: usize) -> u64 {
    ((prev as u64) << 32) | (cur as u64 & 0xffff_ffff)
}

/// Key of one precomputed matrix bundle: (site class, stage group size,
/// b_m bits). The candidate catalog is a pure function of the group size
/// within one run, so the key needs no catalog fingerprint.
pub(crate) type MatrixKey = (u32, u64, u64);

/// Key of one memoized stage-DP solve. A stage's DP result is a pure
/// function of (site class, group size, b_m, microbatch count, live
/// microbatches, memory budget, the stage's layer-class sequence) — the
/// layer *indices* only enter through their cost classes, and the
/// granularity is fixed per run. Interior stages of a homogeneous model
/// therefore collapse to one key per length, which is what makes the BMW
/// adjustment queue (boundary shifts of ±1 layer) and the ordered batch
/// sweep (recurring `b_m = B/m`) incremental: most stage solves after the
/// first few are O(1) map hits.
pub(crate) type DpMemoKey = (u32, u64, u64, u64, u64, u64, Vec<u32>);

/// Memoized stage-DP outcome: the solved result (`None` = infeasible under
/// the budget) plus the DP states the solve visited, replayed into the
/// per-cell counter on a hit so `dp_states_visited` stays deterministic
/// across thread schedules.
pub(crate) type DpMemoEntry = Arc<(Option<DpResult>, u64)>;

/// Flat per-(site, group, b_m) cost tables shared by every stage DP that
/// prices layers on that site at that microbatch size — the "precompute
/// once per (layer-class, b_m)" half of the cold-path speedup. Built from
/// the memoized maps (so warm starts and the entry counters behave exactly
/// as before) and shared by `Arc` across cells, batches and threads:
/// adjacent batch sizes with equal `b_m = B/m` reuse the same bundle, which
/// is what makes the ordered batch sweep incremental.
pub(crate) struct StageMatrices {
    /// `class_costs[layer_class][candidate]` — full catalog order.
    pub class_costs: Vec<Vec<LayerCost>>,
    /// Per-microbatch transform cost between batch-split classes, per layer
    /// class of the *current* layer: `class_transforms[layer_class][ci][cj]`.
    pub class_transforms: Vec<Vec<Vec<f64>>>,
    /// Distinct batch-split degrees (sorted ascending).
    pub splits: Vec<usize>,
    /// Candidate index → split class (index into `splits`).
    pub class_of: Vec<usize>,
    /// Dominance-surviving candidate indices in catalog order (all indices
    /// when pruning is off). See
    /// [`crate::search::decision_tree::dominated_candidates`].
    pub active: Vec<usize>,
    /// Per layer class: min over the catalog of `fwd + bwd` — the
    /// optimistic per-layer term of the lower-bound skip.
    pub min_step: Vec<f64>,
    /// Per layer class: min over the catalog of `fwd + bwd_sync`.
    pub min_step_sync: Vec<f64>,
}

/// Memoizing cost source shared by every cell of a search run, holding one
/// bound estimator per island site class (run-wide deduplicated across PP
/// degrees by the engine).
pub struct CostCache {
    /// Site-class-bound estimators, indexed by `StageSite::class`.
    ests: Vec<CostEstimator>,
    classes: Vec<u32>,
    /// Cost-model fingerprint of the bound estimators (folded into keys).
    provenance: u64,
    layer_costs: RwLock<HashMap<LayerKey, LayerCost>>,
    transforms: RwLock<HashMap<TransformKey, f64>>,
    /// Precomputed per-(site, group, b_m) matrix bundles. A racing
    /// double-build is harmless (values are pure functions of the key);
    /// the insert path re-checks under the lock, so the resident bundle —
    /// and every statistic derived from the map — is thread-independent.
    matrices: Mutex<HashMap<MatrixKey, Arc<StageMatrices>>>,
    /// Memoized stage-DP solves (pruned path only; see [`DpMemoKey`]). A
    /// racing double-solve is harmless for the same reason bundle races
    /// are: values are pure functions of the key.
    dp_memo: Mutex<HashMap<DpMemoKey, DpMemoEntry>>,
    /// Whether bundles drop dominated candidates (the engine resolves
    /// `SearchConfig::prune` / `GALVATRON_NO_PRUNE` into this).
    prune: bool,
    lookups: AtomicU64,
    /// Read-only warm-start tables loaded from the persistent cache,
    /// consulted on an in-memory miss (disk hits are re-inserted into the
    /// in-memory maps so the counters match a cold run exactly).
    disk_layer: HashMap<LayerKey, LayerCost>,
    disk_transforms: HashMap<TransformKey, f64>,
    persist: Option<PersistHandle>,
}

impl CostCache {
    /// Single-site cache (homogeneous context; the one estimator is class
    /// 0). Kept as the simple constructor for tests and library users.
    pub fn new(est: CostEstimator, classes: Vec<u32>) -> CostCache {
        Self::with_sites(vec![est], classes)
    }

    /// Cache over one estimator per island site class.
    pub fn with_sites(ests: Vec<CostEstimator>, classes: Vec<u32>) -> CostCache {
        assert!(!ests.is_empty());
        let provenance = ests[0].cost_model.cache_fingerprint();
        debug_assert!(
            ests.iter().all(|e| e.cost_model.cache_fingerprint() == provenance),
            "every site estimator of one cache must share a cost-model backend"
        );
        CostCache {
            ests,
            classes,
            provenance,
            layer_costs: RwLock::new(HashMap::new()),
            transforms: RwLock::new(HashMap::new()),
            matrices: Mutex::new(HashMap::new()),
            dp_memo: Mutex::new(HashMap::new()),
            prune: true,
            lookups: AtomicU64::new(0),
            disk_layer: HashMap::new(),
            disk_transforms: HashMap::new(),
            persist: None,
        }
    }

    /// Bind a persistent cache directory: loads any valid prior tables for
    /// this context (stale/corrupt/mismatched files are ignored with a
    /// warning) and arms [`CostCache::flush_persist`]. `site_fps` maps this
    /// run's site class ids to their stable content fingerprints. Returns
    /// `(warm_start, entries_loaded)`.
    pub fn attach_persist(&mut self, handle: PersistHandle) -> (bool, u64) {
        let (warm, layer, transforms) = handle.load(self.provenance);
        let loaded = (layer.len() + transforms.len()) as u64;
        self.disk_layer = layer;
        self.disk_transforms = transforms;
        self.persist = Some(handle);
        (warm, loaded)
    }

    /// Merge this run's tables into the persistent cache (no-op without
    /// [`CostCache::attach_persist`]; IO errors degrade to a warning).
    pub fn flush_persist(&self) {
        let Some(handle) = &self.persist else { return };
        let layer =
            self.layer_costs.read().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
        let transforms =
            self.transforms.read().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
        handle.flush(&layer, &transforms);
    }

    /// The underlying (uncached) estimator for `site_class`.
    pub fn estimator(&self, site_class: u32) -> &CostEstimator {
        &self.ests[site_class as usize]
    }

    /// A [`StageCosts`] view bound to one island site class — what the
    /// stage-level DP of a stage placed on that class consumes.
    pub fn site_costs(&self, site_class: u32) -> SiteCosts<'_> {
        debug_assert!((site_class as usize) < self.ests.len());
        SiteCosts { cache: self, site: site_class }
    }

    /// Total memoized lookups served (layer costs + transforms). The per-key
    /// work of every search cell is fixed, so this is deterministic across
    /// thread counts.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Distinct entries resident (the union of keys touched — also
    /// deterministic across thread counts and across warm/cold starts; see
    /// module docs on races and on the disk second level).
    pub fn entries(&self) -> u64 {
        let lc = self.layer_costs.read().unwrap_or_else(std::sync::PoisonError::into_inner).len();
        let tc = self.transforms.read().unwrap_or_else(std::sync::PoisonError::into_inner).len();
        (lc + tc) as u64
    }

    fn class_of(&self, layer_idx: usize) -> u32 {
        self.classes[layer_idx]
    }

    /// Layer → cost-class map this cache was built over.
    pub(crate) fn layer_class_map(&self) -> &[u32] {
        &self.classes
    }

    /// Whether matrix bundles apply dominance pruning.
    pub(crate) fn prune(&self) -> bool {
        self.prune
    }

    /// Set by the engine after resolving `SearchConfig::prune` against the
    /// `GALVATRON_NO_PRUNE` escape hatch (before the cache is shared).
    pub(crate) fn set_prune(&mut self, prune: bool) {
        self.prune = prune;
    }

    /// Fetch (building on first use) the matrix bundle for one
    /// (site class, stage group, b_m) context, counting the lookup traffic
    /// the requesting stage implies: `n_layers · |catalog|` layer costs plus
    /// `(n_layers - 1) · |splits|²` transforms — a pure function of the
    /// stage shape, so the serialized trace counters are independent of
    /// pruning, DP outcomes, thread schedule and warm starts.
    pub(crate) fn stage_matrices(
        &self,
        site: u32,
        group: usize,
        b_m: f64,
        stage_layers: usize,
        candidates: &[Strategy],
        model: &ModelProfile,
    ) -> Arc<StageMatrices> {
        let key: MatrixKey = (site, group as u64, b_m.to_bits());
        let cached = {
            let map = self.matrices.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            map.get(&key).cloned()
        };
        let mats = match cached {
            Some(m) => m,
            None => {
                // Built outside the lock (bit-identical on a race), inserted
                // with a re-check so one bundle wins deterministically.
                let built = Arc::new(self.build_matrices(site, b_m, candidates, model));
                self.matrices
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .entry(key)
                    .or_insert(built)
                    .clone()
            }
        };
        let nl = stage_layers as u64;
        let ns = candidates.len() as u64;
        let nc = mats.splits.len() as u64;
        self.lookups.fetch_add(nl * ns + nl.saturating_sub(1) * nc * nc, Ordering::Relaxed);
        mats
    }

    /// Memoized stage-DP solve for `key`, if one is resident.
    pub(crate) fn dp_memo_get(&self, key: &DpMemoKey) -> Option<DpMemoEntry> {
        self.dp_memo.lock().unwrap_or_else(std::sync::PoisonError::into_inner).get(key).cloned()
    }

    /// Insert a solved stage DP (first writer wins; the returned entry is
    /// the resident one, bit-identical to `entry` on a race).
    pub(crate) fn dp_memo_put(&self, key: DpMemoKey, entry: DpMemoEntry) -> DpMemoEntry {
        self.dp_memo
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .entry(key)
            .or_insert(entry)
            .clone()
    }

    /// Distinct stage-DP solves memoized (diagnostics).
    pub(crate) fn dp_memo_len(&self) -> u64 {
        self.dp_memo.lock().unwrap_or_else(std::sync::PoisonError::into_inner).len() as u64
    }

    /// Distinct bundles built and candidates dominance-dropped across them
    /// (diagnostics for [`super::trace::SearchTiming`]).
    pub(crate) fn matrix_stats(&self) -> (u64, u64) {
        let map = self.matrices.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let builds = map.len() as u64;
        let pruned = map
            .values()
            .map(|m| (m.class_of.len() - m.active.len()) as u64)
            .sum();
        (builds, pruned)
    }

    fn build_matrices(
        &self,
        site: u32,
        b_m: f64,
        candidates: &[Strategy],
        model: &ModelProfile,
    ) -> StageMatrices {
        let n_classes =
            self.classes.iter().max().map(|&c| c as usize + 1).unwrap_or(0);
        // Representative layer per cost class (first occurrence; every
        // member shares its profile and extra params by construction).
        let mut reps = vec![usize::MAX; n_classes];
        for (i, &c) in self.classes.iter().enumerate() {
            if reps[c as usize] == usize::MAX {
                reps[c as usize] = i;
            }
        }
        let ns = candidates.len();

        let class_costs: Vec<Vec<LayerCost>> = reps
            .iter()
            .map(|&rep| {
                let layer = &model.layers[rep];
                let extra = model.extra_params(rep);
                candidates
                    .iter()
                    .map(|s| self.layer_cost_uncounted(site, rep, layer, s, b_m, extra))
                    .collect()
            })
            .collect();

        let mut splits: Vec<usize> = candidates.iter().map(|s| s.batch_split()).collect();
        splits.sort_unstable();
        splits.dedup();
        let nc = splits.len();
        let class_of: Vec<usize> = candidates
            .iter()
            .map(|s| {
                splits
                    .binary_search(&s.batch_split())
                    .unwrap_or_else(|_| unreachable!("split deduped from this catalog"))
            })
            .collect();
        let class_rep: Vec<usize> = (0..nc)
            .map(|c| {
                class_of
                    .iter()
                    .position(|&x| x == c)
                    .unwrap_or_else(|| unreachable!("every split class has a member"))
            })
            .collect();
        let class_transforms: Vec<Vec<Vec<f64>>> = reps
            .iter()
            .map(|&rep| {
                let layer = &model.layers[rep];
                (0..nc)
                    .map(|ci| {
                        (0..nc)
                            .map(|cj| {
                                self.transform_cost_uncounted(
                                    site,
                                    rep,
                                    layer,
                                    &candidates[class_rep[ci]],
                                    &candidates[class_rep[cj]],
                                    b_m,
                                )
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();

        let dominated = if self.prune {
            dominated_candidates(candidates, &class_costs)
        } else {
            vec![false; ns]
        };
        let active: Vec<usize> = (0..ns).filter(|&j| !dominated[j]).collect();

        let min_step: Vec<f64> = class_costs
            .iter()
            .map(|row| row.iter().map(|c| c.fwd + c.bwd).fold(f64::INFINITY, f64::min))
            .collect();
        let min_step_sync: Vec<f64> = class_costs
            .iter()
            .map(|row| row.iter().map(|c| c.fwd + c.bwd_sync).fold(f64::INFINITY, f64::min))
            .collect();

        StageMatrices {
            class_costs,
            class_transforms,
            splits,
            class_of,
            active,
            min_step,
            min_step_sync,
        }
    }

    fn layer_cost_for(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        self.layer_cost_uncounted(site, layer_idx, layer, strategy, b_m, extra_params)
    }

    /// The memoized fetch without the lookup counter: matrix builds count
    /// their traffic at request granularity ([`CostCache::stage_matrices`])
    /// instead of per underlying probe. The disk second level stays in the
    /// path, so warm and cold runs resident-entry counts stay identical.
    fn layer_cost_uncounted(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        let key: LayerKey = (
            self.provenance,
            site,
            self.class_of(layer_idx),
            b_m.to_bits(),
            extra_params.to_bits(),
            strategy_key(strategy),
        );
        if let Some(c) =
            self.layer_costs.read().unwrap_or_else(std::sync::PoisonError::into_inner).get(&key)
        {
            return *c;
        }
        // Persisted values are bit-identical to recomputed ones (the key
        // carries the cost-model provenance), so either source may fill
        // the in-memory entry.
        let c = match self.disk_layer.get(&key) {
            Some(c) => *c,
            None => self.ests[site as usize].layer_cost(layer, strategy, b_m, extra_params),
        };
        // Re-check under the write lock: another worker may have inserted.
        *self
            .layer_costs
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .entry(key)
            .or_insert(c)
    }

    fn transform_cost_for(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        self.transform_cost_uncounted(site, layer_idx, layer, prev, cur, b_m)
    }

    /// See [`CostCache::layer_cost_uncounted`].
    fn transform_cost_uncounted(
        &self,
        site: u32,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        let key: TransformKey = (
            self.provenance,
            site,
            self.class_of(layer_idx),
            b_m.to_bits(),
            pack_splits(prev.batch_split(), cur.batch_split()),
        );
        if let Some(r) =
            self.transforms.read().unwrap_or_else(std::sync::PoisonError::into_inner).get(&key)
        {
            return *r;
        }
        let r = match self.disk_transforms.get(&key) {
            Some(r) => *r,
            None => self.ests[site as usize].transform_cost(layer, prev, cur, b_m),
        };
        *self
            .transforms
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .entry(key)
            .or_insert(r)
    }
}

/// [`StageCosts`] for a bare `CostCache`: the degenerate single-class view
/// (site class 0) — exactly the homogeneous cache's behavior.
impl StageCosts for CostCache {
    fn layer_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.layer_cost_for(0, layer_idx, layer, strategy, b_m, extra_params)
    }

    fn transform_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.transform_cost_for(0, layer_idx, layer, prev, cur, b_m)
    }
}

/// A shared cache viewed from one island site class: the `StageCosts`
/// source handed to the stage-level DP of a stage placed on that class.
pub struct SiteCosts<'a> {
    cache: &'a CostCache,
    site: u32,
}

impl StageCosts for SiteCosts<'_> {
    fn layer_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.cache.layer_cost_for(self.site, layer_idx, layer, strategy, b_m, extra_params)
    }

    fn transform_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.cache.transform_cost_for(self.site, layer_idx, layer, prev, cur, b_m)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::search::decision_tree::{candidate_strategies, SpaceOptions};

    #[test]
    fn homogeneous_layers_collapse_to_few_classes() {
        let model = model_by_name("bert-huge-32").unwrap();
        let classes = layer_classes(&model);
        assert_eq!(classes.len(), 32);
        let distinct = classes.iter().max().unwrap() + 1;
        // Interior layers identical; first/last differ via embeddings/head.
        assert!(distinct <= 3, "expected <=3 classes, got {distinct}: {classes:?}");
        assert_eq!(classes[1], classes[2]);
    }

    #[test]
    fn strategy_key_is_injective_over_the_catalog() {
        // Every catalog strategy for every group size must map to a
        // distinct key; level order must matter.
        use std::collections::HashMap;
        for group in [1usize, 2, 4, 8] {
            let cands = candidate_strategies(group, &SpaceOptions::default());
            let mut seen: HashMap<u64, &Strategy> = HashMap::new();
            for s in &cands {
                if let Some(prev) = seen.insert(strategy_key(s), s) {
                    panic!("key collision at group {group}: {prev} vs {s}");
                }
            }
        }
        let ab = Strategy { levels: vec![(Dim::Dp, 2), (Dim::Tp, 4)], ckpt: false };
        let ba = Strategy { levels: vec![(Dim::Tp, 4), (Dim::Dp, 2)], ckpt: false };
        assert_ne!(strategy_key(&ab), strategy_key(&ba), "level order must be keyed");
        let ck = Strategy { levels: ab.levels.clone(), ckpt: true };
        assert_ne!(strategy_key(&ab), strategy_key(&ck), "ckpt must be keyed");
    }

    #[test]
    fn cached_equals_direct_and_counts_stats() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 2, 1.3);
        let cache = CostCache::new(est.clone(), layer_classes(&model));
        let cands = candidate_strategies(4, &SpaceOptions::default());
        for (i, layer) in model.layers.iter().enumerate().take(3) {
            for s in &cands {
                let direct = est.layer_cost(layer, s, 4.0, model.extra_params(i));
                let cached = cache.layer_cost_at(i, layer, s, 4.0, model.extra_params(i));
                assert_eq!(direct, cached);
                // Second call is a hit and returns the identical value.
                assert_eq!(cache.layer_cost_at(i, layer, s, 4.0, model.extra_params(i)), direct);
            }
        }
        let lookups = cache.lookups();
        let entries = cache.entries();
        assert!(lookups > entries, "lookups {lookups} entries {entries}");
        // Layers 1 and 2 share a class, so entries reflect classes not layers.
        assert!(entries <= 2 * cands.len() as u64);
    }

    #[test]
    fn transform_cache_matches_direct() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 1, 1.3);
        let cache = CostCache::new(est.clone(), layer_classes(&model));
        let cands = candidate_strategies(8, &SpaceOptions::default().no_ckpt());
        for prev in &cands {
            for cur in &cands {
                let direct = est.transform_cost(&model.layers[1], prev, cur, 8.0);
                let cached = cache.transform_cost_at(1, &model.layers[1], prev, cur, 8.0);
                assert_eq!(direct, cached, "{prev} -> {cur}");
            }
        }
    }

    #[test]
    fn calibrated_cache_matches_its_backend_not_analytic() {
        use crate::cost::{CostModel, ProfileDb};
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        // A DB claiming half the nominal FLOP rate everywhere.
        let mut db = ProfileDb::synthetic(&cluster);
        let half = db.ref_flops / 2.0;
        for s in &mut db.layers {
            s.effective_flops = half;
        }
        let backend = CostModel::calibrated(db);
        let analytic = CostEstimator::new(&cluster, 1, 1.3);
        let calibrated =
            CostEstimator::new(&cluster, 1, 1.3).with_cost_model(backend.clone());
        let cache_a = CostCache::new(analytic.clone(), layer_classes(&model));
        let cache_c = CostCache::new(calibrated.clone(), layer_classes(&model));
        let s = crate::parallel::Strategy::serial(false);
        let a = cache_a.layer_cost_at(1, &model.layers[1], &s, 4.0, 0.0);
        let c = cache_c.layer_cost_at(1, &model.layers[1], &s, 4.0, 0.0);
        assert_eq!(a, analytic.layer_cost(&model.layers[1], &s, 4.0, 0.0));
        assert_eq!(c, calibrated.layer_cost(&model.layers[1], &s, 4.0, 0.0));
        assert!(c.fwd > a.fwd, "calibrated {} must exceed analytic {}", c.fwd, a.fwd);
        // The provenance fingerprints keep the key spaces disjoint.
        assert_ne!(backend.cache_fingerprint(), 0);
    }

    #[test]
    fn site_classes_are_cached_independently() {
        // hetero4 at PP=2 has two site classes (TITAN vs A100-80G): the
        // memoized cost of the same (layer, strategy, b_m) must differ by
        // class and match each class's direct estimator.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("hetero4").unwrap();
        let sites = cluster.stage_sites(2);
        assert_ne!(sites[0].class, sites[1].class);
        let ests: Vec<CostEstimator> = sites
            .iter()
            .map(|s| CostEstimator::with_site(&cluster, 2, 1.3, s.clone()))
            .collect();
        let cache = CostCache::with_sites(ests.clone(), layer_classes(&model));
        let cands = candidate_strategies(2, &SpaceOptions::default().no_ckpt());
        for s in &cands {
            let slow = cache.site_costs(0).layer_cost_at(1, &model.layers[1], s, 4.0, 0.0);
            let fast = cache.site_costs(1).layer_cost_at(1, &model.layers[1], s, 4.0, 0.0);
            assert_eq!(slow, ests[0].layer_cost(&model.layers[1], s, 4.0, 0.0));
            assert_eq!(fast, ests[1].layer_cost(&model.layers[1], s, 4.0, 0.0));
            assert!(slow.fwd > fast.fwd, "TITAN must be slower: {} vs {}", slow.fwd, fast.fwd);
        }
    }
}
