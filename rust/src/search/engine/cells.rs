//! Per-cell search kernels: everything the engine computes for one
//! (global-batch, PP-degree) grid cell. Each cell is self-contained — it
//! reads only its own inputs and the shared (thread-safe) cost cache — so
//! cells can run on any worker in any order and still reproduce the
//! sequential planner's results exactly.
//!
//! Heterogeneous clusters: every kernel additionally sweeps the context's
//! candidate stage→slot placements (capacity-ranked first, identity
//! second) and prices each stage on its assigned island — per-stage memory
//! budgets in the DP, per-stage FLOP rates in the seeds. Homogeneous
//! clusters have a single identity placement, so their evaluation counts,
//! plans and traces are untouched.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::cost::estimator::LayerCost;
use crate::cost::pipeline::plan_cost_full;
use crate::model::{ModelProfile, TrainConfig};
use crate::parallel::ParallelPlan;
use crate::search::base::{LayerDiag, SearchConfig, SearchOutcome};
use crate::search::bmw::{adjust_candidates, memory_balanced_partition_budgeted, proxy_stage_stats};
use crate::search::dp::{dp_stage_search, DpStageInput};
use crate::search::partition::{even_partition, rated_balanced_partition};

use super::cache::DpMemoKey;
use super::trace::CellTrace;
use super::{PartitionKind, PpContext};

/// Result of one cell: its local best plan plus the counters the ordered
/// reduction and the [`super::SearchTrace`] need.
pub(crate) struct CellOutcome {
    pub batch: usize,
    pub pp: usize,
    /// Partition evaluations attempted (DP runs composed into plans).
    pub evaluations: usize,
    /// Whether any evaluation was memory-feasible.
    pub feasible: bool,
    /// Best outcome in this cell (ties keep the earliest, matching the
    /// sequential sweep's strictly-greater update rule).
    pub best: Option<SearchOutcome>,
    /// Evaluations short-circuited by the optimistic lower bound (the skip
    /// is byte-neutral: a skipped candidate provably cannot beat the
    /// incumbent, see [`evaluate_partition_cached`]). Diagnostics only —
    /// never serialized.
    pub lb_skips: u64,
    /// DP transition attempts across this cell's stage searches
    /// (diagnostics only — never serialized).
    pub dp_states: u64,
}

impl CellOutcome {
    fn new(batch: usize, pp: usize) -> CellOutcome {
        CellOutcome {
            batch,
            pp,
            evaluations: 0,
            feasible: false,
            best: None,
            lb_skips: 0,
            dp_states: 0,
        }
    }

    /// Keep `out` iff strictly better than the current cell best.
    fn offer(&mut self, out: SearchOutcome) {
        if self.best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
            self.best = Some(out);
        }
    }

    pub(crate) fn to_trace(&self, discarded: bool) -> CellTrace {
        CellTrace {
            batch: self.batch,
            pp: self.pp,
            evaluations: self.evaluations,
            feasible: self.feasible,
            best_throughput: self.best.as_ref().map(|o| o.throughput()),
            discarded,
        }
    }
}

/// Strategy-agnostic per-layer weights for the initial partitions
/// (Strategy_Init: memory under an even split of states across the
/// group) — shared by the BMW seed partition and the Table V ablations.
/// Activation bytes scale with the training dtype and model-state bytes
/// with the dtype/optimizer; the default train config reproduces the
/// historical fp32/Adam weights bit-for-bit.
fn strategy_init_weights(
    model: &ModelProfile,
    group: usize,
    b_m: f64,
    train: TrainConfig,
) -> (Vec<f64>, Vec<f64>) {
    let act_scale = train.act_scale();
    let state_bytes = train.unsharded_state_bytes();
    let act_w = model
        .layers
        .iter()
        .map(|l| l.act_bytes * act_scale * b_m / group as f64)
        .collect();
    let ms_w = (0..model.n_layers())
        .map(|i| (model.layers[i].params + model.extra_params(i)) * state_bytes / group as f64)
        .collect();
    (act_w, ms_w)
}

/// Per-stage memory budgets and FLOP rates of a placement.
fn placement_budgets(ctx: &PpContext, placement: &[usize]) -> (Vec<f64>, Vec<f64>) {
    let budgets = placement.iter().map(|&s| ctx.sites[s].gpu.mem_bytes).collect();
    let rates = placement.iter().map(|&s| ctx.sites[s].gpu.flops).collect();
    (budgets, rates)
}

/// Microbatch-count candidates under the config's accumulation cap —
/// computed once per cell (every kernel hoists it out of its placement
/// sweep) and deduplicated (the candidate list is strictly increasing and
/// the cap fallback only fires on an empty list, so this is belt and
/// braces against future candidate generators).
fn microbatch_options(cfg: &SearchConfig, batch: usize, pp: usize) -> Vec<usize> {
    let mut mbs = crate::search::microbatch_candidates(batch, pp);
    if let Some(cap) = cfg.microbatch_limit {
        mbs.retain(|&m| m <= cap);
        if mbs.is_empty() {
            mbs.push(cap.min(batch));
        }
    }
    mbs.dedup();
    mbs
}

/// Cache-aware port of `search::base::evaluate_partition`: run the stage
/// DPs over the precomputed candidate catalog — each stage against its
/// placed island's budget and cost class — and compose the plan.
///
/// The stage searches consume the cache's memoized `StageMatrices` bundles
/// (built once per (site class, group, b_m) for the whole run), feeding the
/// flat [`dp_stage_search`] kernel with dominance pruning and reachability
/// bounds when the engine's prune mode is on. Lookup traffic is counted at
/// bundle-request granularity *before* anything can fail, so the serialized
/// trace counters are a pure function of the evaluated (partition,
/// placement, m) set — identical with and without pruning.
///
/// `incumbent`: the best throughput this candidate must strictly beat to
/// matter. When set (callers only pass it once the cell is already
/// feasible, and never on evaluations whose diagnostics steer a search
/// trajectory, i.e. BMW's adjustment queue), an optimistic lower bound on
/// the iteration time — every layer at its cheapest catalog cost, ignoring
/// transforms, p2p and memory — may prove the candidate cannot beat it.
/// Floating-point soundness: the bound folds `fl(fwd+bwd)` mins in layer
/// order and mirrors `plan_cost_full`'s max/sum shapes, and IEEE addition
/// of non-negative extras is monotone, so `lb ≤ fl(iter_time)` and the
/// skipped throughput `batch/iter_time ≤ batch/lb ≤ incumbent` — a
/// strictly-greater offer can never be lost. DP counters still accrue.
#[allow(clippy::too_many_arguments)]
pub(crate) fn evaluate_partition_cached(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
    ctx: &PpContext,
    batch: usize,
    microbatches: usize,
    partition: &[usize],
    placement: &[usize],
    incumbent: Option<f64>,
    cell: &mut CellOutcome,
) -> Option<(SearchOutcome, Vec<LayerDiag>)> {
    if ctx.candidates.is_empty() {
        return None;
    }
    let b_m = batch as f64 / microbatches as f64;
    let classes = ctx.cache.layer_class_map();

    // Bundle fetches first: the counted traffic is fixed per (partition,
    // placement, m) regardless of skips, DP misses or pruning.
    let stage_mats: Vec<_> = partition
        .iter()
        .enumerate()
        .map(|(s, &count)| {
            let site = &ctx.sites[placement[s]];
            ctx.cache.stage_matrices(site.class, ctx.group, b_m, count, &ctx.candidates, model)
        })
        .collect();

    if ctx.cache.prune() {
        if let Some(thr) = incumbent {
            let m_f = microbatches as f64;
            let mut max_nosync = 0.0f64;
            let mut sum_sync = 0.0f64;
            let mut start = 0usize;
            for (s, &count) in partition.iter().enumerate() {
                let mats = &stage_mats[s];
                let mut nosync = 0.0f64;
                let mut sync = 0.0f64;
                for i in start..start + count {
                    nosync += mats.min_step[classes[i] as usize];
                    sync += mats.min_step_sync[classes[i] as usize];
                }
                max_nosync = max_nosync.max(nosync);
                sum_sync += sync;
                start += count;
            }
            let iter_lb = (m_f - 1.0) * max_nosync + sum_sync;
            // NaN/zero-safe: both comparisons false → no skip.
            if iter_lb > 0.0 && batch as f64 / iter_lb <= thr {
                cell.lb_skips += 1;
                return None;
            }
        }
    }

    let mut strategies = Vec::with_capacity(model.n_layers());
    let mut diags = Vec::with_capacity(model.n_layers());
    let mut start = 0usize;
    let mut dp_failed = false;
    for (s, &count) in partition.iter().enumerate() {
        let site = &ctx.sites[placement[s]];
        let mats = &stage_mats[s];
        let live = cfg.schedule.live_microbatches(s, ctx.pp, microbatches);
        // A stage DP is a pure function of this key (granularity and the
        // catalog are run-fixed): memoize it run-wide so the BMW queue's
        // ±1-layer boundary shifts and recurring b_m values across the
        // batch sweep re-solve in O(1). Memo hits replay the solve's state
        // count, keeping the diagnostics counter thread-independent.
        let memo_key: Option<DpMemoKey> = ctx.cache.prune().then(|| {
            (
                site.class,
                ctx.group as u64,
                b_m.to_bits(),
                microbatches as u64,
                live as u64,
                site.gpu.mem_bytes.to_bits(),
                classes[start..start + count].to_vec(),
            )
        });
        let entry = match memo_key.as_ref().and_then(|k| ctx.cache.dp_memo_get(k)) {
            Some(hit) => hit,
            None => {
                let layer_costs: Vec<&[LayerCost]> = (start..start + count)
                    .map(|i| mats.class_costs[classes[i] as usize].as_slice())
                    .collect();
                let layer_transforms: Vec<&[Vec<f64>]> = (start..start + count)
                    .map(|i| mats.class_transforms[classes[i] as usize].as_slice())
                    .collect();
                let (res, states) = dp_stage_search(&DpStageInput {
                    strategies: &ctx.candidates,
                    active: &mats.active,
                    class_of: &mats.class_of,
                    nc: mats.splits.len(),
                    layer_costs,
                    layer_transforms,
                    microbatches,
                    live_mb: live,
                    mem_budget: site.gpu.mem_bytes,
                    granularity: cfg.granularity,
                    bounds: ctx.cache.prune(),
                });
                let solved = Arc::new((res, states));
                match memo_key {
                    Some(k) => ctx.cache.dp_memo_put(k, solved),
                    None => solved,
                }
            }
        };
        cell.dp_states += entry.1;
        let Some(res) = entry.0.as_ref() else {
            dp_failed = true;
            break;
        };
        // Diagnostics straight from the bundle rows the DP chose — the
        // same `LayerCost` values the memoized per-layer path returned.
        for (k, &j) in res.choice.iter().enumerate() {
            let c = &mats.class_costs[classes[start + k] as usize][j];
            diags.push(LayerDiag { time: c.fwd + c.bwd, mem: c.mem });
        }
        strategies.extend(res.strategies.iter().cloned());
        start += count;
    }
    if dp_failed {
        return None;
    }

    let plan = ParallelPlan {
        pp: ctx.pp,
        partition: partition.to_vec(),
        strategies,
        batch,
        microbatches,
        stage_slots: if cluster.is_homogeneous() { None } else { Some(placement.to_vec()) },
    };
    let cost = plan_cost_full(
        model,
        cluster,
        &plan,
        cfg.schedule,
        cfg.overlap_slowdown,
        cfg.train,
        &cfg.cost_model,
    );
    if !cost.feasible {
        return None;
    }
    Some((SearchOutcome { plan, cost }, diags))
}

/// Galvatron-Base cell: even partition, quasi-convex microbatch sweep.
pub(crate) fn eval_even_cell(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
    ctx: &PpContext,
    batch: usize,
) -> CellOutcome {
    let mut cell = CellOutcome::new(batch, ctx.pp);
    if ctx.candidates.is_empty() {
        // Catalog mismatch (fixed strategy of another group size): nothing
        // to evaluate — 0 evaluations, so the trace never counts it as OOM.
        return cell;
    }
    let partition = even_partition(model.n_layers(), ctx.pp);
    let mut worse_streak = 0usize;
    let mut best_mb: Option<f64> = None;
    let mb_options = microbatch_options(cfg, batch, ctx.pp);
    for m in mb_options {
        // Best over the candidate placements for this microbatch count
        // (single identity placement on homogeneous clusters).
        let mut m_best: Option<SearchOutcome> = None;
        for placement in &ctx.placements {
            cell.evaluations += 1;
            // Incumbent: anything this placement must strictly beat to
            // affect `m_best`, `best_mb` or the cell best — the running
            // max of both. Beaten candidates can be lower-bound skipped
            // without changing any outcome or counter the trace keeps.
            let incumbent = match (best_mb, m_best.as_ref().map(SearchOutcome::throughput)) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            };
            if let Some((out, _)) = evaluate_partition_cached(
                model, cluster, cfg, ctx, batch, m, &partition, placement, incumbent, &mut cell,
            ) {
                cell.feasible = true;
                if m_best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                    m_best = Some(out);
                }
            }
        }
        match m_best {
            Some(out) => {
                let t = out.throughput();
                if best_mb.map_or(true, |b| t > b) {
                    best_mb = Some(t);
                    worse_streak = 0;
                } else {
                    worse_streak += 1;
                }
                cell.offer(out);
            }
            None => worse_streak += 1,
        }
        if worse_streak >= 2 {
            break; // microbatch cost is quasi-convex; stop early
        }
    }
    cell
}

/// Galvatron-BMW cell: Algorithm 2's boundary-adjustment queue for every
/// microbatch count (and candidate placement) of this (batch, PP) cell.
pub(crate) fn eval_bmw_cell(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
    ctx: &PpContext,
    batch: usize,
    flops_w: &[f64],
) -> CellOutcome {
    let n_layers = model.n_layers();
    let mut cell = CellOutcome::new(batch, ctx.pp);
    if ctx.candidates.is_empty() {
        return cell;
    }
    let pp = ctx.pp;

    if pp < 2 && cfg.pp_degrees.is_none() {
        // Algorithm 2 line 5 iterates P in {2,4,...}; P=1 has no pipeline
        // to balance — still evaluate it via the even path so pure
        // intra-stage plans are not lost.
        let mb_options = microbatch_options(cfg, batch, 1);
        for m in mb_options {
            for placement in &ctx.placements {
                cell.evaluations += 1;
                let incumbent = cell.best.as_ref().map(SearchOutcome::throughput);
                if let Some((out, _)) = evaluate_partition_cached(
                    model,
                    cluster,
                    cfg,
                    ctx,
                    batch,
                    m,
                    &[n_layers],
                    placement,
                    incumbent,
                    &mut cell,
                ) {
                    cell.feasible = true;
                    cell.offer(out);
                }
            }
        }
        return cell;
    }

    let group = ctx.group;
    let mb_options = microbatch_options(cfg, batch, pp);
    for m in mb_options {
        let b_m = batch as f64 / m as f64;
        let (act_w, ms_w) = strategy_init_weights(model, group, b_m, cfg.train);
        for placement in &ctx.placements {
            let (budgets, rates) = placement_budgets(ctx, placement);
            // Seeds re-derived against the placement's budgets/rates: p_m
            // balances per-island memory utilization, p_t per-island
            // normalized time (both reduce to the original homogeneous
            // partitions under uniform budgets/rates).
            let p_m = memory_balanced_partition_budgeted(
                &act_w, &ms_w, pp, m, cfg.schedule, &budgets,
            );
            let p_t = rated_balanced_partition(flops_w, pp, &rates);

            let mut queue: VecDeque<Vec<usize>> = VecDeque::new();
            let mut visited: Vec<Vec<usize>> = Vec::new();
            // Seed with p_m (Algorithm 2 line 7); also evaluate the even and
            // time-balanced partitions so BMW's answer is never worse than
            // Galvatron-Base's for the same (B,P,m).
            queue.push_back(p_m.clone());
            queue.push_back(even_partition(n_layers, pp));
            queue.push_back(p_t.clone());
            let max_iters = 4 * n_layers;
            let mut iters = 0usize;
            let mut local_best_tp = f64::NEG_INFINITY;
            let mut stale = 0usize;

            while let Some(part) = queue.pop_front() {
                iters += 1;
                if iters > max_iters {
                    break;
                }
                if visited.contains(&part) {
                    continue;
                }
                visited.push(part.clone());
                cell.evaluations += 1;
                // Never lower-bound skip here: the diagnostics of *every*
                // evaluated partition steer the adjustment queue, so a
                // skip could change the search trajectory.
                let Some((out, diags)) = evaluate_partition_cached(
                    model, cluster, cfg, ctx, batch, m, &part, placement, None, &mut cell,
                ) else {
                    continue;
                };
                cell.feasible = true;
                if out.throughput() > local_best_tp {
                    local_best_tp = out.throughput();
                    stale = 0;
                } else {
                    stale += 1;
                    if stale > 6 {
                        break;
                    }
                }
                cell.offer(out);

                // Adjustment (Algorithm 2 line 13-15).
                let (times, _mems) = proxy_stage_stats(&diags, &part, m, cfg.schedule);
                let c_max = times.iter().cloned().fold(0.0, f64::max);
                let slowest = times
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                // Validation limit (3): max stage memory under p_t.
                let (_, mems_pt) = proxy_stage_stats(&diags, &p_t, m, cfg.schedule);
                let mem_cap_pt = mems_pt.iter().cloned().fold(0.0, f64::max);
                for cand in adjust_candidates(&part, slowest) {
                    if visited.contains(&cand) {
                        continue;
                    }
                    let (t2, m2) = proxy_stage_stats(&diags, &cand, m, cfg.schedule);
                    let cond1 = t2.iter().cloned().fold(0.0, f64::max) <= c_max + 1e-12;
                    // (2)/(3) against each stage's *assigned island* budget
                    // — the heterogeneous form of the Eq. 7/8 sandwich.
                    let cond2 = m2.iter().zip(&budgets).all(|(&x, &b)| x <= b);
                    let cond3 =
                        m2.iter().zip(&budgets).all(|(&x, &b)| x <= mem_cap_pt.max(b));
                    if cond1 && cond2 && cond3 {
                        queue.push_back(cand);
                    }
                }
            }
        }
    }
    cell
}

/// Table V ablation cell: fixed memory- or time-balanced partition, no
/// adjustment loop (pipeline degrees below 2 have nothing to balance).
pub(crate) fn eval_fixed_cell(
    kind: PartitionKind,
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
    ctx: &PpContext,
    batch: usize,
    flops_w: &[f64],
) -> CellOutcome {
    let mut cell = CellOutcome::new(batch, ctx.pp);
    if ctx.pp < 2 || ctx.candidates.is_empty() {
        return cell;
    }
    let group = ctx.group;
    let mb_options = microbatch_options(cfg, batch, ctx.pp);
    for m in mb_options {
        for placement in &ctx.placements {
            let (budgets, rates) = placement_budgets(ctx, placement);
            let partition = match kind {
                PartitionKind::TimeBalanced => rated_balanced_partition(flops_w, ctx.pp, &rates),
                PartitionKind::MemoryBalanced => {
                    let b_m = batch as f64 / m as f64;
                    let (act_w, ms_w) = strategy_init_weights(model, group, b_m, cfg.train);
                    memory_balanced_partition_budgeted(
                        &act_w, &ms_w, ctx.pp, m, cfg.schedule, &budgets,
                    )
                }
            };
            cell.evaluations += 1;
            let incumbent = cell.best.as_ref().map(SearchOutcome::throughput);
            if let Some((out, _)) = evaluate_partition_cached(
                model, cluster, cfg, ctx, batch, m, &partition, placement, incumbent, &mut cell,
            ) {
                cell.feasible = true;
                cell.offer(out);
            }
        }
    }
    cell
}
