//! Cross-run persistence for the planner's memoized cost tables and for
//! whole plan artifacts (the `--cache-dir` / `GALVATRON_CACHE_DIR`
//! feature).
//!
//! Two kinds of entries live in a cache directory:
//!
//!   * `costs-<context>.bin` — the [`super::cache::CostCache`] layer-cost
//!     and transform tables of one *cost context*, a length-prefixed
//!     little-endian binary with a versioned header. The context
//!     fingerprint ([`context_fingerprint`]) hashes everything a memoized
//!     cost value can depend on beyond its own key: the model's layer
//!     profiles and attributed embedding/head params, the inter-island
//!     link bandwidth, the overlap slowdown, the training numerics, and
//!     the cost-model provenance fingerprint. Island composition lives in
//!     the per-record site fingerprints instead, so clusters that differ
//!     only in which islands they assemble — a fleet sweep, a degraded
//!     replan — share one cost file. Anything else (batch caps,
//!     schedules, thread counts, search spaces) only selects *which* keys
//!     are queried, never their values, so runs that differ only in those
//!     share one cost file too.
//!   * `plan-<request>.json` — a whole serialized
//!     [`crate::api::PlanReport`] keyed by a request fingerprint computed
//!     in `api::request`: an identical `PlanRequest` returns its artifact
//!     without searching at all (the warm-start path for daemons and
//!     sweeps).
//!
//! Site classes are run-local ids (assigned by the engine's registry in
//! discovery order, which depends on the explored PP degrees), so the
//! persisted keys replace them with stable *site fingerprints*
//! ([`site_fingerprint`]) and the loader translates back into whatever ids
//! the current run assigned. Entries for sites the current run does not
//! use are preserved across a flush, never dropped.
//!
//! Failure policy: a missing file is a cold start; a corrupt, truncated,
//! version-skewed or fingerprint-mismatched file is *ignored with a
//! warning* and planning proceeds cold — the cache can never change a
//! plan, only its wall time. Writes go through a temp file + atomic rename
//! and degrade to a warning on IO errors.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::cluster::{ClusterSpec, StageSite};
use crate::cost::calibration::fnv1a64;
use crate::cost::estimator::LayerCost;
use crate::model::ModelProfile;
use crate::parallel::memory::LayerMemory;
use crate::search::base::SearchConfig;
use crate::util::json::Json;

use super::cache::{LayerKey, TransformKey};

/// Bump when the binary layout of `costs-*.bin` changes.
const COST_FILE_VERSION: u32 = 1;
/// Bump when the JSON layout of `plan-*.json` changes.
const PLAN_FILE_VERSION: u64 = 1;
const COST_MAGIC: &[u8; 4] = b"GVCC";

fn warn(msg: &str) {
    crate::util::diag::warn(msg);
}

// ---- fingerprints ---------------------------------------------------------

/// Byte-accumulating FNV-1a hasher over heterogeneous fields.
#[derive(Default)]
pub(crate) struct Fingerprint {
    buf: Vec<u8>,
}

impl Fingerprint {
    pub(crate) fn new() -> Fingerprint {
        Fingerprint::default()
    }

    pub(crate) fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub(crate) fn usize(&mut self, v: usize) -> &mut Self {
        self.u64(v as u64)
    }

    pub(crate) fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub(crate) fn str(&mut self, s: &str) -> &mut Self {
        // Length-prefix so concatenated strings cannot alias.
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    pub(crate) fn finish(&self) -> u64 {
        fnv1a64(&self.buf)
    }
}

/// Stable content fingerprint of one site class of the engine's run-wide
/// registry. `saturated` marks classes whose intra-island limit covers
/// every group the class prices (their effective bandwidth profile is
/// constant `intra_bw`), which is what lets the registry merge them across
/// PP degrees; the concrete limit is hashed only for unsaturated sites.
pub(crate) fn site_fingerprint(site: &StageSite, saturated: bool) -> u64 {
    let mut fp = Fingerprint::new();
    fp.str(&site.gpu.name).f64(site.gpu.mem_bytes).f64(site.gpu.flops).f64(site.intra_bw);
    if saturated {
        fp.u64(u64::MAX);
    } else {
        fp.usize(site.intra_limit);
    }
    fp.finish()
}

/// Fold a model's cost-relevant content (layer profiles + attributed
/// embedding/head params) into `fp`. Names are deliberately excluded:
/// they never enter a cost formula.
pub(crate) fn hash_model(fp: &mut Fingerprint, model: &ModelProfile) {
    fp.usize(model.n_layers());
    for (i, l) in model.layers.iter().enumerate() {
        fp.usize(l.hidden)
            .usize(l.seq)
            .usize(l.heads)
            .usize(l.kv_seq)
            .f64(l.params)
            .f64(l.flops_fwd)
            .f64(l.act_bytes)
            .f64(l.bnd_bytes)
            .f64(model.extra_params(i));
    }
}

/// Fold a cluster's full content (islands, budgets, links) into `fp`.
/// Used by the *request* fingerprint (whole-plan entries are cluster
/// specific); the cost-table context deliberately hashes only `inter_bw`
/// — see [`context_fingerprint`].
pub(crate) fn hash_cluster(fp: &mut Fingerprint, cluster: &ClusterSpec) {
    fp.usize(cluster.islands.len());
    for isl in &cluster.islands {
        fp.str(&isl.gpu.name)
            .f64(isl.gpu.mem_bytes)
            .f64(isl.gpu.flops)
            .usize(isl.count)
            .f64(isl.intra_bw);
    }
    fp.f64(cluster.inter_bw);
}

/// Fold training numerics into `fp` (dtype/optimizer/ZeRO all change
/// memoized memory terms).
pub(crate) fn hash_train(fp: &mut Fingerprint, train: &crate::model::TrainConfig) {
    fp.u64(train.dtype as u64).u64(train.optimizer as u64).u64(u64::from(train.zero));
}

/// Fingerprint of everything a memoized cost value depends on *beyond its
/// own key*. Two runs with equal context fingerprints may share cost
/// tables; anything that could change a cached value (model content, the
/// inter-island link, overlap, training numerics, cost-model backend)
/// changes the fingerprint and therefore the cache file.
///
/// The cluster's island composition is deliberately **not** hashed: every
/// persisted record already carries a stable site fingerprint
/// ([`site_fingerprint`]: gpu class, memory budget, FLOP rate, intra bus,
/// saturation/limit), which is the only way island content reaches a
/// memoized value. The single remaining cluster-global input is
/// `inter_bw` — an unsaturated site prices communication groups that
/// spill past its intra limit on the inter-island link. (Pipeline p2p
/// reads the full topology but is never cached.) Clusters that differ
/// only in island composition — a fleet sweep, a degraded replan —
/// therefore share one cost file, and records for island classes both
/// clusters contain warm-start every member of the sweep. Batch caps,
/// schedules, search spaces and thread counts only select *which* keys
/// are queried, never their values, so they are excluded too.
pub fn context_fingerprint(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    cfg: &SearchConfig,
) -> u64 {
    let mut fp = Fingerprint::new();
    fp.u64(u64::from(COST_FILE_VERSION));
    hash_model(&mut fp, model);
    fp.f64(cluster.inter_bw);
    fp.f64(cfg.overlap_slowdown);
    hash_train(&mut fp, &cfg.train);
    fp.u64(cfg.cost_model.cache_fingerprint());
    fp.finish()
}

// ---- file paths -----------------------------------------------------------

/// Path of the cost-table file for one context fingerprint.
pub fn cost_file_path(dir: &Path, context_fp: u64) -> PathBuf {
    dir.join(format!("costs-{context_fp:016x}.bin"))
}

/// Path of the persisted plan artifact for one request fingerprint.
pub fn plan_file_path(dir: &Path, request_fp: u64) -> PathBuf {
    dir.join(format!("plan-{request_fp:016x}.json"))
}

// ---- binary encode/decode -------------------------------------------------

/// Raw persisted tables, keyed by (provenance, site *fingerprint*, ...) —
/// the stable on-disk form of the cache's run-local keys.
#[derive(Default)]
pub(crate) struct CostStore {
    pub(crate) layer: HashMap<(u64, u64, u32, u64, u64, u64), LayerCost>,
    pub(crate) transforms: HashMap<(u64, u64, u32, u64, u64), f64>,
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Reader<'_> {
    fn u32(&mut self) -> Option<u32> {
        let s = self.b.get(self.pos..self.pos + 4)?;
        self.pos += 4;
        Some(u32::from_le_bytes(s.try_into().ok()?))
    }

    fn u64(&mut self) -> Option<u64> {
        let s = self.b.get(self.pos..self.pos + 8)?;
        self.pos += 8;
        Some(u64::from_le_bytes(s.try_into().ok()?))
    }

    fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }
}

fn encode_cost_store(context_fp: u64, store: &CostStore) -> Vec<u8> {
    let mut buf = Vec::with_capacity(
        24 + 4 + store.layer.len() * 92 + store.transforms.len() * 44,
    );
    buf.extend_from_slice(COST_MAGIC);
    push_u32(&mut buf, COST_FILE_VERSION);
    push_u64(&mut buf, context_fp);
    push_u64(&mut buf, store.layer.len() as u64);
    push_u64(&mut buf, store.transforms.len() as u64);
    // Deterministic record order so identical stores encode identically.
    let mut layer: Vec<_> = store.layer.iter().collect();
    layer.sort_unstable_by_key(|(k, _)| **k);
    for (&(prov, site_fp, class, b_m, extra, strat), c) in layer {
        push_u64(&mut buf, prov);
        push_u64(&mut buf, site_fp);
        push_u32(&mut buf, class);
        push_u64(&mut buf, b_m);
        push_u64(&mut buf, extra);
        push_u64(&mut buf, strat);
        for v in [c.fwd, c.bwd, c.bwd_sync, c.mem.o_ms, c.mem.o_f, c.mem.o_b] {
            push_u64(&mut buf, v.to_bits());
        }
    }
    let mut transforms: Vec<_> = store.transforms.iter().collect();
    transforms.sort_unstable_by_key(|(k, _)| **k);
    for (&(prov, site_fp, class, b_m, splits), r) in transforms {
        push_u64(&mut buf, prov);
        push_u64(&mut buf, site_fp);
        push_u32(&mut buf, class);
        push_u64(&mut buf, b_m);
        push_u64(&mut buf, splits);
        push_u64(&mut buf, r.to_bits());
    }
    buf
}

fn decode_cost_store(bytes: &[u8], context_fp: u64) -> Result<CostStore, &'static str> {
    if bytes.get(..4) != Some(COST_MAGIC.as_slice()) {
        return Err("bad magic");
    }
    let mut r = Reader { b: bytes, pos: 4 };
    let version = r.u32().ok_or("truncated header")?;
    if version != COST_FILE_VERSION {
        return Err("version mismatch");
    }
    let fp = r.u64().ok_or("truncated header")?;
    if fp != context_fp {
        return Err("context fingerprint mismatch");
    }
    let n_layer = r.u64().ok_or("truncated header")?;
    let n_transform = r.u64().ok_or("truncated header")?;
    let expect = r.pos as u64 + n_layer * 92 + n_transform * 44;
    if bytes.len() as u64 != expect {
        return Err("truncated or oversized body");
    }
    let mut store = CostStore::default();
    for _ in 0..n_layer {
        let key = (
            r.u64().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
            r.u32().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
        );
        let cost = LayerCost {
            fwd: r.f64().ok_or("truncated record")?,
            bwd: r.f64().ok_or("truncated record")?,
            bwd_sync: r.f64().ok_or("truncated record")?,
            mem: LayerMemory {
                o_ms: r.f64().ok_or("truncated record")?,
                o_f: r.f64().ok_or("truncated record")?,
                o_b: r.f64().ok_or("truncated record")?,
            },
        };
        store.layer.insert(key, cost);
    }
    for _ in 0..n_transform {
        let key = (
            r.u64().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
            r.u32().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
            r.u64().ok_or("truncated record")?,
        );
        store.transforms.insert(key, r.f64().ok_or("truncated record")?);
    }
    Ok(store)
}

/// Write `bytes` to `path` atomically (temp file in the same directory +
/// rename), creating the directory if needed. Warns instead of failing.
fn write_atomic(path: &Path, bytes: &[u8]) {
    let Some(dir) = path.parent() else {
        warn(&format!("planner cache path {} has no parent directory", path.display()));
        return;
    };
    if let Err(e) = std::fs::create_dir_all(dir) {
        warn(&format!("could not create planner cache dir {}: {e}", dir.display()));
        return;
    }
    // pid + per-process counter: two threads of one process (or two
    // processes) writing the same target never share a temp file, so a
    // rename can only ever publish one writer's complete bytes.
    static TMP_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let tmp = dir.join(format!(
        ".{}.{}.{}.tmp",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("cache-entry"),
        std::process::id(),
        TMP_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    if let Err(e) = std::fs::write(&tmp, bytes) {
        warn(&format!("could not write planner cache file {}: {e}", tmp.display()));
        return;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        warn(&format!("could not publish planner cache file {}: {e}", path.display()));
        let _ = std::fs::remove_file(&tmp);
    }
}

// ---- flush lock -----------------------------------------------------------

/// Advisory cross-process lock around the read→merge→write window of
/// [`PersistHandle::flush`]. Without it, two writers that both read the
/// store before either renamed would each publish a merge missing the
/// other's entries — last rename wins, earlier writer's work silently
/// dropped.
///
/// Implemented as an `O_EXCL` lock file next to the store (the only
/// advisory lock std offers portably). Acquisition waits up to ~2s in
/// 10ms steps; a lock file older than 10s is presumed abandoned by a
/// crashed process and stolen. On timeout the caller proceeds unlocked
/// with a warning — the cache is an accelerator, never a gate, and an
/// unlocked merge can at worst drop another writer's newest entries
/// (exactly the historical behavior).
struct FlushLock {
    path: PathBuf,
    held: bool,
}

impl FlushLock {
    fn acquire(path: PathBuf) -> FlushLock {
        const ATTEMPTS: u32 = 200;
        const STEP: std::time::Duration = std::time::Duration::from_millis(10);
        const STALE: std::time::Duration = std::time::Duration::from_secs(10);
        for _ in 0..ATTEMPTS {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(_) => return FlushLock { path, held: true },
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .is_some_and(|age| age > STALE);
                    if stale {
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    std::thread::sleep(STEP);
                }
                // Unwritable/missing directory etc: the flush itself will
                // surface its own warning; don't spin on a dead path.
                Err(_) => break,
            }
        }
        warn(&format!(
            "could not take planner cache lock {} (proceeding unlocked)",
            path.display()
        ));
        FlushLock { path, held: false }
    }
}

impl Drop for FlushLock {
    fn drop(&mut self) {
        if self.held {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

// ---- the cost-table handle ------------------------------------------------

/// Binding of one engine run to its persistent cost file: the directory,
/// the run's context fingerprint, and the map from run-local site class
/// ids to stable site fingerprints.
pub struct PersistHandle {
    dir: PathBuf,
    context_fp: u64,
    site_fps: Vec<u64>,
}

impl PersistHandle {
    pub fn new(dir: PathBuf, context_fp: u64, site_fps: Vec<u64>) -> PersistHandle {
        PersistHandle { dir, context_fp, site_fps }
    }

    fn read_store(&self) -> Option<CostStore> {
        let path = cost_file_path(&self.dir, self.context_fp);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                warn(&format!("could not read planner cache file {}: {e}", path.display()));
                return None;
            }
        };
        match decode_cost_store(&bytes, self.context_fp) {
            Ok(store) => Some(store),
            Err(reason) => {
                warn(&format!(
                    "ignoring planner cache file {} ({reason}); planning cold",
                    path.display()
                ));
                None
            }
        }
    }

    /// Load the persisted tables, translated to this run's site class ids.
    /// Entries for sites or cost-model provenances the run does not use
    /// are skipped (they stay on disk). Returns `(warm_start, ...)`.
    pub(crate) fn load(
        &self,
        provenance: u64,
    ) -> (bool, HashMap<LayerKey, LayerCost>, HashMap<TransformKey, f64>) {
        let Some(store) = self.read_store() else {
            return (false, HashMap::new(), HashMap::new());
        };
        let class_of = |site_fp: u64| -> Option<u32> {
            self.site_fps.iter().position(|&fp| fp == site_fp).map(|i| i as u32)
        };
        let mut layer = HashMap::with_capacity(store.layer.len());
        for (&(prov, site_fp, class, b_m, extra, strat), &c) in &store.layer {
            if prov != provenance {
                continue;
            }
            if let Some(site) = class_of(site_fp) {
                layer.insert((prov, site, class, b_m, extra, strat), c);
            }
        }
        let mut transforms = HashMap::with_capacity(store.transforms.len());
        for (&(prov, site_fp, class, b_m, splits), &r) in &store.transforms {
            if prov != provenance {
                continue;
            }
            if let Some(site) = class_of(site_fp) {
                transforms.insert((prov, site, class, b_m, splits), r);
            }
        }
        (true, layer, transforms)
    }

    /// Merge this run's tables into the on-disk store (union with whatever
    /// is there). The read→merge→write window is serialized by an
    /// advisory lock file so concurrent flushes — threads of one serve
    /// daemon or separate CLI processes — each see the other's entries:
    /// the last writer includes all.
    pub(crate) fn flush(
        &self,
        layer: &HashMap<LayerKey, LayerCost>,
        transforms: &HashMap<TransformKey, f64>,
    ) {
        // The lock file needs the directory to exist; write_atomic would
        // create it anyway, just later.
        if let Err(e) = std::fs::create_dir_all(&self.dir) {
            warn(&format!(
                "could not create planner cache dir {}: {e}",
                self.dir.display()
            ));
            return;
        }
        let _lock =
            FlushLock::acquire(self.dir.join(format!(".costs-{:016x}.lock", self.context_fp)));
        let mut store = self.read_store().unwrap_or_default();
        let before = store.layer.len() + store.transforms.len();
        for (&(prov, site, class, b_m, extra, strat), &c) in layer {
            let site_fp = self.site_fps[site as usize];
            store.layer.insert((prov, site_fp, class, b_m, extra, strat), c);
        }
        for (&(prov, site, class, b_m, splits), &r) in transforms {
            let site_fp = self.site_fps[site as usize];
            store.transforms.insert((prov, site_fp, class, b_m, splits), r);
        }
        if store.layer.len() + store.transforms.len() == before && before > 0 {
            // Nothing new to say: don't churn the file (keeps warm re-runs
            // read-only, which also keeps them fast).
            return;
        }
        let bytes = encode_cost_store(self.context_fp, &store);
        write_atomic(&cost_file_path(&self.dir, self.context_fp), &bytes);
    }
}

// ---- whole-plan entries ---------------------------------------------------

/// Load a persisted plan artifact for `request_fp`. Returns the embedded
/// report JSON value, or `None` (with a warning unless simply absent).
pub fn load_plan_entry(dir: &Path, request_fp: u64) -> Option<Json> {
    let path = plan_file_path(dir, request_fp);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
        Err(e) => {
            warn(&format!("could not read planner cache file {}: {e}", path.display()));
            return None;
        }
    };
    let invalid = |reason: &str| {
        warn(&format!(
            "ignoring planner cache file {} ({reason}); planning cold",
            path.display()
        ));
    };
    let v = match Json::parse(&text) {
        Ok(v) => v,
        Err(_) => {
            invalid("not valid JSON");
            return None;
        }
    };
    match v.get("version").and_then(Json::as_f64) {
        Some(ver) if ver == PLAN_FILE_VERSION as f64 => {}
        _ => {
            invalid("version mismatch");
            return None;
        }
    }
    match v.get("request_fingerprint").and_then(Json::as_str) {
        Some(fp) if fp == format!("{request_fp:016x}") => {}
        _ => {
            invalid("request fingerprint mismatch");
            return None;
        }
    }
    match v.get("report") {
        Some(report) => Some(report.clone()),
        None => {
            invalid("no report field");
            None
        }
    }
}

/// Persist a plan artifact under `request_fp` (atomic write; IO errors
/// degrade to a warning — the cache is an accelerator, never a gate).
pub fn store_plan_entry(dir: &Path, request_fp: u64, report: &Json) {
    let doc = Json::obj(vec![
        ("version", Json::num(PLAN_FILE_VERSION as f64)),
        ("request_fingerprint", Json::str(&format!("{request_fp:016x}"))),
        ("report", report.clone()),
    ]);
    write_atomic(&plan_file_path(dir, request_fp), doc.to_string().as_bytes());
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn sample_store() -> CostStore {
        let mut store = CostStore::default();
        store.layer.insert(
            (0, 7, 1, 4.5f64.to_bits(), 0.0f64.to_bits(), 0x41),
            LayerCost {
                fwd: 0.25,
                bwd: 0.5,
                bwd_sync: 0.75,
                mem: LayerMemory { o_ms: 1.0, o_f: 2.0, o_b: 3.0 },
            },
        );
        store.transforms.insert((0, 7, 1, 4.5f64.to_bits(), (2 << 32) | 4), 0.125);
        store
    }

    #[test]
    fn cost_store_binary_round_trip() {
        let store = sample_store();
        let bytes = encode_cost_store(0xdead_beef, &store);
        let back = decode_cost_store(&bytes, 0xdead_beef).unwrap();
        assert_eq!(back.layer.len(), 1);
        assert_eq!(back.transforms.len(), 1);
        let key = *store.layer.keys().next().unwrap();
        assert_eq!(back.layer[&key], store.layer[&key]);
        let tkey = *store.transforms.keys().next().unwrap();
        assert_eq!(back.transforms[&tkey].to_bits(), 0.125f64.to_bits());
        // Deterministic encoding.
        assert_eq!(bytes, encode_cost_store(0xdead_beef, &back));
    }

    #[test]
    fn decode_rejects_corruption_and_skew() {
        let bytes = encode_cost_store(1, &sample_store());
        assert!(decode_cost_store(&bytes, 2).is_err(), "fingerprint mismatch");
        assert!(decode_cost_store(&bytes[..bytes.len() - 1], 1).is_err(), "truncated");
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(decode_cost_store(&bad_magic, 1).is_err(), "magic");
        let mut bad_version = bytes.clone();
        bad_version[4] = 0xff;
        assert!(decode_cost_store(&bad_version, 1).is_err(), "version");
        assert!(decode_cost_store(&[], 1).is_err(), "empty");
    }

    #[test]
    fn fingerprint_is_order_and_length_sensitive() {
        let a = Fingerprint::new().str("ab").str("c").finish();
        let b = Fingerprint::new().str("a").str("bc").finish();
        assert_ne!(a, b, "length prefixes must prevent aliasing");
        let c = Fingerprint::new().u64(1).u64(2).finish();
        let d = Fingerprint::new().u64(2).u64(1).finish();
        assert_ne!(c, d);
    }

    #[test]
    fn concurrent_flushes_keep_every_writers_entries() {
        let dir = std::env::temp_dir()
            .join(format!("galvatron-flush-race-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        const WRITERS: u64 = 8;
        let barrier = std::sync::Barrier::new(WRITERS as usize);
        std::thread::scope(|scope| {
            for i in 0..WRITERS {
                let dir = dir.clone();
                let barrier = &barrier;
                scope.spawn(move || {
                    let handle = PersistHandle::new(dir, 0x99, vec![7]);
                    let mut layer = HashMap::new();
                    // Disjoint layer-class keys, one per writer.
                    layer.insert(
                        (0u64, 0u32, i as u32, 1.0f64.to_bits(), 0.0f64.to_bits(), i),
                        LayerCost {
                            fwd: i as f64,
                            bwd: 0.0,
                            bwd_sync: 0.0,
                            mem: LayerMemory { o_ms: 0.0, o_f: 0.0, o_b: 0.0 },
                        },
                    );
                    // All writers hit the read→merge→write window together.
                    barrier.wait();
                    handle.flush(&layer, &HashMap::new());
                });
            }
        });
        let handle = PersistHandle::new(dir.clone(), 0x99, vec![7]);
        let store = handle.read_store().unwrap_or_default();
        assert_eq!(
            store.layer.len(),
            WRITERS as usize,
            "a concurrent flush dropped another writer's entries"
        );
        // No temp or lock files may survive the flushes.
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(Result::ok)
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|name| name.ends_with(".tmp") || name.ends_with(".lock"))
            .collect();
        assert!(leftovers.is_empty(), "leftover files: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
