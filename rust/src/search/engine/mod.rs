//! The parallel memoized search engine (the planner core).
//!
//! [`SearchEngine`] drives every optimizer of the paper (Galvatron-Base,
//! Galvatron-BMW, the fixed-partition ablations) over the same skeleton:
//!
//!   1. **Precompute** — per explored PP degree, build the decision-tree
//!      candidate catalog once and bind a shared memoized cost cache
//!      ([`cache::CostCache`]) that collapses identical layers into cost
//!      classes and reuses `c(l, s)` / transform costs across every batch
//!      size, partition, and BMW boundary-adjustment step.
//!   2. **Fan out** — the independent (global-batch, PP-degree) cells of
//!      the sweep run on a `std::thread::scope` worker pool sized by
//!      [`crate::util::parallelism::resolve_worker_count`], in look-ahead
//!      waves of [`WAVE_BATCHES`] consecutive batch sizes.
//!   3. **Reduce deterministically** — results are folded in (batch, PP)
//!      enumeration order with the sequential sweep's strictly-greater
//!      update rule, and batch-sweep patience is counted over *ordered*
//!      batch sizes (never completion order), so the winning plan — and the
//!      serialized [`trace::SearchTrace`] — are bit-identical for every
//!      worker count.
//!
//! `search::base::optimize`, `search::bmw::optimize_bmw` and the
//! `api::MethodSpec` catalog are thin fronts over this engine;
//! `search::dp` remains the pure per-stage kernel.

pub mod cache;
pub mod persist;
pub mod trace;
mod cells;

pub use cache::{layer_classes, CostCache, SiteCosts};
pub use trace::{CellTrace, SearchTiming, SearchTrace};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cluster::{ClusterSpec, StageSite};
use crate::cost::CostEstimator;
use crate::model::ModelProfile;
use crate::parallel::Strategy;
use crate::search::base::{pp_degrees, stage_candidates, SearchConfig, SearchOutcome};
use crate::util::parallelism::resolve_worker_count;

use cells::CellOutcome;

/// Which fixed partition policy a [`CellAlgo::Fixed`] cell evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// Memory-balanced partition p_m (1F1B live-microbatch aware).
    MemoryBalanced,
    /// Time-balanced partition p_t (FLOPs-balanced).
    TimeBalanced,
}

/// The per-cell algorithm the engine fans out over the (batch × PP) grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellAlgo {
    /// Galvatron-Base (Algorithm 1): even partition, microbatch sweep.
    Even,
    /// Galvatron-BMW (Algorithm 2): bi-objective boundary adjustment.
    Bmw,
    /// Table V ablations: fixed balanced partition, no adjustment loop.
    Fixed(PartitionKind),
}

/// Precomputed per-PP-degree context shared by all cells of that degree:
/// stage group size, the candidate catalog, the island slot sites (their
/// `class` rewritten to *run-wide* registry ids), the candidate
/// stage→slot placements, and a handle on the run-wide memoized cost
/// cache shared by every PP degree.
pub(crate) struct PpContext {
    pub pp: usize,
    pub group: usize,
    pub candidates: Vec<Strategy>,
    /// Slot sites of this PP degree, in device order.
    pub sites: Vec<StageSite>,
    /// Candidate stage→slot assignments, deduped by slot-class signature:
    /// the capacity-ranked placement (memory-heavy early 1F1B stages on
    /// large-memory slots) first, then the identity if it differs. A
    /// homogeneous cluster collapses to the identity alone, so its cell
    /// evaluation counts — and trace — are unchanged.
    pub placements: Vec<Vec<usize>>,
    pub cache: Arc<CostCache>,
}

/// One entry of the run-wide site registry: a distinct cost signature over
/// every explored PP degree, plus its stable persistence fingerprint.
///
/// Memoized costs never read the PP binding (only p2p pricing does, and
/// p2p is never cached), so two slot sites are cost-equivalent iff their
/// device class and *effective bandwidth profile over the spans they
/// price* agree. A site whose `intra_limit` covers its whole stage group
/// (`saturated`) prices every span at `intra_bw` — all such sites with the
/// same (gpu, intra_bw) merge into one class regardless of PP degree,
/// which is what lets e.g. titan8's PP=1/2/4/8 contexts share one table.
/// Unsaturated sites (mixed-island stages that can spill to `inter_bw`)
/// merge only on an exact (gpu, intra_bw, intra_limit) match.
struct SiteClass {
    /// Representative site; for saturated classes, the member with the
    /// largest `intra_limit` seen, so the bound estimator serves every
    /// merged context's spans from the intra branch.
    site: StageSite,
    /// A PP degree the representative occurred at (any member is valid:
    /// cached costs never depend on it).
    pp: usize,
    saturated: bool,
    /// Stable content fingerprint (see [`persist::site_fingerprint`]).
    fp: u64,
}

fn register_site(registry: &mut Vec<SiteClass>, site: &StageSite, group: usize, pp: usize) -> u32 {
    let saturated = site.intra_limit >= group;
    let found = registry.iter().position(|e| {
        e.saturated == saturated
            && e.site.gpu == site.gpu
            && e.site.intra_bw == site.intra_bw
            && (saturated || e.site.intra_limit == site.intra_limit)
    });
    match found {
        Some(i) => {
            if saturated && site.intra_limit > registry[i].site.intra_limit {
                registry[i].site = site.clone();
                registry[i].pp = pp;
            }
            i as u32
        }
        None => {
            registry.push(SiteClass {
                site: site.clone(),
                pp,
                saturated,
                fp: persist::site_fingerprint(site, saturated),
            });
            (registry.len() - 1) as u32
        }
    }
}

/// Candidate stage→slot placements for one PP degree. The capacity-ranked
/// placement assigns the k-th largest-memory slot to stage k — under 1F1B
/// stage 0 holds the most live microbatches, so memory-heavy stages land
/// on large-memory islands. The stable sort keeps device order on ties,
/// which makes the ranked placement equal the identity on homogeneous
/// clusters (deduped to a single entry).
fn placement_candidates(sites: &[StageSite]) -> Vec<Vec<usize>> {
    let p = sites.len();
    let identity: Vec<usize> = (0..p).collect();
    let mut ranked = identity.clone();
    ranked.sort_by(|&a, &b| sites[b].gpu.mem_bytes.total_cmp(&sites[a].gpu.mem_bytes));
    let signature =
        |pl: &[usize]| -> Vec<u32> { pl.iter().map(|&s| sites[s].class).collect() };
    let mut out = vec![ranked];
    if signature(&identity) != signature(&out[0]) {
        out.push(identity);
    }
    out
}

/// Default prune mode when [`SearchConfig::prune`] is `None`: on, unless
/// the `GALVATRON_NO_PRUNE` environment variable is set to a non-empty
/// value other than `0`. Pruning never changes an artifact byte (every
/// skipped candidate is provably dominated or beaten); the escape hatch
/// exists so CI and the benches can measure — and byte-compare — the
/// unpruned path.
fn prune_default() -> bool {
    match std::env::var("GALVATRON_NO_PRUNE") {
        Ok(v) => v.trim().is_empty() || v.trim() == "0",
        Err(_) => true,
    }
}

/// Look-ahead window of the batch sweep: cells of this many consecutive
/// batch sizes are computed per wave. Deliberately fixed (never derived
/// from the worker count) so the set of computed cells — and therefore the
/// serialized trace — is identical for every `--threads` value. Matches
/// the default patience of 3: at most one wave of overshoot past the
/// stopping batch.
const WAVE_BATCHES: usize = 4;

/// The parallel memoized planner core. Construct per search run; borrows
/// its inputs for the run's duration.
pub struct SearchEngine<'a> {
    model: &'a ModelProfile,
    cluster: &'a ClusterSpec,
    cfg: &'a SearchConfig,
    algo: CellAlgo,
    threads: usize,
    contexts: Vec<PpContext>,
    /// The run-wide cost cache every context shares (one bound estimator
    /// per registry site class, deduplicated across PP degrees).
    cache: Arc<CostCache>,
    flops_w: Vec<f64>,
    precompute_secs: f64,
    warm_start: bool,
    persisted_entries: u64,
}

impl<'a> SearchEngine<'a> {
    pub fn new(
        model: &'a ModelProfile,
        cluster: &'a ClusterSpec,
        cfg: &'a SearchConfig,
        algo: CellAlgo,
    ) -> SearchEngine<'a> {
        let t0 = Instant::now();
        let threads = resolve_worker_count(cfg.threads);
        let classes = layer_classes(model);
        // Pass 1: build per-degree contexts against a run-wide site
        // registry, rewriting each slot's class to its registry id.
        let mut registry: Vec<SiteClass> = Vec::new();
        let mut parts: Vec<(usize, usize, Vec<Strategy>, Vec<StageSite>, Vec<Vec<usize>>)> =
            Vec::new();
        for pp in pp_degrees(model, cluster, cfg) {
            let group = cluster.n_devices() / pp;
            let candidates = stage_candidates(cfg, group);
            let mut sites = cluster.stage_sites(pp);
            for site in &mut sites {
                site.class = register_site(&mut registry, site, group, pp);
            }
            let placements = placement_candidates(&sites);
            parts.push((pp, group, candidates, sites, placements));
        }
        // Pass 2: one bound estimator per registry class, one shared cache.
        let ests: Vec<CostEstimator> = registry
            .iter()
            .map(|e| {
                CostEstimator::with_site(cluster, e.pp, cfg.overlap_slowdown, e.site.clone())
                    .with_train(cfg.train)
                    .with_cost_model(cfg.cost_model.clone())
            })
            .collect();
        let site_fps: Vec<u64> = registry.iter().map(|e| e.fp).collect();
        let mut cache = CostCache::with_sites(ests, classes);
        let (warm_start, persisted_entries) = match &cfg.cache_dir {
            Some(dir) => {
                let context_fp = persist::context_fingerprint(model, cluster, cfg);
                cache.attach_persist(persist::PersistHandle::new(
                    dir.clone(),
                    context_fp,
                    site_fps,
                ))
            }
            None => (false, 0),
        };
        cache.set_prune(cfg.prune.unwrap_or_else(prune_default));
        let cache = Arc::new(cache);
        let contexts: Vec<PpContext> = parts
            .into_iter()
            .map(|(pp, group, candidates, sites, placements)| PpContext {
                pp,
                group,
                candidates,
                sites,
                placements,
                cache: Arc::clone(&cache),
            })
            .collect();
        let flops_w = model.layers.iter().map(|l| l.flops_fwd).collect();
        let precompute_secs = t0.elapsed().as_secs_f64();
        SearchEngine {
            model,
            cluster,
            cfg,
            algo,
            threads,
            contexts,
            cache,
            flops_w,
            precompute_secs,
            warm_start,
            persisted_entries,
        }
    }

    /// Worker count this engine resolved (for diagnostics).
    pub fn worker_count(&self) -> usize {
        self.threads
    }

    /// Run the full sweep: fan cells out, reduce in order, return the best
    /// outcome (if any plan fit) plus the structured search trace.
    pub fn run(&self) -> (Option<SearchOutcome>, SearchTrace) {
        let t_run = Instant::now();
        let batches = crate::search::batch_candidates(self.cfg.max_batch);
        let per_batch = self.contexts.len();
        let mut trace = SearchTrace::default();
        let mut best: Option<SearchOutcome> = None;
        let mut infeasible_streak = 0usize;
        let mut stopped = false;

        for wave in batches.chunks(WAVE_BATCHES) {
            if stopped {
                trace.cells_skipped += wave.len() * per_batch;
                continue;
            }
            let wave_cells: Vec<(usize, usize)> = wave
                .iter()
                .flat_map(|&b| (0..per_batch).map(move |c| (b, c)))
                .collect();
            let outcomes = self.run_wave(&wave_cells);

            // Ordered reduction: batches in sweep order, PP degrees in
            // enumeration order — identical to the sequential nested loop.
            for (wi, _) in wave.iter().enumerate() {
                let slice = &outcomes[wi * per_batch..(wi + 1) * per_batch];
                if stopped {
                    // Computed in this look-ahead wave, but the patience
                    // rule already ended the sweep at an earlier batch:
                    // record the work, discard the results.
                    for (cell, secs) in slice {
                        trace.cells_discarded += 1;
                        trace.cells.push(cell.to_trace(true));
                        trace.timing.cell_secs.push((cell.batch, cell.pp, *secs));
                        trace.timing.lb_skips += cell.lb_skips;
                        trace.timing.dp_states_visited += cell.dp_states;
                    }
                    continue;
                }
                let mut any_feasible = false;
                for (cell, secs) in slice {
                    any_feasible |= cell.feasible;
                    trace.cells_explored += 1;
                    trace.evaluations += cell.evaluations;
                    if !cell.feasible && cell.evaluations > 0 {
                        trace.cells_oom += 1;
                    }
                    trace.cells.push(cell.to_trace(false));
                    trace.timing.cell_secs.push((cell.batch, cell.pp, *secs));
                    trace.timing.lb_skips += cell.lb_skips;
                    trace.timing.dp_states_visited += cell.dp_states;
                    if let Some(out) = &cell.best {
                        if best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                            best = Some(out.clone());
                            trace.best_cell = Some((cell.batch, cell.pp));
                        }
                    }
                }
                if any_feasible {
                    infeasible_streak = 0;
                } else if best.is_some() {
                    // Patience over ordered batch sizes: memory use is
                    // monotone in B, so after `patience` consecutive
                    // infeasible batches the sweep stops.
                    infeasible_streak += 1;
                    if infeasible_streak >= self.cfg.patience {
                        stopped = true;
                    }
                }
            }
        }

        // The run-wide cache is shared by every context: read its
        // statistics once (the former per-context sum double-counted
        // nothing, but there is only one cache now).
        trace.cache_lookups = self.cache.lookups();
        trace.cache_entries = self.cache.entries();
        // Persist what this run learned (no-op without a cache dir).
        self.cache.flush_persist();
        let search_secs = t_run.elapsed().as_secs_f64();
        trace.timing.precompute_secs = self.precompute_secs;
        trace.timing.search_secs = search_secs;
        trace.timing.total_secs = self.precompute_secs + search_secs;
        trace.timing.warm_start = self.warm_start;
        trace.timing.persisted_entries = self.persisted_entries;
        let (matrix_builds, candidates_pruned) = self.cache.matrix_stats();
        trace.timing.matrix_builds = matrix_builds;
        trace.timing.candidates_pruned = candidates_pruned;
        trace.timing.dp_memo_entries = self.cache.dp_memo_len();
        (best, trace)
    }

    /// Compute one wave of cells, fanning out across the worker pool.
    /// Results come back in input order regardless of completion order,
    /// each with its wall time (diagnostics only — never serialized).
    fn run_wave(&self, wave_cells: &[(usize, usize)]) -> Vec<(CellOutcome, f64)> {
        let want = self.threads.min(wave_cells.len()).max(1);
        // Under an installed process-wide budget (the serve daemon) the
        // wave's pool is capped by the workers still free, so concurrent
        // searches share the machine at wave granularity. Without one
        // (every CLI path) the grant is exactly `want`. The grant only
        // sizes the pool — cell results are thread-count-independent, so
        // the artifact bytes never change.
        let grant = crate::util::parallelism::acquire_workers(want);
        let workers = grant.workers();
        if workers <= 1 {
            return wave_cells.iter().map(|&(b, c)| self.eval_cell_timed(b, c)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<(CellOutcome, f64)>>> =
            wave_cells.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= wave_cells.len() {
                        break;
                    }
                    let (batch, ctx_idx) = wave_cells[i];
                    let out = self.eval_cell_timed(batch, ctx_idx);
                    *slots[i].lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                        Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .unwrap_or_else(|| unreachable!("worker filled every wave slot"))
            })
            .collect()
    }

    fn eval_cell_timed(&self, batch: usize, ctx_idx: usize) -> (CellOutcome, f64) {
        let t = Instant::now();
        let out = self.eval_cell(batch, ctx_idx);
        (out, t.elapsed().as_secs_f64())
    }

    fn eval_cell(&self, batch: usize, ctx_idx: usize) -> CellOutcome {
        let ctx = &self.contexts[ctx_idx];
        match self.algo {
            CellAlgo::Even => cells::eval_even_cell(self.model, self.cluster, self.cfg, ctx, batch),
            CellAlgo::Bmw => {
                cells::eval_bmw_cell(self.model, self.cluster, self.cfg, ctx, batch, &self.flops_w)
            }
            CellAlgo::Fixed(kind) => cells::eval_fixed_cell(
                kind,
                self.model,
                self.cluster,
                self.cfg,
                ctx,
                batch,
                &self.flops_w,
            ),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::util::GIB;

    fn cfg(threads: usize, max_batch: usize) -> SearchConfig {
        SearchConfig { threads: Some(threads), max_batch, ..Default::default() }
    }

    #[test]
    fn parallel_run_matches_single_threaded_bitwise() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let (b1, t1) =
            SearchEngine::new(&model, &cluster, &cfg(1, 48), CellAlgo::Even).run();
        let (b8, t8) =
            SearchEngine::new(&model, &cluster, &cfg(8, 48), CellAlgo::Even).run();
        let (p1, p8) = (b1.expect("feasible"), b8.expect("feasible"));
        assert_eq!(p1.plan, p8.plan);
        assert_eq!(p1.cost.throughput.to_bits(), p8.cost.throughput.to_bits());
        assert_eq!(t1, t8, "trace must not depend on worker count");
    }

    #[test]
    fn trace_counts_are_consistent() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let (best, trace) =
            SearchEngine::new(&model, &cluster, &cfg(2, 48), CellAlgo::Even).run();
        assert!(best.is_some());
        assert_eq!(
            trace.cells.len(),
            trace.cells_explored + trace.cells_discarded
        );
        assert!(trace.evaluations > 0);
        assert!(trace.cache_lookups > trace.cache_entries);
        assert!(trace.cache_hit_rate() > 0.5, "hit rate {}", trace.cache_hit_rate());
        assert!(trace.best_cell.is_some());
    }

    #[test]
    fn run_wide_registry_merges_saturated_sites_across_pp() {
        // titan8: every PP degree's slots are saturated (the intra limit
        // equals the stage group) with one gpu/bus shape, so the whole run
        // shares a single cost class — the cross-PP sharing the run-wide
        // cache exists for.
        let hom = cluster_by_name("titan8").unwrap();
        let mut registry: Vec<SiteClass> = Vec::new();
        for pp in [1usize, 2, 4, 8] {
            let group = hom.n_devices() / pp;
            for site in hom.stage_sites(pp) {
                register_site(&mut registry, &site, group, pp);
            }
        }
        assert_eq!(registry.len(), 1, "homogeneous cluster must collapse to one class");
        assert!(registry[0].saturated);
        // hetero4: the PP=1 whole-cluster slot spans both islands
        // (unsaturated: groups can spill to the inter link), while the
        // saturated per-island classes of PP=2 and PP=4 merge.
        let het = cluster_by_name("hetero4").unwrap();
        let mut reg: Vec<SiteClass> = Vec::new();
        for pp in [1usize, 2, 4] {
            let group = het.n_devices() / pp;
            for site in het.stage_sites(pp) {
                register_site(&mut reg, &site, group, pp);
            }
        }
        assert_eq!(reg.len(), 3, "floor + two island classes");
        assert!(!reg[0].saturated, "pp=1 spanning slot can spill to inter_bw");
        // Distinct classes keep distinct persistence fingerprints.
        let mut fps: Vec<u64> = reg.iter().map(|e| e.fp).collect();
        fps.sort_unstable();
        fps.dedup();
        assert_eq!(fps.len(), reg.len());
    }

    #[test]
    fn shared_cache_matches_per_degree_costs() {
        // The run-wide cache must return bit-identical costs to a direct
        // per-PP estimator for every degree it serves — the saturation
        // merge may never change a value.
        use crate::cost::StageCosts;
        use crate::search::decision_tree::{candidate_strategies, SpaceOptions};
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let c = SearchConfig::default();
        let engine = SearchEngine::new(&model, &cluster, &c, CellAlgo::Even);
        for ctx in &engine.contexts {
            let direct = crate::cost::CostEstimator::new(&cluster, ctx.pp, c.overlap_slowdown)
                .with_train(c.train)
                .with_cost_model(c.cost_model.clone());
            let cands = candidate_strategies(ctx.group, &SpaceOptions::default());
            let class = ctx.sites[0].class;
            for s in cands.iter().take(6) {
                for b_m in [1.0f64, 4.0] {
                    let via_cache = ctx.cache.site_costs(class).layer_cost_at(
                        1,
                        &model.layers[1],
                        s,
                        b_m,
                        0.0,
                    );
                    assert_eq!(
                        via_cache,
                        direct.layer_cost(&model.layers[1], s, b_m, 0.0),
                        "pp={} {s} b_m={b_m}",
                        ctx.pp
                    );
                }
            }
        }
    }

    #[test]
    fn placements_collapse_on_homogeneous_and_rank_on_mixed() {
        let hom = cluster_by_name("titan8").unwrap().stage_sites(4);
        assert_eq!(placement_candidates(&hom), vec![vec![0, 1, 2, 3]]);
        // hetero4 lists the TITAN island first: the ranked placement must
        // put stage 0 on the A100-80G slot, with identity as the fallback.
        let het = cluster_by_name("hetero4").unwrap().stage_sites(2);
        let pls = placement_candidates(&het);
        assert_eq!(pls.len(), 2);
        assert_eq!(pls[0], vec![1, 0]);
        assert_eq!(pls[1], vec![0, 1]);
    }

    #[test]
    fn mixed_island_run_is_thread_deterministic() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("hetero4").unwrap();
        let (b1, t1) = SearchEngine::new(&model, &cluster, &cfg(1, 32), CellAlgo::Bmw).run();
        let (b8, t8) = SearchEngine::new(&model, &cluster, &cfg(8, 32), CellAlgo::Bmw).run();
        assert_eq!(t1, t8, "trace must not depend on worker count");
        match (b1, b8) {
            (Some(x), Some(y)) => {
                assert_eq!(x.plan, y.plan);
                assert_eq!(x.cost.throughput.to_bits(), y.cost.throughput.to_bits());
            }
            (None, None) => {}
            _ => panic!("feasibility differed across thread counts"),
        }
    }

    #[test]
    fn patience_stops_sweep_on_ordered_batches() {
        // Tight budget: large batches become infeasible quickly, so the
        // ordered reduction must stop and skip/discard later cells.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(5.0 * GIB);
        let c = SearchConfig { threads: Some(4), max_batch: 256, ..Default::default() };
        let (_, trace) = SearchEngine::new(&model, &cluster, &c, CellAlgo::Even).run();
        let total = trace.cells_explored + trace.cells_discarded + trace.cells_skipped;
        let grid = crate::search::batch_candidates(256).len()
            * pp_degrees(&model, &cluster, &c).len();
        assert_eq!(total, grid);
        if trace.cells_explored < grid {
            // The sweep stopped early: the stop point is batch-ordered, so
            // every explored cell's batch precedes every skipped batch.
            assert!(trace.cells_skipped > 0 || trace.cells_discarded > 0);
        }
    }
}
