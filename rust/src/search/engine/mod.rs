//! The parallel memoized search engine (the planner core).
//!
//! [`SearchEngine`] drives every optimizer of the paper (Galvatron-Base,
//! Galvatron-BMW, the fixed-partition ablations) over the same skeleton:
//!
//!   1. **Precompute** — per explored PP degree, build the decision-tree
//!      candidate catalog once and bind a shared memoized cost cache
//!      ([`cache::CostCache`]) that collapses identical layers into cost
//!      classes and reuses `c(l, s)` / transform costs across every batch
//!      size, partition, and BMW boundary-adjustment step.
//!   2. **Fan out** — the independent (global-batch, PP-degree) cells of
//!      the sweep run on a `std::thread::scope` worker pool sized by
//!      [`crate::util::parallelism::resolve_worker_count`], in look-ahead
//!      waves of [`WAVE_BATCHES`] consecutive batch sizes.
//!   3. **Reduce deterministically** — results are folded in (batch, PP)
//!      enumeration order with the sequential sweep's strictly-greater
//!      update rule, and batch-sweep patience is counted over *ordered*
//!      batch sizes (never completion order), so the winning plan — and the
//!      serialized [`trace::SearchTrace`] — are bit-identical for every
//!      worker count.
//!
//! `search::base::optimize`, `search::bmw::optimize_bmw` and the
//! `api::MethodSpec` catalog are thin fronts over this engine;
//! `search::dp` remains the pure per-stage kernel.

pub mod cache;
pub mod trace;
mod cells;

pub use cache::{layer_classes, CostCache, SiteCosts};
pub use trace::{CellTrace, SearchTrace};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::{ClusterSpec, StageSite};
use crate::cost::CostEstimator;
use crate::model::ModelProfile;
use crate::parallel::Strategy;
use crate::search::base::{pp_degrees, stage_candidates, SearchConfig, SearchOutcome};
use crate::util::parallelism::resolve_worker_count;

use cells::CellOutcome;

/// Which fixed partition policy a [`CellAlgo::Fixed`] cell evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// Memory-balanced partition p_m (1F1B live-microbatch aware).
    MemoryBalanced,
    /// Time-balanced partition p_t (FLOPs-balanced).
    TimeBalanced,
}

/// The per-cell algorithm the engine fans out over the (batch × PP) grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellAlgo {
    /// Galvatron-Base (Algorithm 1): even partition, microbatch sweep.
    Even,
    /// Galvatron-BMW (Algorithm 2): bi-objective boundary adjustment.
    Bmw,
    /// Table V ablations: fixed balanced partition, no adjustment loop.
    Fixed(PartitionKind),
}

/// Precomputed per-PP-degree context shared by all cells of that degree:
/// stage group size, the candidate catalog, the island slot sites, the
/// candidate stage→slot placements, and the memoized cost cache (one
/// bound estimator per island site class).
pub(crate) struct PpContext {
    pub pp: usize,
    pub group: usize,
    pub candidates: Vec<Strategy>,
    /// Slot sites of this PP degree, in device order.
    pub sites: Vec<StageSite>,
    /// Candidate stage→slot assignments, deduped by slot-class signature:
    /// the capacity-ranked placement (memory-heavy early 1F1B stages on
    /// large-memory slots) first, then the identity if it differs. A
    /// homogeneous cluster collapses to the identity alone, so its cell
    /// evaluation counts — and trace — are unchanged.
    pub placements: Vec<Vec<usize>>,
    pub cache: CostCache,
}

/// Candidate stage→slot placements for one PP degree. The capacity-ranked
/// placement assigns the k-th largest-memory slot to stage k — under 1F1B
/// stage 0 holds the most live microbatches, so memory-heavy stages land
/// on large-memory islands. The stable sort keeps device order on ties,
/// which makes the ranked placement equal the identity on homogeneous
/// clusters (deduped to a single entry).
fn placement_candidates(sites: &[StageSite]) -> Vec<Vec<usize>> {
    let p = sites.len();
    let identity: Vec<usize> = (0..p).collect();
    let mut ranked = identity.clone();
    ranked.sort_by(|&a, &b| sites[b].gpu.mem_bytes.total_cmp(&sites[a].gpu.mem_bytes));
    let signature =
        |pl: &[usize]| -> Vec<u32> { pl.iter().map(|&s| sites[s].class).collect() };
    let mut out = vec![ranked];
    if signature(&identity) != signature(&out[0]) {
        out.push(identity);
    }
    out
}

/// Look-ahead window of the batch sweep: cells of this many consecutive
/// batch sizes are computed per wave. Deliberately fixed (never derived
/// from the worker count) so the set of computed cells — and therefore the
/// serialized trace — is identical for every `--threads` value. Matches
/// the default patience of 3: at most one wave of overshoot past the
/// stopping batch.
const WAVE_BATCHES: usize = 4;

/// The parallel memoized planner core. Construct per search run; borrows
/// its inputs for the run's duration.
pub struct SearchEngine<'a> {
    model: &'a ModelProfile,
    cluster: &'a ClusterSpec,
    cfg: &'a SearchConfig,
    algo: CellAlgo,
    threads: usize,
    contexts: Vec<PpContext>,
    flops_w: Vec<f64>,
}

impl<'a> SearchEngine<'a> {
    pub fn new(
        model: &'a ModelProfile,
        cluster: &'a ClusterSpec,
        cfg: &'a SearchConfig,
        algo: CellAlgo,
    ) -> SearchEngine<'a> {
        let threads = resolve_worker_count(cfg.threads);
        let classes = layer_classes(model);
        let contexts: Vec<PpContext> = pp_degrees(model, cluster, cfg)
            .into_iter()
            .map(|pp| {
                let group = cluster.n_devices() / pp;
                let candidates = stage_candidates(cfg, group);
                let sites = cluster.stage_sites(pp);
                // One bound estimator per distinct island site class (a
                // homogeneous cluster has exactly one, class 0).
                let n_classes =
                    sites.iter().map(|s| s.class).max().map(|c| c as usize + 1).unwrap_or(1);
                let ests: Vec<CostEstimator> = (0..n_classes)
                    .map(|c| {
                        let site = sites
                            .iter()
                            .find(|s| s.class == c as u32)
                            .unwrap_or_else(|| unreachable!("contiguous site class ids"))
                            .clone();
                        CostEstimator::with_site(cluster, pp, cfg.overlap_slowdown, site)
                            .with_train(cfg.train)
                            .with_cost_model(cfg.cost_model.clone())
                    })
                    .collect();
                let placements = placement_candidates(&sites);
                PpContext {
                    pp,
                    group,
                    candidates,
                    sites,
                    placements,
                    cache: CostCache::with_sites(ests, classes.clone()),
                }
            })
            .collect();
        let flops_w = model.layers.iter().map(|l| l.flops_fwd).collect();
        SearchEngine { model, cluster, cfg, algo, threads, contexts, flops_w }
    }

    /// Worker count this engine resolved (for diagnostics).
    pub fn worker_count(&self) -> usize {
        self.threads
    }

    /// Run the full sweep: fan cells out, reduce in order, return the best
    /// outcome (if any plan fit) plus the structured search trace.
    pub fn run(&self) -> (Option<SearchOutcome>, SearchTrace) {
        let batches = crate::search::batch_candidates(self.cfg.max_batch);
        let per_batch = self.contexts.len();
        let mut trace = SearchTrace::default();
        let mut best: Option<SearchOutcome> = None;
        let mut infeasible_streak = 0usize;
        let mut stopped = false;

        for wave in batches.chunks(WAVE_BATCHES) {
            if stopped {
                trace.cells_skipped += wave.len() * per_batch;
                continue;
            }
            let wave_cells: Vec<(usize, usize)> = wave
                .iter()
                .flat_map(|&b| (0..per_batch).map(move |c| (b, c)))
                .collect();
            let outcomes = self.run_wave(&wave_cells);

            // Ordered reduction: batches in sweep order, PP degrees in
            // enumeration order — identical to the sequential nested loop.
            for (wi, _) in wave.iter().enumerate() {
                let slice = &outcomes[wi * per_batch..(wi + 1) * per_batch];
                if stopped {
                    // Computed in this look-ahead wave, but the patience
                    // rule already ended the sweep at an earlier batch:
                    // record the work, discard the results.
                    for cell in slice {
                        trace.cells_discarded += 1;
                        trace.cells.push(cell.to_trace(true));
                    }
                    continue;
                }
                let mut any_feasible = false;
                for cell in slice {
                    any_feasible |= cell.feasible;
                    trace.cells_explored += 1;
                    trace.evaluations += cell.evaluations;
                    if !cell.feasible && cell.evaluations > 0 {
                        trace.cells_oom += 1;
                    }
                    trace.cells.push(cell.to_trace(false));
                    if let Some(out) = &cell.best {
                        if best.as_ref().map_or(true, |b| out.throughput() > b.throughput()) {
                            best = Some(out.clone());
                            trace.best_cell = Some((cell.batch, cell.pp));
                        }
                    }
                }
                if any_feasible {
                    infeasible_streak = 0;
                } else if best.is_some() {
                    // Patience over ordered batch sizes: memory use is
                    // monotone in B, so after `patience` consecutive
                    // infeasible batches the sweep stops.
                    infeasible_streak += 1;
                    if infeasible_streak >= self.cfg.patience {
                        stopped = true;
                    }
                }
            }
        }

        for ctx in &self.contexts {
            trace.cache_lookups += ctx.cache.lookups();
            trace.cache_entries += ctx.cache.entries();
        }
        (best, trace)
    }

    /// Compute one wave of cells, fanning out across the worker pool.
    /// Results come back in input order regardless of completion order.
    fn run_wave(&self, wave_cells: &[(usize, usize)]) -> Vec<CellOutcome> {
        let workers = self.threads.min(wave_cells.len()).max(1);
        if workers <= 1 {
            return wave_cells.iter().map(|&(b, c)| self.eval_cell(b, c)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<CellOutcome>>> =
            wave_cells.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= wave_cells.len() {
                        break;
                    }
                    let (batch, ctx_idx) = wave_cells[i];
                    let out = self.eval_cell(batch, ctx_idx);
                    *slots[i].lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                        Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .unwrap_or_else(|| unreachable!("worker filled every wave slot"))
            })
            .collect()
    }

    fn eval_cell(&self, batch: usize, ctx_idx: usize) -> CellOutcome {
        let ctx = &self.contexts[ctx_idx];
        match self.algo {
            CellAlgo::Even => cells::eval_even_cell(self.model, self.cluster, self.cfg, ctx, batch),
            CellAlgo::Bmw => {
                cells::eval_bmw_cell(self.model, self.cluster, self.cfg, ctx, batch, &self.flops_w)
            }
            CellAlgo::Fixed(kind) => cells::eval_fixed_cell(
                kind,
                self.model,
                self.cluster,
                self.cfg,
                ctx,
                batch,
                &self.flops_w,
            ),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::util::GIB;

    fn cfg(threads: usize, max_batch: usize) -> SearchConfig {
        SearchConfig { threads: Some(threads), max_batch, ..Default::default() }
    }

    #[test]
    fn parallel_run_matches_single_threaded_bitwise() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let (b1, t1) =
            SearchEngine::new(&model, &cluster, &cfg(1, 48), CellAlgo::Even).run();
        let (b8, t8) =
            SearchEngine::new(&model, &cluster, &cfg(8, 48), CellAlgo::Even).run();
        let (p1, p8) = (b1.expect("feasible"), b8.expect("feasible"));
        assert_eq!(p1.plan, p8.plan);
        assert_eq!(p1.cost.throughput.to_bits(), p8.cost.throughput.to_bits());
        assert_eq!(t1, t8, "trace must not depend on worker count");
    }

    #[test]
    fn trace_counts_are_consistent() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
        let (best, trace) =
            SearchEngine::new(&model, &cluster, &cfg(2, 48), CellAlgo::Even).run();
        assert!(best.is_some());
        assert_eq!(
            trace.cells.len(),
            trace.cells_explored + trace.cells_discarded
        );
        assert!(trace.evaluations > 0);
        assert!(trace.cache_lookups > trace.cache_entries);
        assert!(trace.cache_hit_rate() > 0.5, "hit rate {}", trace.cache_hit_rate());
        assert!(trace.best_cell.is_some());
    }

    #[test]
    fn placements_collapse_on_homogeneous_and_rank_on_mixed() {
        let hom = cluster_by_name("titan8").unwrap().stage_sites(4);
        assert_eq!(placement_candidates(&hom), vec![vec![0, 1, 2, 3]]);
        // hetero4 lists the TITAN island first: the ranked placement must
        // put stage 0 on the A100-80G slot, with identity as the fallback.
        let het = cluster_by_name("hetero4").unwrap().stage_sites(2);
        let pls = placement_candidates(&het);
        assert_eq!(pls.len(), 2);
        assert_eq!(pls[0], vec![1, 0]);
        assert_eq!(pls[1], vec![0, 1]);
    }

    #[test]
    fn mixed_island_run_is_thread_deterministic() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("hetero4").unwrap();
        let (b1, t1) = SearchEngine::new(&model, &cluster, &cfg(1, 32), CellAlgo::Bmw).run();
        let (b8, t8) = SearchEngine::new(&model, &cluster, &cfg(8, 32), CellAlgo::Bmw).run();
        assert_eq!(t1, t8, "trace must not depend on worker count");
        match (b1, b8) {
            (Some(x), Some(y)) => {
                assert_eq!(x.plan, y.plan);
                assert_eq!(x.cost.throughput.to_bits(), y.cost.throughput.to_bits());
            }
            (None, None) => {}
            _ => panic!("feasibility differed across thread counts"),
        }
    }

    #[test]
    fn patience_stops_sweep_on_ordered_batches() {
        // Tight budget: large batches become infeasible quickly, so the
        // ordered reduction must stop and skip/discard later cells.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(5.0 * GIB);
        let c = SearchConfig { threads: Some(4), max_batch: 256, ..Default::default() };
        let (_, trace) = SearchEngine::new(&model, &cluster, &c, CellAlgo::Even).run();
        let total = trace.cells_explored + trace.cells_discarded + trace.cells_skipped;
        let grid = crate::search::batch_candidates(256).len()
            * pp_degrees(&model, &cluster, &c).len();
        assert_eq!(total, grid);
        if trace.cells_explored < grid {
            // The sweep stopped early: the stop point is batch-ordered, so
            // every explored cell's batch precedes every skipped batch.
            assert!(trace.cells_skipped > 0 || trace.cells_discarded > 0);
        }
    }
}
