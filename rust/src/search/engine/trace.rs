//! Structured search diagnostics: what the engine explored, pruned and
//! reused while planning. Serialized into [`crate::api::PlanReport`]
//! artifacts (the `search_trace` field), so a saved plan records how it
//! was found.
//!
//! Every serialized quantity is deterministic across worker counts: cells
//! are enumerated in fixed (batch, PP) order, the per-cell work is
//! independent of other cells, and the cache statistics count lookups
//! (fixed per cell) and distinct entries (the union of keys) rather than
//! racy miss counts. `threads=1` and `threads=N` therefore produce
//! byte-identical traces.

use crate::util::json::Json;

/// One (global-batch, PP-degree) cell of the search grid.
#[derive(Debug, Clone, PartialEq)]
pub struct CellTrace {
    /// Global batch size of this cell.
    pub batch: usize,
    /// Pipeline degree of this cell.
    pub pp: usize,
    /// Partition evaluations (stage-DP runs composed into a plan) tried.
    pub evaluations: usize,
    /// Whether any evaluation produced a memory-feasible plan.
    pub feasible: bool,
    /// Best estimated throughput found in this cell (samples/s).
    pub best_throughput: Option<f64>,
    /// Computed in a look-ahead wave but discarded because the ordered
    /// batch-patience reduction had already stopped the sweep.
    pub discarded: bool,
}

impl CellTrace {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("pp", Json::num(self.pp as f64)),
            ("evaluations", Json::num(self.evaluations as f64)),
            ("feasible", Json::Bool(self.feasible)),
            (
                "best_throughput",
                match self.best_throughput {
                    Some(t) => Json::num(t),
                    None => Json::Null,
                },
            ),
            ("discarded", Json::Bool(self.discarded)),
        ])
    }

    fn from_json(v: &Json) -> Option<CellTrace> {
        Some(CellTrace {
            batch: v.get("batch")?.as_usize()?,
            pp: v.get("pp")?.as_usize()?,
            evaluations: v.get("evaluations")?.as_usize()?,
            feasible: v.get("feasible")?.as_bool()?,
            best_throughput: match v.get("best_throughput") {
                None | Some(Json::Null) => None,
                Some(t) => Some(t.as_f64()?),
            },
            discarded: v.get("discarded")?.as_bool()?,
        })
    }
}

/// Wall-clock and warm-start provenance of one engine run. Diagnostics
/// only: never serialized into artifacts and excluded from the trace's
/// `PartialEq`, so the byte-determinism guarantees (threads=1 vs threads=N,
/// warm vs cold cache) are unaffected by how long anything took.
#[derive(Debug, Clone, Default)]
pub struct SearchTiming {
    /// Full engine run: precompute + sweep (seconds).
    pub total_secs: f64,
    /// Context construction: catalogs, site registry, cache load (seconds).
    pub precompute_secs: f64,
    /// The (batch × PP) fan-out and ordered reduction (seconds).
    pub search_secs: f64,
    /// Per computed cell `(batch, pp, seconds)`, in reduction order.
    pub cell_secs: Vec<(usize, usize, f64)>,
    /// Persisted cost tables were found and loaded for this run.
    pub warm_start: bool,
    /// Cost entries loaded from the persistent cache at startup.
    pub persisted_entries: u64,
    /// DP transition attempts evaluated across every stage search — the
    /// direct measure of how much work pruning and the reachability
    /// bounds saved.
    pub dp_states_visited: u64,
    /// Partition evaluations short-circuited by the optimistic lower
    /// bound (each skip avoided a full stage-DP pass).
    pub lb_skips: u64,
    /// Candidate strategies dropped as pairwise dominated, summed over the
    /// distinct matrix bundles of the run (0 when pruning is off).
    pub candidates_pruned: u64,
    /// Distinct (site class, group, b_m) matrix bundles built — each one
    /// amortized across every cell, batch and thread that requested it.
    pub matrix_builds: u64,
    /// Distinct stage-DP solves memoized run-wide (pruned path): every
    /// repeated (site, group, b_m, m, live, budget, layer-class-sequence)
    /// stage beyond these was an O(1) map hit instead of a DP pass.
    pub dp_memo_entries: u64,
}

impl SearchTiming {
    fn merge(&mut self, other: SearchTiming) {
        self.total_secs += other.total_secs;
        self.precompute_secs += other.precompute_secs;
        self.search_secs += other.search_secs;
        self.cell_secs.extend(other.cell_secs);
        self.warm_start |= other.warm_start;
        self.persisted_entries += other.persisted_entries;
        self.dp_states_visited += other.dp_states_visited;
        self.lb_skips += other.lb_skips;
        self.candidates_pruned += other.candidates_pruned;
        self.matrix_builds += other.matrix_builds;
        self.dp_memo_entries += other.dp_memo_entries;
    }
}

/// Aggregate diagnostics of one engine run (or, for composite methods like
/// Alpa, of several merged runs).
#[derive(Debug, Clone, Default)]
pub struct SearchTrace {
    /// Every computed cell, in deterministic enumeration order.
    pub cells: Vec<CellTrace>,
    /// Cells whose results entered the ordered reduction.
    pub cells_explored: usize,
    /// Cells computed in a look-ahead wave but discarded after the
    /// patience stop (work done, result unused).
    pub cells_discarded: usize,
    /// Grid cells never computed because the sweep stopped first.
    pub cells_skipped: usize,
    /// Explored cells in which no plan fit the memory budget.
    pub cells_oom: usize,
    /// Partition evaluations across explored cells.
    pub evaluations: usize,
    /// Memoized cost lookups served by the shared caches.
    pub cache_lookups: u64,
    /// Distinct cost entries resident at the end of the run.
    pub cache_entries: u64,
    /// (batch, pp) of the cell holding the winning plan.
    pub best_cell: Option<(usize, usize)>,
    /// Wall-clock + warm-start diagnostics (not serialized, not compared).
    pub timing: SearchTiming,
}

/// Everything except `timing` (wall time is nondeterministic by nature, so
/// it must not break `assert_eq!(trace_t1, trace_t8)` or warm-vs-cold
/// artifact comparisons).
impl PartialEq for SearchTrace {
    fn eq(&self, other: &Self) -> bool {
        self.cells == other.cells
            && self.cells_explored == other.cells_explored
            && self.cells_discarded == other.cells_discarded
            && self.cells_skipped == other.cells_skipped
            && self.cells_oom == other.cells_oom
            && self.evaluations == other.evaluations
            && self.cache_lookups == other.cache_lookups
            && self.cache_entries == other.cache_entries
            && self.best_cell == other.best_cell
    }
}

impl SearchTrace {
    /// Fraction of cost lookups served from memory rather than computed.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            0.0
        } else {
            (self.cache_lookups - self.cache_entries.min(self.cache_lookups)) as f64
                / self.cache_lookups as f64
        }
    }

    /// Fold another run's trace into this one (cells appended in order;
    /// `best_cell` is cleared — the caller knows which run won).
    pub fn merge(&mut self, other: SearchTrace) {
        self.cells.extend(other.cells);
        self.cells_explored += other.cells_explored;
        self.cells_discarded += other.cells_discarded;
        self.cells_skipped += other.cells_skipped;
        self.cells_oom += other.cells_oom;
        self.evaluations += other.evaluations;
        self.cache_lookups += other.cache_lookups;
        self.cache_entries += other.cache_entries;
        self.best_cell = None;
        self.timing.merge(other.timing);
    }

    /// One-line wall-clock summary for CLI output (empty when the trace
    /// was deserialized from an artifact, which carries no timing).
    pub fn timing_summary(&self) -> Option<String> {
        let t = &self.timing;
        if t.total_secs <= 0.0 {
            return None;
        }
        let warm = if t.warm_start {
            format!("warm ({} persisted entries)", t.persisted_entries)
        } else {
            "cold".to_string()
        };
        Some(format!(
            "timing: {:.3}s total ({:.3}s precompute, {:.3}s search), cache start: {warm}, pruning: {} candidates pruned / {} lb skips / {} dp states / {} matrix builds / {} dp memo entries",
            t.total_secs,
            t.precompute_secs,
            t.search_secs,
            t.candidates_pruned,
            t.lb_skips,
            t.dp_states_visited,
            t.matrix_builds,
            t.dp_memo_entries,
        ))
    }

    /// One-line human summary for CLI output.
    pub fn summary(&self) -> String {
        format!(
            "search: {} cells explored ({} oom, {} discarded, {} skipped), {} evaluations, cache hit rate {:.1}% ({} lookups, {} entries)",
            self.cells_explored,
            self.cells_oom,
            self.cells_discarded,
            self.cells_skipped,
            self.evaluations,
            self.cache_hit_rate() * 100.0,
            self.cache_lookups,
            self.cache_entries,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cells", Json::arr(self.cells.iter().map(|c| c.to_json()))),
            ("cells_explored", Json::num(self.cells_explored as f64)),
            ("cells_discarded", Json::num(self.cells_discarded as f64)),
            ("cells_skipped", Json::num(self.cells_skipped as f64)),
            ("cells_oom", Json::num(self.cells_oom as f64)),
            ("evaluations", Json::num(self.evaluations as f64)),
            ("cache_lookups", Json::num(self.cache_lookups as f64)),
            ("cache_entries", Json::num(self.cache_entries as f64)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate())),
            (
                "best_cell",
                match self.best_cell {
                    Some((b, p)) => Json::arr(vec![Json::num(b as f64), Json::num(p as f64)]),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Inverse of [`SearchTrace::to_json`] (`cache_hit_rate` is derived and
    /// ignored on input). Returns `None` on any missing/mistyped field.
    pub fn from_json(v: &Json) -> Option<SearchTrace> {
        let mut cells = Vec::new();
        for c in v.get("cells")?.as_arr()? {
            cells.push(CellTrace::from_json(c)?);
        }
        Some(SearchTrace {
            cells,
            cells_explored: v.get("cells_explored")?.as_usize()?,
            cells_discarded: v.get("cells_discarded")?.as_usize()?,
            cells_skipped: v.get("cells_skipped")?.as_usize()?,
            cells_oom: v.get("cells_oom")?.as_usize()?,
            evaluations: v.get("evaluations")?.as_usize()?,
            cache_lookups: v.get("cache_lookups")?.as_f64()? as u64,
            cache_entries: v.get("cache_entries")?.as_f64()? as u64,
            best_cell: match v.get("best_cell") {
                None | Some(Json::Null) => None,
                Some(bc) => {
                    let pair = bc.as_usize_vec().filter(|p| p.len() == 2)?;
                    Some((pair[0], pair[1]))
                }
            },
            timing: SearchTiming::default(),
        })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn sample() -> SearchTrace {
        SearchTrace {
            cells: vec![
                CellTrace {
                    batch: 8,
                    pp: 2,
                    evaluations: 5,
                    feasible: true,
                    best_throughput: Some(123.5),
                    discarded: false,
                },
                CellTrace {
                    batch: 16,
                    pp: 4,
                    evaluations: 2,
                    feasible: false,
                    best_throughput: None,
                    discarded: true,
                },
            ],
            cells_explored: 1,
            cells_discarded: 1,
            cells_skipped: 4,
            cells_oom: 0,
            evaluations: 5,
            cache_lookups: 1000,
            cache_entries: 100,
            best_cell: Some((8, 2)),
            timing: SearchTiming::default(),
        }
    }

    #[test]
    fn timing_never_affects_equality_or_serialization() {
        let a = sample();
        let mut b = sample();
        b.timing.total_secs = 42.0;
        b.timing.warm_start = true;
        b.timing.cell_secs.push((8, 2, 1.5));
        assert_eq!(a, b, "wall time must not break trace equality");
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(b.timing_summary().is_some());
        assert!(a.timing_summary().is_none());
    }

    #[test]
    fn json_round_trip() {
        let t = sample();
        let text = t.to_json().to_string();
        let back = SearchTrace::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
        // Deterministic serialization.
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn hit_rate_math() {
        let t = sample();
        assert!((t.cache_hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(SearchTrace::default().cache_hit_rate(), 0.0);
    }

    #[test]
    fn merge_accumulates_and_clears_best() {
        let mut a = sample();
        let b = sample();
        a.merge(b);
        assert_eq!(a.cells.len(), 4);
        assert_eq!(a.cells_explored, 2);
        assert_eq!(a.cache_lookups, 2000);
        assert_eq!(a.best_cell, None);
    }
}
