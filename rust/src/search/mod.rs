//! Parallelism optimization framework (paper §IV): search-space
//! construction, stage-level DP, Galvatron-Base, Galvatron-BMW, and every
//! baseline the paper compares against.

pub mod baselines;
pub mod base;
pub mod bmw;
pub mod decision_tree;
pub mod dp;
pub mod engine;
pub mod partition;

pub use base::{optimize, optimize_traced, SearchConfig, SearchOutcome};
pub use bmw::{optimize_bmw, optimize_bmw_traced};
pub use decision_tree::{candidate_strategies, SpaceOptions};
pub use engine::{CellAlgo, PartitionKind, SearchEngine, SearchTrace};

use crate::cost::pipeline::Schedule;
use crate::parallel::{Dim, Strategy};

// Which optimizer a method uses is now expressed by the typed
// [`crate::api::MethodSpec`] catalog (the old string-keyed `Method` tag
// lived here).

/// Batch sizes explored by the sweep: dense at small B, geometric beyond.
pub fn batch_candidates(max_batch: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut b = 8;
    while b <= max_batch {
        out.push(b);
        b += if b < 128 {
            8
        } else if b < 512 {
            32
        } else if b < 2048 {
            128
        } else {
            512
        };
    }
    out
}

/// Microbatch-count candidates for batch `b` under `pp` stages: powers of
/// two multiples of max(pp, 1) that divide... (we allow fractional
/// microbatch sizes, so only m <= b is required), capped to 6 options.
pub fn microbatch_candidates(b: usize, pp: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut m = pp.max(1);
    while m <= b && out.len() < 6 {
        out.push(m);
        m *= 2;
    }
    if out.is_empty() {
        out.push(b.max(1));
    }
    out
}

/// Convenience constructor for fixed-strategy levels.
pub fn levels(spec: &[(Dim, usize)]) -> Strategy {
    Strategy { levels: spec.to_vec(), ckpt: false }
}

/// Human description of a schedule.
pub fn schedule_name(s: Schedule) -> &'static str {
    match s {
        Schedule::OneFOneB => "1F1B-Flush",
        Schedule::GPipe => "GPipe",
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn batch_sweep_shape() {
        let bs = batch_candidates(2048);
        assert_eq!(bs[0], 8);
        assert!(bs.windows(2).all(|w| w[1] > w[0]));
        assert!(bs.contains(&128) && bs.contains(&512));
        assert!(*bs.last().unwrap() <= 2048);
    }

    #[test]
    fn microbatch_options() {
        assert_eq!(microbatch_candidates(32, 4), vec![4, 8, 16, 32]);
        assert_eq!(microbatch_candidates(8, 1), vec![1, 2, 4, 8]);
        // b < pp: fall back to one sample per microbatch (m = b).
        assert_eq!(microbatch_candidates(4, 8), vec![4]);
    }
}
