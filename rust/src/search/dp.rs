//! Dynamic-programming layer-strategy assignment (paper §IV-A2, Eq. 4,
//! Appendix A / Algorithm 3).
//!
//! For one pipeline stage (a layer sub-sequence on a device group) we
//! minimize the stage execution cost subject to the device memory budget.
//! Following Appendix A1, the DP state is (layer, forward-memory bucket,
//! strategy-of-last-layer): tracking forward memory E_f keeps the state
//! linear in the budget; the full Eq. 2 peak (which adds backward spikes
//! O_b and the 1F1B live-microbatch multiplier) is verified on the
//! backtraced solution, scanning candidate terminal states in cost order —
//! equivalent to Algorithm 3's E_fwd sweep.
//!
//! Two entry points share one kernel:
//!
//! * [`dp_stage_search`] — the flat core. The caller hands it prebuilt
//!   per-layer-class cost rows and per-microbatch transform matrices
//!   (see [`crate::search::engine`]'s `StageMatrices`), an *active*
//!   candidate subset (dominance survivors), and optional reachability
//!   bounds. State tables are single contiguous buffers indexed by
//!   `(memory bucket, active candidate)`; the parent chain is one flat
//!   `u32` buffer for the whole stage.
//! * [`dp_search`] — the historical convenience wrapper over a
//!   [`StageCosts`] source. It prices the full catalog through the counted
//!   cache path (one probe per (layer, strategy) plus one per
//!   (layer ≥ 1, split-class pair) — identical traffic to the original
//!   kernel) and runs the core with every candidate active and bounds off,
//!   so its results and side effects are byte-for-byte those of the
//!   pre-flattening implementation.

use crate::cost::estimator::{LayerCost, StageCosts};
use crate::model::LayerProfile;
use crate::parallel::memory::stage_peak_memory;
use crate::parallel::Strategy;

/// Inputs for one stage-level DP search.
pub struct DpInput<'a> {
    /// The stage's layers, in order.
    pub layers: &'a [LayerProfile],
    /// Embedding/head params attributed to each layer (same length).
    pub extra_params: &'a [f64],
    /// Candidate strategies (all with degree == stage group size).
    pub strategies: &'a [Strategy],
    /// Cost source: a bare [`crate::cost::CostEstimator`] or the engine's
    /// shared memoized cache — the kernel itself stays cache-agnostic.
    pub costs: &'a dyn StageCosts,
    /// Model-global index of `layers[0]` (for cost-cache keying).
    pub layer_offset: usize,
    /// Microbatch size (global samples per microbatch).
    pub b_m: f64,
    /// Microbatches per global batch (m).
    pub microbatches: usize,
    /// Live microbatches at this stage's peak (1F1B: P - stage_idx).
    pub live_mb: usize,
    /// Device memory budget E, bytes.
    pub mem_budget: f64,
    /// Memory discretization granularity, bytes.
    pub granularity: f64,
}

/// Inputs for the flat DP core: costs come prebuilt as per-layer rows over
/// the *full* candidate catalog, and the DP itself runs over the `active`
/// subset only. Built by the engine from its memoized `StageMatrices`
/// bundles (one build per (site class, group, b_m) for the whole run) or by
/// the [`dp_search`] compatibility wrapper.
pub struct DpStageInput<'a> {
    /// Full candidate catalog (indices below refer into this).
    pub strategies: &'a [Strategy],
    /// Candidate indices the DP may assign (ascending). Dominance pruning
    /// shrinks this; the unpruned path passes `0..strategies.len()`.
    pub active: &'a [usize],
    /// Catalog index → batch-split class (index into the sorted distinct
    /// split list).
    pub class_of: &'a [usize],
    /// Number of distinct batch-split classes.
    pub nc: usize,
    /// Per stage layer: the full-catalog cost row of its layer class.
    pub layer_costs: Vec<&'a [LayerCost]>,
    /// Per stage layer `l ≥ 1`: the `nc × nc` *per-microbatch* transform
    /// matrix of its layer class (entry 0 is never read).
    pub layer_transforms: Vec<&'a [Vec<f64>]>,
    /// Microbatches per global batch (m).
    pub microbatches: usize,
    /// Live microbatches at this stage's peak (1F1B: P - stage_idx).
    pub live_mb: usize,
    /// Device memory budget E, bytes.
    pub mem_budget: f64,
    /// Memory discretization granularity, bytes.
    pub granularity: f64,
    /// Enable the reachability bounds (min-weight bail, prefix band,
    /// suffix-min column cutoff). Sound — every state they skip is
    /// unreachable or cannot reach any in-budget terminal — so results are
    /// identical either way; gated so `GALVATRON_NO_PRUNE=1` measures the
    /// full legacy sweep.
    pub bounds: bool,
}

/// Result of a stage-level DP search.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Per-global-batch stage time: m·(fwd+bwd+R) + grad-sync extra.
    pub cost_per_batch: f64,
    /// Per-microbatch stage time without gradient sync.
    pub time_nosync: f64,
    /// Per-microbatch stage time of the sync microbatch.
    pub time_sync: f64,
    /// Eq. 2 peak memory (bytes) with the live-microbatch multiplier.
    pub peak_mem: f64,
    /// Chosen strategy per layer.
    pub strategies: Vec<Strategy>,
    /// Chosen *catalog* index per layer (parallel to `strategies`).
    pub choice: Vec<usize>,
    /// DP transition attempts this search evaluated (diagnostics).
    pub states_visited: u64,
}

const INF: f64 = f64::INFINITY;

/// Run the flat DP core. Returns the result (if any assignment fits) and
/// the number of transition attempts evaluated — also reported on misses,
/// where there is no `DpResult` to carry it.
pub fn dp_stage_search(input: &DpStageInput) -> (Option<DpResult>, u64) {
    let nl = input.layer_costs.len();
    let na = input.active.len();
    let mut states: u64 = 0;
    if nl == 0 || na == 0 {
        return (None, states);
    }
    let m = input.microbatches as f64;
    let buckets = (input.mem_budget / input.granularity).floor() as usize;
    if buckets == 0 {
        return (None, states);
    }
    let nc = input.nc;

    // ---- Per-(layer, active candidate) weights and per-batch costs ------
    // weight = forward-memory share: model states + live·O_f (Eq. 3 with
    // the schedule's live multiplier).
    let mut weight: Vec<Vec<usize>> = Vec::with_capacity(nl);
    let mut batch_cost: Vec<Vec<f64>> = Vec::with_capacity(nl);
    for row in &input.layer_costs {
        let mut wrow = Vec::with_capacity(na);
        let mut brow = Vec::with_capacity(na);
        for &cand in input.active {
            let c = &row[cand];
            let fwd_bytes = c.mem.o_ms + input.live_mb as f64 * c.mem.o_f;
            wrow.push((fwd_bytes / input.granularity).ceil() as usize);
            brow.push(m * (c.fwd + c.bwd) + (c.bwd_sync - c.bwd));
        }
        weight.push(wrow);
        batch_cost.push(brow);
    }
    // Per-batch transform matrices, flattened `ci*nc + cj`. The m-multiply
    // happens here — `fl(m · x)` exactly as the historical per-stage build.
    let r_batch: Vec<Vec<f64>> = (0..nl)
        .map(|l| {
            if l == 0 {
                return Vec::new();
            }
            let t = input.layer_transforms[l];
            let mut flat = vec![0.0; nc * nc];
            for (ci, row) in t.iter().enumerate() {
                for (cj, &x) in row.iter().enumerate() {
                    flat[ci * nc + cj] = m * x;
                }
            }
            flat
        })
        .collect();
    // Split class of each active candidate.
    let class_act: Vec<usize> = input.active.iter().map(|&i| input.class_of[i]).collect();

    // ---- Reachability bounds --------------------------------------------
    // Columns outside [prefix_min, prefix_max] hold no state; states whose
    // remaining layers cannot fit even at min weight reach no in-budget
    // terminal, and neither can any state they would populate (weights are
    // additive) — skipping both leaves the terminal set untouched.
    let mut lo = vec![0usize; nl];
    let mut hi = vec![buckets; nl];
    // suffix_min[l] = min buckets needed by layers l.. (suffix_min[nl] = 0).
    let mut suffix_min = vec![0usize; nl + 1];
    if input.bounds {
        let min_w: Vec<usize> = weight
            .iter()
            .map(|row| row.iter().copied().fold(usize::MAX, usize::min))
            .collect();
        let max_w: Vec<usize> =
            weight.iter().map(|row| row.iter().copied().fold(0, usize::max)).collect();
        for l in (0..nl).rev() {
            suffix_min[l] = suffix_min[l + 1].saturating_add(min_w[l]);
        }
        if suffix_min[0] > buckets {
            return (None, states); // even the lightest assignment overflows
        }
        let (mut run_min, mut run_max) = (0usize, 0usize);
        for l in 0..nl {
            run_min = run_min.saturating_add(min_w[l]);
            run_max = run_max.saturating_add(max_w[l]);
            lo[l] = run_min;
            hi[l] = run_max.min(buckets);
        }
    }

    // ---- DP tables -------------------------------------------------------
    // prev[e*na + a]: min per-batch cost of layers 0..=l with exactly e
    // buckets of forward memory used and layer l on active candidate a.
    // parent is one flat buffer for the whole stage, offset l*width*na,
    // holding the predecessor's `e_prev*na + a_prev`.
    let width = buckets + 1;
    let mut prev = vec![INF; width * na];
    let mut cur = vec![INF; width * na];
    let mut parent = vec![u32::MAX; nl * width * na];

    // Layer 0.
    for a in 0..na {
        let w = weight[0][a];
        if w <= buckets {
            states += 1;
            let idx = w * na + a;
            if batch_cost[0][a] < prev[idx] {
                prev[idx] = batch_cost[0][a];
                parent[idx] = idx as u32; // self-marker, never read back
            }
        }
    }

    let mut best_class = vec![(INF, 0u32); nc];
    for l in 1..nl {
        for c in cur.iter_mut() {
            *c = INF;
        }
        let par_off = l * width * na;
        let r_l = &r_batch[l];
        for e_prev in lo[l - 1]..=hi[l - 1] {
            if input.bounds && e_prev.saturating_add(suffix_min[l]) > buckets {
                break; // ascending e_prev: every later column is worse
            }
            let base = e_prev * na;
            // Collapse predecessors into split classes: min cost + argmin.
            for b in best_class.iter_mut() {
                *b = (INF, 0);
            }
            let mut any = false;
            for a in 0..na {
                let c_prev = prev[base + a];
                if c_prev < best_class[class_act[a]].0 {
                    best_class[class_act[a]] = (c_prev, (base + a) as u32);
                    any = true;
                }
            }
            if !any {
                continue; // empty column
            }
            for a in 0..na {
                let w = weight[l][a];
                let e = e_prev + w;
                if e > buckets {
                    continue;
                }
                states += 1;
                let cj = class_act[a];
                let mut best = INF;
                let mut best_par = u32::MAX;
                for (ci, &(c_prev, par_idx)) in best_class.iter().enumerate() {
                    if !c_prev.is_finite() {
                        continue;
                    }
                    let c = c_prev + r_l[ci * nc + cj];
                    if c < best {
                        best = c;
                        best_par = par_idx;
                    }
                }
                if !best.is_finite() {
                    continue;
                }
                let c = best + batch_cost[l][a];
                let idx = e * na + a;
                if c < cur[idx] {
                    cur[idx] = c;
                    parent[par_off + idx] = best_par;
                }
            }
        }
        std::mem::swap(&mut prev, &mut cur);
    }

    // ---- Pick the cheapest terminal state whose true Eq. 2 peak fits ----
    let mut terminals: Vec<(f64, usize)> = prev
        .iter()
        .enumerate()
        .filter(|(_, c)| c.is_finite())
        .map(|(idx, c)| (*c, idx))
        .collect();
    terminals.sort_by(|a, b| a.0.total_cmp(&b.0));

    for (c_batch, term_idx) in terminals {
        // Backtrace (active space), then lift to catalog indices.
        let mut choice_a = vec![0usize; nl];
        let mut idx = term_idx;
        for l in (0..nl).rev() {
            choice_a[l] = idx % na;
            if l > 0 {
                idx = parent[l * width * na + idx] as usize;
                debug_assert_ne!(idx, u32::MAX as usize);
            }
        }
        let choice: Vec<usize> = choice_a.iter().map(|&a| input.active[a]).collect();
        // True peak (Eq. 2 with live multiplier).
        let mems: Vec<_> = (0..nl).map(|l| input.layer_costs[l][choice[l]].mem).collect();
        let peak = stage_peak_memory(&mems, input.live_mb);
        if peak <= input.mem_budget {
            let mut nosync = 0.0;
            let mut sync = 0.0;
            for l in 0..nl {
                let c = &input.layer_costs[l][choice[l]];
                nosync += c.fwd + c.bwd;
                sync += c.fwd + c.bwd_sync;
                if l > 0 {
                    // fl(m·x)/m, not x: keeps the historical double rounding.
                    let rt = r_batch[l][class_act[choice_a[l - 1]] * nc + class_act[choice_a[l]]] / m;
                    nosync += rt;
                    sync += rt;
                }
            }
            return (
                Some(DpResult {
                    cost_per_batch: c_batch,
                    time_nosync: nosync,
                    time_sync: sync,
                    peak_mem: peak,
                    strategies: choice.iter().map(|&j| input.strategies[j].clone()).collect(),
                    choice,
                    states_visited: states,
                }),
                states,
            );
        }
    }
    (None, states)
}

/// Run the DP search over a [`StageCosts`] source; `None` if no assignment
/// fits the budget. Compatibility wrapper: full catalog active, bounds off,
/// cost-source traffic identical to the historical kernel.
pub fn dp_search(input: &DpInput) -> Option<DpResult> {
    let nl = input.layers.len();
    let ns = input.strategies.len();
    if nl == 0 || ns == 0 {
        return None;
    }
    if ((input.mem_budget / input.granularity).floor() as usize) == 0 {
        return None;
    }

    // Price the full catalog through the counted path: one probe per
    // (layer, strategy)...
    let rows: Vec<Vec<LayerCost>> = input
        .layers
        .iter()
        .enumerate()
        .map(|(l, layer)| {
            input
                .strategies
                .iter()
                .map(|s| {
                    input.costs.layer_cost_at(
                        input.layer_offset + l,
                        layer,
                        s,
                        input.b_m,
                        input.extra_params[l],
                    )
                })
                .collect()
        })
        .collect();

    // §Perf: R(l, S_i, S_j) depends on the strategies only through their
    // batch-split degrees (transform.rs), so strategies collapse into a
    // handful of *split classes*. The DP transition then takes the min
    // over classes instead of over all |S| predecessors, cutting the inner
    // loop from O(|S|^2) to O(|S|·C), C = #distinct splits (<= 5 for 64
    // GPUs). See EXPERIMENTS.md §Perf for the before/after.
    let mut splits: Vec<usize> = input.strategies.iter().map(|s| s.batch_split()).collect();
    splits.sort_unstable();
    splits.dedup();
    let nc = splits.len();
    let class_of: Vec<usize> = input
        .strategies
        .iter()
        .map(|s| {
            splits
                .binary_search(&s.batch_split())
                .unwrap_or_else(|_| unreachable!("split deduped from this strategy set"))
        })
        .collect();
    // Representative strategy per class (transform cost only reads split).
    let class_rep: Vec<usize> = (0..nc)
        .map(|c| {
            class_of
                .iter()
                .position(|&x| x == c)
                .unwrap_or_else(|| unreachable!("every class has a member"))
        })
        .collect();
    // ...plus one per (layer ≥ 1, split-class pair). Per-microbatch values;
    // the core multiplies by m.
    let mut transforms: Vec<Vec<Vec<f64>>> = Vec::with_capacity(nl);
    transforms.push(Vec::new()); // unused for l=0
    for l in 1..nl {
        let mut mat = vec![vec![0.0; nc]; nc];
        for (ci, row) in mat.iter_mut().enumerate() {
            for (cj, cell) in row.iter_mut().enumerate() {
                *cell = input.costs.transform_cost_at(
                    input.layer_offset + l,
                    &input.layers[l],
                    &input.strategies[class_rep[ci]],
                    &input.strategies[class_rep[cj]],
                    input.b_m,
                );
            }
        }
        transforms.push(mat);
    }

    let active: Vec<usize> = (0..ns).collect();
    dp_stage_search(&DpStageInput {
        strategies: input.strategies,
        active: &active,
        class_of: &class_of,
        nc,
        layer_costs: rows.iter().map(Vec::as_slice).collect(),
        layer_transforms: transforms.iter().map(Vec::as_slice).collect(),
        microbatches: input.microbatches,
        live_mb: input.live_mb,
        mem_budget: input.mem_budget,
        granularity: input.granularity,
        bounds: false,
    })
    .0
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::cost::CostEstimator;
    use crate::model::model_by_name;
    use crate::search::decision_tree::{candidate_strategies, SpaceOptions};
    use crate::util::{GIB, MIB};

    fn setup(budget_gb: f64) -> (Vec<LayerProfile>, Vec<f64>, Vec<Strategy>, CostEstimator, f64) {
        let model = model_by_name("bert-huge-32").unwrap();
        let layers: Vec<_> = model.layers[..8].to_vec();
        let extra = vec![0.0; 8];
        let strategies = candidate_strategies(8, &SpaceOptions::default());
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(budget_gb * GIB);
        let est = CostEstimator::new(&cluster, 1, 1.3);
        (layers, extra, strategies, est, budget_gb * GIB)
    }

    fn run(budget_gb: f64, b_m: f64) -> Option<DpResult> {
        let (layers, extra, strategies, est, budget) = setup(budget_gb);
        dp_search(&DpInput {
            layers: &layers,
            extra_params: &extra,
            strategies: &strategies,
            costs: &est,
            layer_offset: 0,
            b_m,
            microbatches: 1,
            live_mb: 1,
            mem_budget: budget,
            granularity: 32.0 * MIB,
        })
    }

    #[test]
    fn finds_feasible_plan() {
        let r = run(16.0, 8.0).expect("feasible");
        assert_eq!(r.strategies.len(), 8);
        assert!(r.peak_mem <= 16.0 * GIB);
        assert!(r.cost_per_batch.is_finite() && r.cost_per_batch > 0.0);
    }

    #[test]
    fn respects_budget_always() {
        for gb in [4.0, 8.0, 16.0] {
            if let Some(r) = run(gb, 8.0) {
                assert!(r.peak_mem <= gb * GIB, "budget {gb} violated: {}", r.peak_mem / GIB);
            }
        }
    }

    #[test]
    fn cost_monotone_in_budget() {
        // More memory can only help (paper: optimal substructure).
        let c8 = run(8.0, 8.0).map(|r| r.cost_per_batch);
        let c16 = run(16.0, 8.0).map(|r| r.cost_per_batch);
        let c24 = run(24.0, 8.0).map(|r| r.cost_per_batch);
        if let (Some(a), Some(b)) = (c16, c24) {
            assert!(b <= a * 1.0001, "{b} vs {a}");
        }
        if let (Some(a), Some(b)) = (c8, c16) {
            assert!(b <= a * 1.0001);
        }
    }

    #[test]
    fn infeasible_when_tiny_budget() {
        assert!(run(0.25, 8.0).is_none());
    }

    #[test]
    fn tight_budget_prefers_memory_saving_strategies() {
        // Under a loose budget vs a tight one, the tight plan must use at
        // least as much state sharding or checkpointing.
        let loose = run(20.0, 8.0).unwrap();
        let tight = run(6.0, 8.0);
        if let Some(t) = tight {
            let shard = |r: &DpResult| {
                r.strategies
                    .iter()
                    .map(|s| s.state_shard() as f64 + if s.ckpt { 8.0 } else { 0.0 })
                    .sum::<f64>()
            };
            assert!(shard(&t) >= shard(&loose), "tight {} loose {}", shard(&t), shard(&loose));
            assert!(t.cost_per_batch >= loose.cost_per_batch * 0.999);
        }
    }

    #[test]
    fn matches_bruteforce_on_small_instance() {
        // 3 layers, uniform-strategy brute force (the DP also explores
        // non-uniform assignments, so dp <= best uniform).
        let model = model_by_name("bert-huge-32").unwrap();
        let layers = model.layers[..3].to_vec();
        let extra = vec![0.0; 3];
        let strategies = candidate_strategies(4, &SpaceOptions::default());
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 2, 1.3);
        let input = DpInput {
            layers: &layers,
            extra_params: &extra,
            strategies: &strategies,
            costs: &est,
            layer_offset: 0,
            b_m: 4.0,
            microbatches: 2,
            live_mb: 2,
            mem_budget: 24.0 * GIB,
            granularity: 16.0 * MIB,
        };
        let dp = dp_search(&input).unwrap();

        let mut best_uniform = f64::INFINITY;
        for s in &strategies {
            let mut total = 0.0;
            let mut mems = Vec::new();
            for (l, layer) in layers.iter().enumerate() {
                let c = est.layer_cost(layer, s, 4.0, extra[l]);
                total += 2.0 * (c.fwd + c.bwd) + (c.bwd_sync - c.bwd);
                mems.push(c.mem);
            }
            if stage_peak_memory(&mems, 2) <= 24.0 * GIB {
                best_uniform = best_uniform.min(total);
            }
        }
        assert!(
            dp.cost_per_batch <= best_uniform * 1.0001,
            "dp {} vs uniform {}",
            dp.cost_per_batch,
            best_uniform
        );
    }

    #[test]
    fn granularity_insensitivity() {
        let (layers, extra, strategies, est, budget) = setup(16.0);
        let mut costs = Vec::new();
        for gran in [16.0 * MIB, 64.0 * MIB] {
            let r = dp_search(&DpInput {
                layers: &layers,
                extra_params: &extra,
                strategies: &strategies,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 1,
                live_mb: 1,
                mem_budget: budget,
                granularity: gran,
            })
            .unwrap();
            costs.push(r.cost_per_batch);
        }
        let rel = (costs[0] - costs[1]).abs() / costs[0];
        assert!(rel < 0.10, "granularity changed cost by {:.1}%", rel * 100.0);
    }
}
