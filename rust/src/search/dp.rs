//! Dynamic-programming layer-strategy assignment (paper §IV-A2, Eq. 4,
//! Appendix A / Algorithm 3).
//!
//! For one pipeline stage (a layer sub-sequence on a device group) we
//! minimize the stage execution cost subject to the device memory budget.
//! Following Appendix A1, the DP state is (layer, forward-memory bucket,
//! strategy-of-last-layer): tracking forward memory E_f keeps the state
//! linear in the budget; the full Eq. 2 peak (which adds backward spikes
//! O_b and the 1F1B live-microbatch multiplier) is verified on the
//! backtraced solution, scanning candidate terminal states in cost order —
//! equivalent to Algorithm 3's E_fwd sweep.

use crate::cost::estimator::{LayerCost, StageCosts};
use crate::model::LayerProfile;
use crate::parallel::memory::stage_peak_memory;
use crate::parallel::Strategy;

/// Inputs for one stage-level DP search.
pub struct DpInput<'a> {
    /// The stage's layers, in order.
    pub layers: &'a [LayerProfile],
    /// Embedding/head params attributed to each layer (same length).
    pub extra_params: &'a [f64],
    /// Candidate strategies (all with degree == stage group size).
    pub strategies: &'a [Strategy],
    /// Cost source: a bare [`crate::cost::CostEstimator`] or the engine's
    /// shared memoized cache — the kernel itself stays cache-agnostic.
    pub costs: &'a dyn StageCosts,
    /// Model-global index of `layers[0]` (for cost-cache keying).
    pub layer_offset: usize,
    /// Microbatch size (global samples per microbatch).
    pub b_m: f64,
    /// Microbatches per global batch (m).
    pub microbatches: usize,
    /// Live microbatches at this stage's peak (1F1B: P - stage_idx).
    pub live_mb: usize,
    /// Device memory budget E, bytes.
    pub mem_budget: f64,
    /// Memory discretization granularity, bytes.
    pub granularity: f64,
}

/// Result of a stage-level DP search.
#[derive(Debug, Clone)]
pub struct DpResult {
    /// Per-global-batch stage time: m·(fwd+bwd+R) + grad-sync extra.
    pub cost_per_batch: f64,
    /// Per-microbatch stage time without gradient sync.
    pub time_nosync: f64,
    /// Per-microbatch stage time of the sync microbatch.
    pub time_sync: f64,
    /// Eq. 2 peak memory (bytes) with the live-microbatch multiplier.
    pub peak_mem: f64,
    /// Chosen strategy per layer.
    pub strategies: Vec<Strategy>,
}

const INF: f64 = f64::INFINITY;

/// Run the DP search; `None` if no assignment fits the budget.
pub fn dp_search(input: &DpInput) -> Option<DpResult> {
    let nl = input.layers.len();
    let ns = input.strategies.len();
    if nl == 0 || ns == 0 {
        return None;
    }
    let m = input.microbatches as f64;
    let buckets = (input.mem_budget / input.granularity).floor() as usize;
    if buckets == 0 {
        return None;
    }

    // ---- Precompute per-(layer, strategy) costs and weights -------------
    // weight = forward-memory share: model states + live·O_f (Eq. 3 with
    // the schedule's live multiplier).
    let mut cost: Vec<Vec<LayerCost>> = Vec::with_capacity(nl);
    let mut weight: Vec<Vec<usize>> = Vec::with_capacity(nl);
    let mut batch_cost: Vec<Vec<f64>> = Vec::with_capacity(nl);
    for (l, layer) in input.layers.iter().enumerate() {
        let mut crow = Vec::with_capacity(ns);
        let mut wrow = Vec::with_capacity(ns);
        let mut brow = Vec::with_capacity(ns);
        for s in input.strategies {
            let c = input.costs.layer_cost_at(
                input.layer_offset + l,
                layer,
                s,
                input.b_m,
                input.extra_params[l],
            );
            let fwd_bytes = c.mem.o_ms + input.live_mb as f64 * c.mem.o_f;
            wrow.push((fwd_bytes / input.granularity).ceil() as usize);
            brow.push(m * (c.fwd + c.bwd) + (c.bwd_sync - c.bwd));
            crow.push(c);
        }
        cost.push(crow);
        weight.push(wrow);
        batch_cost.push(brow);
    }

    // Transform costs R between consecutive layers (per batch: m times).
    //
    // §Perf: R(l, S_i, S_j) depends on the strategies only through their
    // batch-split degrees (transform.rs), so strategies collapse into a
    // handful of *split classes*. The DP transition then takes the min
    // over classes instead of over all |S| predecessors, cutting the inner
    // loop from O(|S|^2) to O(|S|·C), C = #distinct splits (<= 5 for 64
    // GPUs). See EXPERIMENTS.md §Perf for the before/after.
    let mut splits: Vec<usize> = input.strategies.iter().map(|s| s.batch_split()).collect();
    splits.sort_unstable();
    splits.dedup();
    let nc = splits.len();
    let class_of: Vec<usize> = input
        .strategies
        .iter()
        .map(|s| {
            splits
                .binary_search(&s.batch_split())
                .unwrap_or_else(|_| unreachable!("split deduped from this strategy set"))
        })
        .collect();
    // Representative strategy per class (transform cost only reads split).
    let class_rep: Vec<usize> = (0..nc)
        .map(|c| {
            class_of
                .iter()
                .position(|&x| x == c)
                .unwrap_or_else(|| unreachable!("every class has a member"))
        })
        .collect();
    // r_class[l][ci][cj]: per-batch transform cost between split classes.
    let mut r_class: Vec<Vec<Vec<f64>>> = Vec::with_capacity(nl);
    r_class.push(vec![vec![0.0; nc]; 1]); // unused for l=0
    for l in 1..nl {
        let mut mat = vec![vec![0.0; nc]; nc];
        for ci in 0..nc {
            for cj in 0..nc {
                mat[ci][cj] = m * input.costs.transform_cost_at(
                    input.layer_offset + l,
                    &input.layers[l],
                    &input.strategies[class_rep[ci]],
                    &input.strategies[class_rep[cj]],
                    input.b_m,
                );
            }
        }
        r_class.push(mat);
    }
    let r_between = |l: usize, i: usize, j: usize| r_class[l][class_of[i]][class_of[j]];

    // ---- DP table --------------------------------------------------------
    // dp[e][j]: min per-batch cost of layers 0..=l with exactly e buckets of
    // forward memory used and layer l running strategy j.
    let width = buckets + 1;
    let mut prev = vec![INF; width * ns];
    let mut parent: Vec<Vec<u32>> = Vec::with_capacity(nl);

    // Layer 0.
    let mut p0 = vec![u32::MAX; width * ns];
    for j in 0..ns {
        let w = weight[0][j];
        if w <= buckets {
            let idx = w * ns + j;
            if batch_cost[0][j] < prev[idx] {
                prev[idx] = batch_cost[0][j];
                p0[idx] = j as u32; // self-marker
            }
        }
    }
    parent.push(p0);

    for l in 1..nl {
        let mut cur = vec![INF; width * ns];
        let mut par = vec![u32::MAX; width * ns];
        let mut best_class = vec![(INF, 0u32); nc];
        for e_prev in 0..width {
            let base = e_prev * ns;
            // Collapse predecessors into split classes: min cost + argmin.
            for b in best_class.iter_mut() {
                *b = (INF, 0);
            }
            let mut any = false;
            for i in 0..ns {
                let c_prev = prev[base + i];
                if c_prev < best_class[class_of[i]].0 {
                    best_class[class_of[i]] = (c_prev, (base + i) as u32);
                    any = true;
                }
            }
            if !any {
                continue; // empty column
            }
            for j in 0..ns {
                let w = weight[l][j];
                let e = e_prev + w;
                if e > buckets {
                    continue;
                }
                let cj = class_of[j];
                let mut best = INF;
                let mut best_par = u32::MAX;
                for (ci, &(c_prev, par_idx)) in best_class.iter().enumerate() {
                    if !c_prev.is_finite() {
                        continue;
                    }
                    let c = c_prev + r_class[l][ci][cj];
                    if c < best {
                        best = c;
                        best_par = par_idx;
                    }
                }
                if !best.is_finite() {
                    continue;
                }
                let c = best + batch_cost[l][j];
                let idx = e * ns + j;
                if c < cur[idx] {
                    cur[idx] = c;
                    par[idx] = best_par;
                }
            }
        }
        parent.push(par);
        prev = cur;
    }

    // ---- Pick the cheapest terminal state whose true Eq. 2 peak fits ----
    let mut terminals: Vec<(f64, usize)> = prev
        .iter()
        .enumerate()
        .filter(|(_, c)| c.is_finite())
        .map(|(idx, c)| (*c, idx))
        .collect();
    terminals.sort_by(|a, b| a.0.total_cmp(&b.0));

    for (c_batch, term_idx) in terminals {
        // Backtrace.
        let mut choice = vec![0usize; nl];
        let mut idx = term_idx;
        for l in (0..nl).rev() {
            choice[l] = idx % ns;
            if l > 0 {
                idx = parent[l][idx] as usize;
                debug_assert_ne!(idx, u32::MAX as usize);
            }
        }
        // True peak (Eq. 2 with live multiplier).
        let mems: Vec<_> = (0..nl).map(|l| cost[l][choice[l]].mem).collect();
        let peak = stage_peak_memory(&mems, input.live_mb);
        if peak <= input.mem_budget {
            let mut nosync = 0.0;
            let mut sync = 0.0;
            for l in 0..nl {
                let c = &cost[l][choice[l]];
                nosync += c.fwd + c.bwd;
                sync += c.fwd + c.bwd_sync;
                if l > 0 {
                    let rt = r_between(l, choice[l - 1], choice[l]) / m;
                    nosync += rt;
                    sync += rt;
                }
            }
            return Some(DpResult {
                cost_per_batch: c_batch,
                time_nosync: nosync,
                time_sync: sync,
                peak_mem: peak,
                strategies: choice.iter().map(|&j| input.strategies[j].clone()).collect(),
            });
        }
    }
    None
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::cost::CostEstimator;
    use crate::model::model_by_name;
    use crate::search::decision_tree::{candidate_strategies, SpaceOptions};
    use crate::util::{GIB, MIB};

    fn setup(budget_gb: f64) -> (Vec<LayerProfile>, Vec<f64>, Vec<Strategy>, CostEstimator, f64) {
        let model = model_by_name("bert-huge-32").unwrap();
        let layers: Vec<_> = model.layers[..8].to_vec();
        let extra = vec![0.0; 8];
        let strategies = candidate_strategies(8, &SpaceOptions::default());
        let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(budget_gb * GIB);
        let est = CostEstimator::new(&cluster, 1, 1.3);
        (layers, extra, strategies, est, budget_gb * GIB)
    }

    fn run(budget_gb: f64, b_m: f64) -> Option<DpResult> {
        let (layers, extra, strategies, est, budget) = setup(budget_gb);
        dp_search(&DpInput {
            layers: &layers,
            extra_params: &extra,
            strategies: &strategies,
            costs: &est,
            layer_offset: 0,
            b_m,
            microbatches: 1,
            live_mb: 1,
            mem_budget: budget,
            granularity: 32.0 * MIB,
        })
    }

    #[test]
    fn finds_feasible_plan() {
        let r = run(16.0, 8.0).expect("feasible");
        assert_eq!(r.strategies.len(), 8);
        assert!(r.peak_mem <= 16.0 * GIB);
        assert!(r.cost_per_batch.is_finite() && r.cost_per_batch > 0.0);
    }

    #[test]
    fn respects_budget_always() {
        for gb in [4.0, 8.0, 16.0] {
            if let Some(r) = run(gb, 8.0) {
                assert!(r.peak_mem <= gb * GIB, "budget {gb} violated: {}", r.peak_mem / GIB);
            }
        }
    }

    #[test]
    fn cost_monotone_in_budget() {
        // More memory can only help (paper: optimal substructure).
        let c8 = run(8.0, 8.0).map(|r| r.cost_per_batch);
        let c16 = run(16.0, 8.0).map(|r| r.cost_per_batch);
        let c24 = run(24.0, 8.0).map(|r| r.cost_per_batch);
        if let (Some(a), Some(b)) = (c16, c24) {
            assert!(b <= a * 1.0001, "{b} vs {a}");
        }
        if let (Some(a), Some(b)) = (c8, c16) {
            assert!(b <= a * 1.0001);
        }
    }

    #[test]
    fn infeasible_when_tiny_budget() {
        assert!(run(0.25, 8.0).is_none());
    }

    #[test]
    fn tight_budget_prefers_memory_saving_strategies() {
        // Under a loose budget vs a tight one, the tight plan must use at
        // least as much state sharding or checkpointing.
        let loose = run(20.0, 8.0).unwrap();
        let tight = run(6.0, 8.0);
        if let Some(t) = tight {
            let shard = |r: &DpResult| {
                r.strategies
                    .iter()
                    .map(|s| s.state_shard() as f64 + if s.ckpt { 8.0 } else { 0.0 })
                    .sum::<f64>()
            };
            assert!(shard(&t) >= shard(&loose), "tight {} loose {}", shard(&t), shard(&loose));
            assert!(t.cost_per_batch >= loose.cost_per_batch * 0.999);
        }
    }

    #[test]
    fn matches_bruteforce_on_small_instance() {
        // 3 layers, uniform-strategy brute force (the DP also explores
        // non-uniform assignments, so dp <= best uniform).
        let model = model_by_name("bert-huge-32").unwrap();
        let layers = model.layers[..3].to_vec();
        let extra = vec![0.0; 3];
        let strategies = candidate_strategies(4, &SpaceOptions::default());
        let cluster = cluster_by_name("titan8").unwrap();
        let est = CostEstimator::new(&cluster, 2, 1.3);
        let input = DpInput {
            layers: &layers,
            extra_params: &extra,
            strategies: &strategies,
            costs: &est,
            layer_offset: 0,
            b_m: 4.0,
            microbatches: 2,
            live_mb: 2,
            mem_budget: 24.0 * GIB,
            granularity: 16.0 * MIB,
        };
        let dp = dp_search(&input).unwrap();

        let mut best_uniform = f64::INFINITY;
        for s in &strategies {
            let mut total = 0.0;
            let mut mems = Vec::new();
            for (l, layer) in layers.iter().enumerate() {
                let c = est.layer_cost(layer, s, 4.0, extra[l]);
                total += 2.0 * (c.fwd + c.bwd) + (c.bwd_sync - c.bwd);
                mems.push(c.mem);
            }
            if stage_peak_memory(&mems, 2) <= 24.0 * GIB {
                best_uniform = best_uniform.min(total);
            }
        }
        assert!(
            dp.cost_per_batch <= best_uniform * 1.0001,
            "dp {} vs uniform {}",
            dp.cost_per_batch,
            best_uniform
        );
    }

    #[test]
    fn granularity_insensitivity() {
        let (layers, extra, strategies, est, budget) = setup(16.0);
        let mut costs = Vec::new();
        for gran in [16.0 * MIB, 64.0 * MIB] {
            let r = dp_search(&DpInput {
                layers: &layers,
                extra_params: &extra,
                strategies: &strategies,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 1,
                live_mb: 1,
                mem_budget: budget,
                granularity: gran,
            })
            .unwrap();
            costs.push(r.cost_per_batch);
        }
        let rel = (costs[0] - costs[1]).abs() / costs[0];
        assert!(rel < 0.10, "granularity changed cost by {:.1}%", rel * 100.0);
    }
}
