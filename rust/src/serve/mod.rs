//! `galvatron serve`: a long-lived planning-as-a-service daemon.
//!
//! The daemon keeps one immutable world resident — zoo specs, cluster
//! presets, cost model, and the warm persistent caches under
//! `--cache-dir` — and answers [`crate::api::PlanRequest`]-shaped JSON
//! over two zero-dependency transports:
//!
//! * **JSONL** (default): one request per stdin line, one response per
//!   stdout line, exit at EOF ([`run_jsonl`]).
//! * **HTTP/1.1** (`--http ADDR`): a hand-rolled listener over
//!   [`std::net::TcpListener`] ([`http::serve_http`]).
//!
//! Three layers make repeat work cheap, every one re-proved by the same
//! `check` gate a cold plan passes through:
//!
//! 1. **In-flight dedup** — a request identical (by
//!    [`crate::api::request_fingerprint`]) to one currently being
//!    planned blocks on that search's result instead of re-searching.
//! 2. **In-memory memo** — a fingerprint answered before in this
//!    process returns its retained artifact.
//! 3. **Persistent store** — the PR 7 `--cache-dir` plan store and cost
//!    tables, shared with the CLI, which make a *freshly started*
//!    daemon warm.
//!
//! Artifacts are byte-identical to `galvatron plan`: the daemon hands
//! out `PlanReport::to_json_string()` bytes verbatim (the `out` request
//! key and the HTTP `/plan/artifact` endpoint), never a re-serialization.
//!
//! Concurrent searches share the machine through the process-wide
//! [`crate::util::parallelism::WorkerBudget`], installed once at daemon
//! startup: each search's waves draw workers from the shared budget
//! instead of every request spawning a full pool.

pub mod http;
pub mod protocol;

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};

use crate::api::{request_fingerprint, PlanReport, PlanSource, Planner};
use crate::util::json::Json;

pub use http::serve_http;
pub use protocol::{plan_error_kind, ServeError, REQUEST_KEYS};

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Default bound on the in-memory memo: retained artifacts beyond this
/// count evict the least-recently-used entry, so a daemon fed a stream of
/// distinct requests holds a bounded working set instead of growing
/// without limit. The persistent `--cache-dir` store remains the durable
/// tier — an evicted entry that recurs is re-answered from there.
pub const MEMO_CAPACITY: usize = 256;

/// Monotonic counters over the daemon's lifetime, served on `/health`.
#[derive(Default)]
pub struct ServeStats {
    requests: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    /// Request-level warm hits from the persistent plan store.
    store_hits: AtomicU64,
    /// Hits on the daemon's in-memory memo of past answers.
    memo_hits: AtomicU64,
    /// Requests answered from an identical in-flight computation.
    dedup_hits: AtomicU64,
    /// Requests that ran a fresh search.
    searched: AtomicU64,
    /// Memo entries dropped to stay under the capacity bound.
    memo_evictions: AtomicU64,
}

/// A point-in-time copy of [`ServeStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub ok: u64,
    pub errors: u64,
    pub store_hits: u64,
    pub memo_hits: u64,
    pub dedup_hits: u64,
    pub searched: u64,
    pub memo_evictions: u64,
}

impl StatsSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("store_hits", Json::num(self.store_hits as f64)),
            ("memo_hits", Json::num(self.memo_hits as f64)),
            ("dedup_hits", Json::num(self.dedup_hits as f64)),
            ("searched", Json::num(self.searched as f64)),
            ("memo_evictions", Json::num(self.memo_evictions as f64)),
        ])
    }
}

/// Terminal state of one planning computation, shared with every request
/// deduplicated onto it.
#[derive(Clone)]
enum Done {
    Ok {
        /// `"hit"` or `"miss"` — how the leader got the answer.
        cache: &'static str,
        /// Exact `PlanReport::to_json_string()` bytes.
        artifact: Arc<String>,
        /// Parsed artifact value for the response envelope.
        report: Arc<Json>,
        warnings: Arc<Vec<String>>,
    },
    Err {
        kind: &'static str,
        message: Arc<String>,
        warnings: Arc<Vec<String>>,
    },
}

/// One in-flight computation: the first arrival (leader) fills `done`
/// and notifies; identical requests arriving meanwhile (waiters) block
/// on the condvar and share the result.
struct InFlight {
    done: Mutex<Option<Done>>,
    cond: Condvar,
}

impl InFlight {
    fn new() -> InFlight {
        InFlight { done: Mutex::new(None), cond: Condvar::new() }
    }

    fn complete(&self, done: Done) {
        let mut slot = lock(&self.done);
        if slot.is_none() {
            *slot = Some(done);
        }
        drop(slot);
        self.cond.notify_all();
    }

    fn wait(&self) -> Done {
        let mut slot = lock(&self.done);
        loop {
            if let Some(done) = slot.as_ref() {
                return done.clone();
            }
            slot = self.cond.wait(slot).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// A memoized answer retained until capacity pressure evicts it.
#[derive(Clone)]
struct MemoEntry {
    report: PlanReport,
    artifact: Arc<String>,
    /// Tick from [`ServeState::memo_clock`] at the last hit or insert;
    /// the eviction victim is the minimum. Ticks are unique, so the
    /// victim is deterministic.
    last_used: u64,
}

/// The daemon's shared immutable world plus its request-coordination
/// state. One instance serves every connection of a daemon; it is also
/// constructed directly by tests and benches to drive the serving path
/// in-process.
pub struct ServeState {
    planner: Planner,
    cache_dir: Option<PathBuf>,
    stats: ServeStats,
    inflight: Mutex<HashMap<u64, Arc<InFlight>>>,
    memo: Mutex<HashMap<u64, MemoEntry>>,
    /// LRU bound on `memo`; `0` disables memoization entirely.
    memo_capacity: usize,
    /// Monotonic recency ticks for `MemoEntry::last_used`.
    memo_clock: AtomicU64,
}

/// What one request produced: the response envelope (one JSONL line /
/// HTTP body) plus, on success, the exact artifact bytes.
pub struct ServeOutcome {
    pub ok: bool,
    pub envelope: Json,
    /// `PlanReport::to_json_string()` bytes, present iff `ok`.
    pub artifact: Option<Arc<String>>,
}

impl ServeState {
    /// `cache_dir` is attached to every request (requests cannot override
    /// it); `None` plans without persistence unless `GALVATRON_CACHE_DIR`
    /// is set, mirroring the CLI.
    pub fn new(cache_dir: Option<PathBuf>) -> ServeState {
        ServeState::with_memo_capacity(cache_dir, MEMO_CAPACITY)
    }

    /// [`ServeState::new`] with an explicit memo bound (tests shrink it to
    /// exercise eviction; `0` turns the memo tier off).
    pub fn with_memo_capacity(cache_dir: Option<PathBuf>, memo_capacity: usize) -> ServeState {
        ServeState {
            planner: Planner::new(),
            cache_dir,
            stats: ServeStats::default(),
            inflight: Mutex::new(HashMap::new()),
            memo: Mutex::new(HashMap::new()),
            memo_capacity,
            memo_clock: AtomicU64::new(0),
        }
    }

    pub fn stats(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.stats.requests.load(Ordering::SeqCst),
            ok: self.stats.ok.load(Ordering::SeqCst),
            errors: self.stats.errors.load(Ordering::SeqCst),
            store_hits: self.stats.store_hits.load(Ordering::SeqCst),
            memo_hits: self.stats.memo_hits.load(Ordering::SeqCst),
            dedup_hits: self.stats.dedup_hits.load(Ordering::SeqCst),
            searched: self.stats.searched.load(Ordering::SeqCst),
            memo_evictions: self.stats.memo_evictions.load(Ordering::SeqCst),
        }
    }

    /// Memo entries currently retained (diagnostics/tests).
    pub fn memo_len(&self) -> usize {
        lock(&self.memo).len()
    }

    /// Requests currently registered as in-flight (diagnostics/tests).
    pub fn inflight_len(&self) -> usize {
        lock(&self.inflight).len()
    }

    /// Handle one request line (raw JSON text).
    pub fn handle_line(&self, line: &str) -> ServeOutcome {
        match Json::parse(line) {
            Ok(v) => self.handle_value(&v),
            Err(e) => self.finish_error(
                None,
                "parse",
                &format!("request is not valid JSON: {e}"),
                &[],
            ),
        }
    }

    /// Handle one parsed request value.
    pub fn handle_value(&self, v: &Json) -> ServeOutcome {
        self.handle_value_with(v, || {})
    }

    /// [`ServeState::handle_value`] with a test seam: `after_register`
    /// runs iff this request became the leader for its fingerprint,
    /// after it registered as in-flight and before it computes —
    /// letting tests hold a search open while identical requests arrive.
    pub fn handle_value_with(&self, v: &Json, after_register: impl FnOnce()) -> ServeOutcome {
        self.stats.requests.fetch_add(1, Ordering::SeqCst);
        let id = v.get("id").cloned();
        let parsed = match protocol::parse_request(v) {
            Ok(p) => p,
            Err(e) => return self.finish_error(id.as_ref(), e.kind, &e.message, &[]),
        };
        let mut req = parsed.request;
        if req.cache_dir.is_none() {
            req.cache_dir.clone_from(&self.cache_dir);
        }
        let resolved = match self.planner.resolve(&req) {
            Ok(r) => r,
            Err(e) => {
                return self.finish_error(id.as_ref(), plan_error_kind(&e), &e.to_string(), &[])
            }
        };
        let fp = request_fingerprint(&resolved);

        enum Role {
            Leader(Arc<InFlight>),
            Waiter(Arc<InFlight>),
        }
        let role = {
            let mut inflight = lock(&self.inflight);
            match inflight.get(&fp) {
                Some(flight) => Role::Waiter(Arc::clone(flight)),
                None => {
                    let flight = Arc::new(InFlight::new());
                    inflight.insert(fp, Arc::clone(&flight));
                    Role::Leader(flight)
                }
            }
        };
        let (done, dedup) = match role {
            Role::Waiter(flight) => {
                self.stats.dedup_hits.fetch_add(1, Ordering::SeqCst);
                (flight.wait(), true)
            }
            Role::Leader(flight) => {
                // Guarantee waiters are released and the slot is freed
                // even if the computation panics.
                struct LeaderGuard<'a> {
                    state: &'a ServeState,
                    flight: &'a InFlight,
                    fp: u64,
                    completed: bool,
                }
                impl Drop for LeaderGuard<'_> {
                    fn drop(&mut self) {
                        if !self.completed {
                            self.flight.complete(Done::Err {
                                kind: "internal",
                                message: Arc::new("request handler panicked".to_string()),
                                warnings: Arc::new(Vec::new()),
                            });
                        }
                        lock(&self.state.inflight).remove(&self.fp);
                    }
                }
                let mut guard =
                    LeaderGuard { state: self, flight: &flight, fp, completed: false };
                after_register();
                let done = self.compute(&resolved, fp);
                flight.complete(done.clone());
                guard.completed = true;
                drop(guard);
                (done, false)
            }
        };

        match done {
            Done::Ok { cache, artifact, report, warnings } => {
                let cache = if dedup { "dedup" } else { cache };
                // Each request honors its own `out` path, waiters included.
                if let Some(path) = &parsed.out {
                    if let Err(e) = std::fs::write(path, artifact.as_bytes()) {
                        return self.finish_error(
                            id.as_ref(),
                            "io",
                            &format!("could not write artifact to {}: {e}", path.display()),
                            &warnings,
                        );
                    }
                }
                self.stats.ok.fetch_add(1, Ordering::SeqCst);
                let out = parsed.out.as_deref().map(|p| p.display().to_string());
                ServeOutcome {
                    ok: true,
                    envelope: protocol::ok_response(
                        id.as_ref(),
                        cache,
                        out.as_deref(),
                        &warnings,
                        (*report).clone(),
                    ),
                    artifact: Some(artifact),
                }
            }
            Done::Err { kind, message, warnings } => {
                self.finish_error(id.as_ref(), kind, &message, &warnings)
            }
        }
    }

    /// Resolve a fingerprint to an answer: memo, persistent store, or a
    /// fresh search — capturing every warning the attempt emits.
    fn compute(&self, r: &crate::api::ResolvedRequest, fp: u64) -> Done {
        // Bind before the `if let`: a temporary guard in the scrutinee
        // would live for the whole block and deadlock on the remove below.
        let memo_entry = {
            let mut memo = lock(&self.memo);
            memo.get_mut(&fp).map(|entry| {
                entry.last_used = self.memo_clock.fetch_add(1, Ordering::SeqCst);
                entry.clone()
            })
        };
        if let Some(entry) = memo_entry {
            // Same re-proving discipline as the persistent store: a memo
            // entry that no longer passes the gate is dropped, not served.
            if crate::check::gate(&r.model, &r.cluster, &entry.report).is_ok() {
                self.stats.memo_hits.fetch_add(1, Ordering::SeqCst);
                return Done::Ok {
                    cache: "hit",
                    artifact: entry.artifact,
                    report: Arc::new(entry.report.to_json()),
                    warnings: Arc::new(Vec::new()),
                };
            }
            lock(&self.memo).remove(&fp);
        }
        let (result, warnings) =
            crate::util::diag::capture(|| self.planner.plan_resolved_sourced(r));
        match result {
            Ok((report, source)) => {
                let cache = match source {
                    PlanSource::Stored => {
                        self.stats.store_hits.fetch_add(1, Ordering::SeqCst);
                        "hit"
                    }
                    PlanSource::Searched => {
                        self.stats.searched.fetch_add(1, Ordering::SeqCst);
                        "miss"
                    }
                };
                let artifact = Arc::new(report.to_json_string());
                let report_json = Arc::new(report.to_json());
                if self.memo_capacity > 0 {
                    let mut memo = lock(&self.memo);
                    if !memo.contains_key(&fp) && memo.len() >= self.memo_capacity {
                        // Evict the least-recently-used entry; recency
                        // ticks are unique, so the victim is deterministic.
                        if let Some((&victim, _)) =
                            memo.iter().min_by_key(|(_, entry)| entry.last_used)
                        {
                            memo.remove(&victim);
                            self.stats.memo_evictions.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    let last_used = self.memo_clock.fetch_add(1, Ordering::SeqCst);
                    memo.insert(
                        fp,
                        MemoEntry { report, artifact: Arc::clone(&artifact), last_used },
                    );
                }
                Done::Ok {
                    cache,
                    artifact,
                    report: report_json,
                    warnings: Arc::new(warnings),
                }
            }
            Err(e) => Done::Err {
                kind: plan_error_kind(&e),
                message: Arc::new(e.to_string()),
                warnings: Arc::new(warnings),
            },
        }
    }

    fn finish_error(
        &self,
        id: Option<&Json>,
        kind: &str,
        message: &str,
        warnings: &[String],
    ) -> ServeOutcome {
        self.stats.errors.fetch_add(1, Ordering::SeqCst);
        ServeOutcome {
            ok: false,
            envelope: protocol::error_response(id, kind, message, warnings),
            artifact: None,
        }
    }

    /// `/health` payload.
    pub fn health_json(&self) -> Json {
        Json::obj(vec![
            ("status", Json::str("ok")),
            (
                "memo",
                Json::obj(vec![
                    ("entries", Json::num(self.memo_len() as f64)),
                    ("capacity", Json::num(self.memo_capacity as f64)),
                ]),
            ),
            ("stats", self.stats().to_json()),
        ])
    }

    /// Handle one capacity-advice request (raw JSON text): the `POST
    /// /advise` endpoint. Sweeps are not memoized or deduplicated — each
    /// one replans through the shared `cache_dir`, which already answers
    /// repeat fleets from the warm store.
    pub fn handle_advise(&self, text: &str) -> ServeOutcome {
        self.stats.requests.fetch_add(1, Ordering::SeqCst);
        let v = match Json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                return self.finish_error(
                    None,
                    "parse",
                    &format!("request is not valid JSON: {e}"),
                    &[],
                )
            }
        };
        let id = v.get("id").cloned();
        let parsed = match protocol::parse_advise_request(&v) {
            Ok(p) => p,
            Err(e) => return self.finish_error(id.as_ref(), e.kind, &e.message, &[]),
        };
        let mut req = parsed.request;
        if req.cache_dir.is_none() {
            req.cache_dir.clone_from(&self.cache_dir);
        }
        let (result, warnings) = crate::util::diag::capture(|| crate::advise::advise(&req));
        match result {
            Ok(frontier) => {
                let artifact = Arc::new(frontier.to_pretty_string());
                if let Some(path) = &parsed.out {
                    if let Err(e) = std::fs::write(path, artifact.as_bytes()) {
                        return self.finish_error(
                            id.as_ref(),
                            "io",
                            &format!("could not write artifact to {}: {e}", path.display()),
                            &warnings,
                        );
                    }
                }
                self.stats.ok.fetch_add(1, Ordering::SeqCst);
                let out = parsed.out.as_deref().map(|p| p.display().to_string());
                ServeOutcome {
                    ok: true,
                    envelope: protocol::ok_response(
                        id.as_ref(),
                        "miss",
                        out.as_deref(),
                        &warnings,
                        frontier.to_json(),
                    ),
                    artifact: Some(artifact),
                }
            }
            Err(e) => {
                self.finish_error(id.as_ref(), plan_error_kind(&e), &e.to_string(), &warnings)
            }
        }
    }
}

/// Drive the daemon over JSONL: one request per input line, one response
/// envelope per output line. Responses stream in completion order —
/// match them to requests by the echoed `id`; with `workers == 1` they
/// are strictly in request order. Returns when the reader reaches EOF
/// and every accepted request has been answered.
pub fn run_jsonl<R, W>(
    state: &Arc<ServeState>,
    reader: R,
    writer: W,
    workers: usize,
) -> std::io::Result<()>
where
    R: BufRead,
    W: Write + Send,
{
    let workers = workers.max(1);
    std::thread::scope(|scope| -> std::io::Result<()> {
        let (response_tx, response_rx) = mpsc::channel::<String>();
        // One writer thread serializes output so responses never interleave.
        let writer_thread = scope.spawn(move || -> std::io::Result<()> {
            let mut writer = writer;
            for line in response_rx {
                writeln!(writer, "{line}")?;
                writer.flush()?;
            }
            Ok(())
        });
        {
            // Bounded job queue: a flood of input lines backpressures the
            // reader instead of buffering unboundedly.
            let (job_tx, job_rx) = mpsc::sync_channel::<String>(workers);
            let job_rx = Arc::new(Mutex::new(job_rx));
            for _ in 0..workers {
                let job_rx = Arc::clone(&job_rx);
                let response_tx = response_tx.clone();
                let state = Arc::clone(state);
                scope.spawn(move || loop {
                    let job = {
                        let rx = job_rx.lock().unwrap_or_else(PoisonError::into_inner);
                        rx.recv()
                    };
                    let Ok(line) = job else { break };
                    if line.trim().is_empty() {
                        continue;
                    }
                    let outcome = state.handle_line(&line);
                    if response_tx.send(outcome.envelope.to_string()).is_err() {
                        break;
                    }
                });
            }
            drop(response_tx);
            for line in reader.lines() {
                let line = line?;
                if job_tx.send(line).is_err() {
                    break;
                }
            }
            // job_tx drops here: workers drain the queue and exit, the
            // last response_tx clone drops, and the writer finishes.
        }
        match writer_thread.join() {
            Ok(result) => result,
            Err(_) => Ok(()),
        }
    })
}
