//! Minimal HTTP/1.1 front end for the serve daemon, hand-rolled over
//! [`std::net::TcpListener`] per the repo's zero-dependency policy.
//! `Content-Length` bodies only, no TLS. Connections close after one
//! response unless the client opts in with an explicit `Connection:
//! keep-alive` header, in which case the handler loops on the socket
//! (bounded by the 30s read timeout) and echoes `connection: keep-alive`
//! back — clients that read until EOF keep working unchanged.
//!
//! Routes:
//!
//! * `POST /plan` — body is one serve request object; responds with the
//!   JSON envelope ([`super::protocol`]). `200` on `status:"ok"`, `400`
//!   on `status:"error"`.
//! * `POST /plan/artifact` — same request; responds with the **raw plan
//!   artifact bytes**, byte-identical to `galvatron plan --out` (this is
//!   what `cmp`-based gates should fetch). Errors return the envelope
//!   with `400`.
//! * `POST /advise` — body is one advise request object
//!   ([`super::protocol::ADVISE_REQUEST_KEYS`]); responds with the
//!   envelope whose `report` is the frontier artifact value.
//! * `GET /health` — liveness plus the daemon's counters.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::time::Duration;

use super::{protocol, ServeState};

/// Largest accepted request body; a plan request is a few hundred bytes,
/// so this is generous headroom, not a real limit.
const MAX_BODY: usize = 8 * 1024 * 1024;
const MAX_HEADER_LINES: usize = 100;

/// Accept loop: serves `listener` until the process exits, dispatching
/// connections to `workers` handler threads. Blocks the calling thread.
pub fn serve_http(
    listener: TcpListener,
    state: Arc<ServeState>,
    workers: usize,
) -> std::io::Result<()> {
    let workers = workers.max(1);
    std::thread::scope(|scope| {
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for _ in 0..workers {
            let conn_rx = Arc::clone(&conn_rx);
            let state = Arc::clone(&state);
            scope.spawn(move || loop {
                let conn = {
                    let rx = conn_rx.lock().unwrap_or_else(PoisonError::into_inner);
                    rx.recv()
                };
                let Ok(stream) = conn else { break };
                handle_connection(stream, &state);
            });
        }
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    if conn_tx.send(s).is_err() {
                        break;
                    }
                }
                // Transient accept errors (EMFILE, aborted handshakes)
                // must not kill the daemon.
                Err(_) => continue,
            }
        }
        drop(conn_tx);
        Ok(())
    })
}

/// One parsed HTTP request off a connection.
struct Request {
    method: String,
    path: String,
    body: Vec<u8>,
    /// The client sent an explicit `Connection: keep-alive` header.
    keep_alive: bool,
}

/// Why [`read_request`] produced no request.
enum ReadError {
    /// The peer closed (or idled past the read timeout) between requests
    /// — a normal end of a keep-alive conversation, nothing to answer.
    Closed,
    /// The stream held bytes that do not form an HTTP request.
    Malformed(String),
}

fn handle_connection(stream: TcpStream, state: &ServeState) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    // One buffered reader for the connection's lifetime: a per-request
    // reader would discard bytes of the next pipelined request that it
    // buffered past the current body.
    let mut reader = BufReader::new(&stream);
    loop {
        match read_request(&mut reader) {
            Ok(req) => {
                respond(&stream, state, &req);
                if !req.keep_alive {
                    break;
                }
            }
            Err(ReadError::Closed) => break,
            Err(ReadError::Malformed(reason)) => {
                let envelope = protocol::error_response(
                    None,
                    "parse",
                    &format!("malformed HTTP request: {reason}"),
                    &[],
                );
                write_response(&stream, 400, "Bad Request", envelope.to_string().as_bytes(), false);
                break;
            }
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Parse request line, headers (`Content-Length` and `Connection` matter),
/// and body.
fn read_request(reader: &mut BufReader<&TcpStream>) -> Result<Request, ReadError> {
    let malformed = |e: std::io::Error| ReadError::Malformed(e.to_string());
    let mut line = String::new();
    match reader.read_line(&mut line) {
        // EOF before any byte of a request line: the peer is done.
        Ok(0) => return Err(ReadError::Closed),
        Ok(_) => {}
        // An idle keep-alive socket hitting the read timeout is a normal
        // close, not a protocol error to answer with a 400.
        Err(e)
            if line.is_empty()
                && matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
        {
            return Err(ReadError::Closed)
        }
        Err(e) => return Err(malformed(e)),
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("empty request line".to_string()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ReadError::Malformed("request line has no path".to_string()))?
        .to_string();
    let mut content_length = 0usize;
    let mut keep_alive = false;
    let mut saw_blank = false;
    for _ in 0..MAX_HEADER_LINES {
        let mut header = String::new();
        let n = reader.read_line(&mut header).map_err(malformed)?;
        if n == 0 {
            return Err(ReadError::Malformed("connection closed mid-headers".to_string()));
        }
        let header = header.trim();
        if header.is_empty() {
            saw_blank = true;
            break;
        }
        if let Some((key, value)) = header.split_once(':') {
            let key = key.trim();
            if key.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| ReadError::Malformed("unparsable Content-Length".to_string()))?;
            } else if key.eq_ignore_ascii_case("connection") {
                keep_alive = value.trim().eq_ignore_ascii_case("keep-alive");
            }
        }
    }
    if !saw_blank {
        return Err(ReadError::Malformed(format!("more than {MAX_HEADER_LINES} header lines")));
    }
    if content_length > MAX_BODY {
        return Err(ReadError::Malformed(format!(
            "body of {content_length} bytes exceeds the {MAX_BODY}-byte limit"
        )));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(malformed)?;
    Ok(Request { method, path, body, keep_alive })
}

fn respond(stream: &TcpStream, state: &ServeState, req: &Request) {
    let (status, reason, payload): (u16, &str, Vec<u8>) = match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/plan") | ("POST", "/plan/artifact") => {
            let text = String::from_utf8_lossy(&req.body);
            let outcome = state.handle_line(&text);
            if req.path == "/plan/artifact" {
                match &outcome.artifact {
                    Some(artifact) => (200, "OK", artifact.as_bytes().to_vec()),
                    None => (400, "Bad Request", outcome.envelope.to_string().into_bytes()),
                }
            } else if outcome.ok {
                (200, "OK", outcome.envelope.to_string().into_bytes())
            } else {
                (400, "Bad Request", outcome.envelope.to_string().into_bytes())
            }
        }
        ("POST", "/advise") => {
            let text = String::from_utf8_lossy(&req.body);
            let outcome = state.handle_advise(&text);
            if outcome.ok {
                (200, "OK", outcome.envelope.to_string().into_bytes())
            } else {
                (400, "Bad Request", outcome.envelope.to_string().into_bytes())
            }
        }
        ("GET", "/health") => (200, "OK", state.health_json().to_string().into_bytes()),
        _ => {
            let envelope = protocol::error_response(
                None,
                "not_found",
                &format!("no route for {} {}", req.method, req.path),
                &[],
            );
            (404, "Not Found", envelope.to_string().into_bytes())
        }
    };
    write_response(stream, status, reason, &payload, req.keep_alive);
}

fn write_response(mut stream: &TcpStream, status: u16, reason: &str, body: &[u8], keep_alive: bool) {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: {connection}\r\n\r\n",
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body))
        .and_then(|()| stream.flush());
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn request_parsing_handles_headers_and_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"POST /plan HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody",
            )
            .unwrap();
            s.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(&stream);
        let req = read_request(&mut reader).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/plan");
        assert_eq!(req.body, b"body");
        // Keep-alive is strictly opt-in: absent header means close.
        assert!(!req.keep_alive);
        client.join().unwrap();
    }

    #[test]
    fn keep_alive_header_is_parsed_and_eof_is_a_clean_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"GET /health HTTP/1.1\r\nConnection: keep-alive\r\n\r\n",
            )
            .unwrap();
            s.flush().unwrap();
            // Close after one request: the server's next read is EOF.
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(&stream);
        let req = read_request(&mut reader).unwrap();
        assert!(req.keep_alive);
        assert_eq!(req.path, "/health");
        client.join().unwrap();
        assert!(matches!(read_request(&mut reader), Err(ReadError::Closed)));
    }

    #[test]
    fn missing_blank_line_is_an_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /health HTTP/1.1\r\nHost: x\r\n").unwrap();
            s.flush().unwrap();
            // Close without ever sending the header-terminating blank line.
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(&stream);
        assert!(matches!(read_request(&mut reader), Err(ReadError::Malformed(_))));
        client.join().unwrap();
    }

    #[test]
    fn json_content_type_header_is_emitted() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            write_response(&stream, 200, "OK", b"{}", false);
        });
        let mut s = TcpStream::connect(addr).unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        server.join().unwrap();
    }
}
