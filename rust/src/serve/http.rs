//! Minimal HTTP/1.1 front end for the serve daemon, hand-rolled over
//! [`std::net::TcpListener`] per the repo's zero-dependency policy. One
//! request per connection (`Connection: close`), `Content-Length` bodies
//! only, no TLS.
//!
//! Routes:
//!
//! * `POST /plan` — body is one serve request object; responds with the
//!   JSON envelope ([`super::protocol`]). `200` on `status:"ok"`, `400`
//!   on `status:"error"`.
//! * `POST /plan/artifact` — same request; responds with the **raw plan
//!   artifact bytes**, byte-identical to `galvatron plan --out` (this is
//!   what `cmp`-based gates should fetch). Errors return the envelope
//!   with `400`.
//! * `GET /health` — liveness plus the daemon's counters.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::time::Duration;

use super::{protocol, ServeState};

/// Largest accepted request body; a plan request is a few hundred bytes,
/// so this is generous headroom, not a real limit.
const MAX_BODY: usize = 8 * 1024 * 1024;
const MAX_HEADER_LINES: usize = 100;

/// Accept loop: serves `listener` until the process exits, dispatching
/// connections to `workers` handler threads. Blocks the calling thread.
pub fn serve_http(
    listener: TcpListener,
    state: Arc<ServeState>,
    workers: usize,
) -> std::io::Result<()> {
    let workers = workers.max(1);
    std::thread::scope(|scope| {
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        for _ in 0..workers {
            let conn_rx = Arc::clone(&conn_rx);
            let state = Arc::clone(&state);
            scope.spawn(move || loop {
                let conn = {
                    let rx = conn_rx.lock().unwrap_or_else(PoisonError::into_inner);
                    rx.recv()
                };
                let Ok(stream) = conn else { break };
                handle_connection(stream, &state);
            });
        }
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    if conn_tx.send(s).is_err() {
                        break;
                    }
                }
                // Transient accept errors (EMFILE, aborted handshakes)
                // must not kill the daemon.
                Err(_) => continue,
            }
        }
        drop(conn_tx);
        Ok(())
    })
}

fn handle_connection(stream: TcpStream, state: &ServeState) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    match read_request(&stream) {
        Ok((method, path, body)) => respond(&stream, state, &method, &path, &body),
        Err(reason) => {
            let envelope = protocol::error_response(
                None,
                "parse",
                &format!("malformed HTTP request: {reason}"),
                &[],
            );
            write_response(&stream, 400, "Bad Request", envelope.to_string().as_bytes());
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Parse request line, headers (only `Content-Length` matters), and body.
fn read_request(stream: &TcpStream) -> Result<(String, String, Vec<u8>), String> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or("empty request line")?.to_string();
    let path = parts.next().ok_or("request line has no path")?.to_string();
    let mut content_length = 0usize;
    let mut saw_blank = false;
    for _ in 0..MAX_HEADER_LINES {
        let mut header = String::new();
        let n = reader.read_line(&mut header).map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("connection closed mid-headers".to_string());
        }
        let header = header.trim();
        if header.is_empty() {
            saw_blank = true;
            break;
        }
        if let Some((key, value)) = header.split_once(':') {
            if key.trim().eq_ignore_ascii_case("content-length") {
                content_length =
                    value.trim().parse().map_err(|_| "unparsable Content-Length")?;
            }
        }
    }
    if !saw_blank {
        return Err(format!("more than {MAX_HEADER_LINES} header lines"));
    }
    if content_length > MAX_BODY {
        return Err(format!("body of {content_length} bytes exceeds the {MAX_BODY}-byte limit"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| e.to_string())?;
    Ok((method, path, body))
}

fn respond(stream: &TcpStream, state: &ServeState, method: &str, path: &str, body: &[u8]) {
    let (status, reason, payload): (u16, &str, Vec<u8>) = match (method, path) {
        ("POST", "/plan") | ("POST", "/plan/artifact") => {
            let text = String::from_utf8_lossy(body);
            let outcome = state.handle_line(&text);
            if path == "/plan/artifact" {
                match &outcome.artifact {
                    Some(artifact) => (200, "OK", artifact.as_bytes().to_vec()),
                    None => (400, "Bad Request", outcome.envelope.to_string().into_bytes()),
                }
            } else if outcome.ok {
                (200, "OK", outcome.envelope.to_string().into_bytes())
            } else {
                (400, "Bad Request", outcome.envelope.to_string().into_bytes())
            }
        }
        ("GET", "/health") => (200, "OK", state.health_json().to_string().into_bytes()),
        _ => {
            let envelope = protocol::error_response(
                None,
                "not_found",
                &format!("no route for {method} {path}"),
                &[],
            );
            (404, "Not Found", envelope.to_string().into_bytes())
        }
    };
    write_response(stream, status, reason, &payload);
}

fn write_response(mut stream: &TcpStream, status: u16, reason: &str, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body))
        .and_then(|()| stream.flush());
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn request_parsing_handles_headers_and_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"POST /plan HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nbody",
            )
            .unwrap();
            s.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let (method, path, body) = read_request(&stream).unwrap();
        assert_eq!(method, "POST");
        assert_eq!(path, "/plan");
        assert_eq!(body, b"body");
        client.join().unwrap();
    }

    #[test]
    fn missing_blank_line_is_an_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /health HTTP/1.1\r\nHost: x\r\n").unwrap();
            s.flush().unwrap();
            // Close without ever sending the header-terminating blank line.
        });
        let (stream, _) = listener.accept().unwrap();
        assert!(read_request(&stream).is_err());
        client.join().unwrap();
    }

    #[test]
    fn json_content_type_header_is_emitted() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            write_response(&stream, 200, "OK", b"{}");
        });
        let mut s = TcpStream::connect(addr).unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        server.join().unwrap();
    }
}
