//! Wire schema of the serve daemon: request JSON → [`PlanRequest`],
//! response envelopes, and the typed error-kind vocabulary shared by the
//! JSONL and HTTP transports.
//!
//! A request is a single JSON object mirroring the `galvatron plan` CLI
//! flags (strict: unknown keys are rejected so typos fail loudly):
//!
//! ```json
//! {"id": 1, "model": "bert-huge-32", "cluster": "titan8",
//!  "memory_gb": 16, "max_batch": 64, "out": "/tmp/plan.json"}
//! ```
//!
//! Responses are one JSON object per request:
//!
//! ```json
//! {"status": "ok", "id": 1, "cache": "miss", "warnings": [], "report": {...}}
//! {"status": "error", "id": 2, "error": {"kind": "infeasible", "message": "..."},
//!  "warnings": []}
//! ```
//!
//! `cache` is `"miss"` (fresh search), `"hit"` (request-level warm hit —
//! persistent store or the daemon's in-memory memo), or `"dedup"` (this
//! request arrived while an identical one was already in flight and was
//! answered from its result).

use std::path::PathBuf;

use crate::api::{parse_schedule, PlanError, PlanRequest};
use crate::util::json::{check_object_keys, Json};

/// Every key a serve request may carry. `id` is echoed back verbatim for
/// matching responses to requests under concurrency; `out` makes the
/// daemon write the raw artifact (byte-identical to `plan --out`) to a
/// path; the rest mirror `galvatron plan` flags.
pub const REQUEST_KEYS: &[&str] = &[
    "id",
    "model",
    "model_file",
    "cluster",
    "memory_gb",
    "method",
    "max_batch",
    "dtype",
    "optimizer",
    "zero",
    "schedule",
    "overlap_slowdown",
    "microbatch_limit",
    "pipeline_degrees",
    "threads",
    "profile_db",
    "out",
];

/// A serve-level failure: protocol errors (bad JSON, bad schema) and
/// planner errors share one envelope shape.
#[derive(Debug, Clone)]
pub struct ServeError {
    pub kind: &'static str,
    pub message: String,
}

impl ServeError {
    pub fn schema(message: impl Into<String>) -> ServeError {
        ServeError { kind: "schema", message: message.into() }
    }
}

/// Stable snake_case kind for a [`PlanError`], so clients can dispatch on
/// errors without parsing prose.
pub fn plan_error_kind(e: &PlanError) -> &'static str {
    match e {
        PlanError::UnknownModel { .. } => "unknown_model",
        PlanError::UnknownCluster { .. } => "unknown_cluster",
        PlanError::UnknownMethod { .. } => "unknown_method",
        PlanError::InvalidRequest { .. } => "invalid_request",
        PlanError::InvalidModel { .. } => "invalid_model",
        PlanError::InvalidCluster { .. } => "invalid_cluster",
        PlanError::InvalidProfileDb { .. } => "invalid_profile_db",
        PlanError::ProfileDbCoverage { .. } => "profile_db_coverage",
        PlanError::Infeasible { .. } => "infeasible",
        PlanError::InvalidFleet { .. } => "invalid_fleet",
        PlanError::Artifact { .. } => "artifact",
        PlanError::InvalidArtifact { .. } => "invalid_artifact",
    }
}

/// A parsed serve request: the planner input plus serve-only directives.
pub struct ParsedRequest {
    pub request: PlanRequest,
    pub out: Option<PathBuf>,
}

fn str_field<'a>(v: &'a Json, key: &str) -> Result<Option<&'a str>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => j
            .as_str()
            .map(Some)
            .ok_or_else(|| ServeError::schema(format!("\"{key}\" must be a string"))),
    }
}

fn f64_field(v: &Json, key: &str) -> Result<Option<f64>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => j
            .as_f64()
            .map(Some)
            .ok_or_else(|| ServeError::schema(format!("\"{key}\" must be a number"))),
    }
}

fn usize_field(v: &Json, key: &str) -> Result<Option<usize>, ServeError> {
    match v.get(key) {
        None => Ok(None),
        Some(j) => match j.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 => Ok(Some(n as usize)),
            _ => Err(ServeError::schema(format!("\"{key}\" must be a non-negative integer"))),
        },
    }
}

/// Parse and validate one request object into a [`PlanRequest`]. Strict:
/// missing required keys, unknown keys, and wrong types all produce a
/// `schema` error naming the offending field.
pub fn parse_request(v: &Json) -> Result<ParsedRequest, ServeError> {
    check_object_keys(v, REQUEST_KEYS, "serve request").map_err(ServeError::schema)?;
    let model = str_field(v, "model")?
        .ok_or_else(|| ServeError::schema("a \"model\" string is required"))?;
    let cluster = str_field(v, "cluster")?
        .ok_or_else(|| ServeError::schema("a \"cluster\" string is required"))?;
    let mut req = PlanRequest::new(model, cluster);
    if let Some(path) = str_field(v, "model_file")? {
        req = req.model_file(path);
    }
    if let Some(gb) = f64_field(v, "memory_gb")? {
        req = req.memory_gb(gb);
    }
    if let Some(name) = str_field(v, "method")? {
        req = req.try_method_name(name).map_err(|e| ServeError {
            kind: plan_error_kind(&e),
            message: e.to_string(),
        })?;
    }
    if let Some(n) = usize_field(v, "max_batch")? {
        req = req.max_batch(n);
    }
    if let Some(name) = str_field(v, "dtype")? {
        let dtype = name
            .parse()
            .map_err(|e| ServeError::schema(format!("\"dtype\": {e}")))?;
        req = req.dtype(dtype);
    }
    if let Some(name) = str_field(v, "optimizer")? {
        let optimizer = name
            .parse()
            .map_err(|e| ServeError::schema(format!("\"optimizer\": {e}")))?;
        req = req.optimizer(optimizer);
    }
    if let Some(j) = v.get("zero") {
        let zero = j
            .as_bool()
            .ok_or_else(|| ServeError::schema("\"zero\" must be a boolean"))?;
        req = req.zero(zero);
    }
    if let Some(name) = str_field(v, "schedule")? {
        let schedule = parse_schedule(name)
            .map_err(|e| ServeError::schema(format!("\"schedule\": {e}")))?;
        req = req.schedule(schedule);
    }
    if let Some(factor) = f64_field(v, "overlap_slowdown")? {
        req = req.overlap_slowdown(factor);
    }
    if let Some(limit) = usize_field(v, "microbatch_limit")? {
        req = req.microbatch_limit(limit);
    }
    if let Some(j) = v.get("pipeline_degrees") {
        let degrees = j.as_usize_vec().ok_or_else(|| {
            ServeError::schema("\"pipeline_degrees\" must be an array of integers")
        })?;
        req = req.pipeline_degrees(&degrees);
    }
    if let Some(n) = usize_field(v, "threads")? {
        req = req.threads(n);
    }
    if let Some(path) = str_field(v, "profile_db")? {
        req = req.profile_db(path);
    }
    let out = str_field(v, "out")?.map(PathBuf::from);
    Ok(ParsedRequest { request: req, out })
}

/// Every key a `POST /advise` request may carry: a capacity-advice sweep
/// mirroring the `galvatron advise` CLI flags.
pub const ADVISE_REQUEST_KEYS: &[&str] =
    &["id", "model", "gpus", "max_islands", "max_batch", "method", "threads", "out"];

/// A parsed advise request: the sweep input plus serve-only directives.
pub struct ParsedAdvise {
    pub request: crate::advise::AdviseRequest,
    pub out: Option<PathBuf>,
}

/// Parse and validate one advise request object. Same strictness as
/// [`parse_request`]: unknown keys and wrong types fail loudly.
pub fn parse_advise_request(v: &Json) -> Result<ParsedAdvise, ServeError> {
    check_object_keys(v, ADVISE_REQUEST_KEYS, "advise request").map_err(ServeError::schema)?;
    let model = str_field(v, "model")?
        .ok_or_else(|| ServeError::schema("a \"model\" string is required"))?;
    let gpus = str_field(v, "gpus")?
        .ok_or_else(|| ServeError::schema("a \"gpus\" fleet spec string is required"))?;
    let max_islands = usize_field(v, "max_islands")?.unwrap_or(3);
    let plan_err = |e: PlanError| ServeError { kind: plan_error_kind(&e), message: e.to_string() };
    let space = crate::advise::parse_fleet_spec(gpus, max_islands).map_err(plan_err)?;
    let mut req = crate::advise::AdviseRequest::new(model, space);
    if let Some(n) = usize_field(v, "max_batch")? {
        req = req.max_batch(n);
    }
    if let Some(name) = str_field(v, "method")? {
        req = req.method(crate::api::MethodSpec::parse(name).map_err(plan_err)?);
    }
    if let Some(n) = usize_field(v, "threads")? {
        req = req.threads(n);
    }
    let out = str_field(v, "out")?.map(PathBuf::from);
    Ok(ParsedAdvise { request: req, out })
}

fn warnings_json(warnings: &[String]) -> Json {
    Json::arr(warnings.iter().map(|w| Json::str(w)))
}

/// Success envelope. `report` is the parsed artifact value; the exact
/// artifact bytes travel via `out` files or the HTTP `/plan/artifact`
/// endpoint (re-serializing the envelope is not guaranteed byte-identical
/// to `PlanReport::to_json_string`).
pub fn ok_response(
    id: Option<&Json>,
    cache: &str,
    out: Option<&str>,
    warnings: &[String],
    report: Json,
) -> Json {
    let mut fields = vec![
        ("status", Json::str("ok")),
        ("cache", Json::str(cache)),
        ("warnings", warnings_json(warnings)),
        ("report", report),
    ];
    if let Some(id) = id {
        fields.push(("id", id.clone()));
    }
    if let Some(out) = out {
        fields.push(("out", Json::str(out)));
    }
    Json::obj(fields)
}

/// Error envelope with a stable `error.kind` for dispatch.
pub fn error_response(id: Option<&Json>, kind: &str, message: &str, warnings: &[String]) -> Json {
    let mut fields = vec![
        ("status", Json::str("error")),
        (
            "error",
            Json::obj(vec![("kind", Json::str(kind)), ("message", Json::str(message))]),
        ),
        ("warnings", warnings_json(warnings)),
    ];
    if let Some(id) = id {
        fields.push(("id", id.clone()));
    }
    Json::obj(fields)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn minimal_request_parses() {
        let v = Json::parse(r#"{"model":"bert-huge-32","cluster":"titan8"}"#).unwrap();
        let parsed = parse_request(&v).unwrap();
        assert!(parsed.out.is_none());
        assert!(matches!(
            &parsed.request.model,
            crate::api::ModelSource::Name(n) if n == "bert-huge-32"
        ));
        assert!(matches!(
            &parsed.request.cluster,
            crate::api::ClusterSource::Name(n) if n == "titan8"
        ));
    }

    #[test]
    fn missing_required_keys_are_schema_errors() {
        let v = Json::parse(r#"{"model":"bert-huge-32"}"#).unwrap();
        let err = parse_request(&v).unwrap_err();
        assert_eq!(err.kind, "schema");
        assert!(err.message.contains("cluster"), "{}", err.message);
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let v =
            Json::parse(r#"{"model":"m","cluster":"c","max_bathc":4}"#).unwrap();
        let err = parse_request(&v).unwrap_err();
        assert_eq!(err.kind, "schema");
        assert!(err.message.contains("max_bathc"), "{}", err.message);
    }

    #[test]
    fn wrong_types_name_the_field() {
        let v = Json::parse(r#"{"model":"m","cluster":"c","max_batch":"lots"}"#).unwrap();
        let err = parse_request(&v).unwrap_err();
        assert!(err.message.contains("max_batch"), "{}", err.message);
        let v = Json::parse(r#"{"model":"m","cluster":"c","zero":1}"#).unwrap();
        let err = parse_request(&v).unwrap_err();
        assert!(err.message.contains("zero"), "{}", err.message);
    }

    #[test]
    fn envelopes_have_stable_shape() {
        let id = Json::num(7.0);
        let ok = ok_response(Some(&id), "hit", Some("/tmp/x.json"), &[], Json::obj(vec![]));
        assert_eq!(ok.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(ok.get("cache").and_then(Json::as_str), Some("hit"));
        assert_eq!(ok.get("id").and_then(Json::as_f64), Some(7.0));
        let err = error_response(None, "parse", "bad json", &["w".to_string()]);
        assert_eq!(err.get("status").and_then(Json::as_str), Some("error"));
        assert_eq!(
            err.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("parse")
        );
        assert_eq!(err.get("warnings").and_then(Json::as_arr).map(<[Json]>::len), Some(1));
    }
}
