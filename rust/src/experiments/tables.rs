//! Table regenerators: Tables II, III, IV, V, VI of the paper.
//!
//! Each function sweeps (model × memory budget × method) on the matching
//! cluster preset and prints the paper's cell format: "throughput (batch)"
//! or OOM. Absolute numbers are calibrated-simulator estimates; the *shape*
//! (who wins, OOM pattern, rough factors) is the reproduction target.

use crate::api::{MethodSpec, PlanRequest};
use crate::search::baselines::method_names;
use crate::search::bmw::partition_str;
use crate::search::SearchOutcome;
use crate::util::table::{tp_cell, Table};

use super::{cluster, model, ExpOptions};

fn cell(out: &Option<SearchOutcome>) -> String {
    tp_cell(out.as_ref().map(|o| (o.throughput(), o.plan.batch)))
}

/// Resolve user/default method names once, up front — a typo panics with
/// the catalog's did-you-mean hint before any search time is spent.
fn resolve_methods(names: &[String]) -> Vec<(String, MethodSpec)> {
    names
        .iter()
        .map(|n| match MethodSpec::parse(n) {
            Ok(spec) => (n.clone(), spec),
            Err(e) => panic!("{e}"),
        })
        .collect()
}

/// Shared engine for Tables II/III/IV/VI: methods × models at budgets.
fn throughput_table(
    title: &str,
    cluster_name: &str,
    budgets: &[f64],
    models: &[String],
    methods: &[String],
    max_batch: usize,
) -> Vec<Table> {
    let specs = resolve_methods(methods);
    let mut tables = Vec::new();
    for &budget in budgets {
        println!("\n=== {title} | cluster={cluster_name} | memory={budget}G ===");
        let mut header = vec!["Strategy".to_string()];
        header.extend(models.iter().cloned());
        let mut t = Table::new(header);
        for (mname, spec) in &specs {
            let mut row = vec![mname.clone()];
            for m in models {
                let mp = model(m);
                let cl = cluster(cluster_name, budget);
                let out = spec.run(&mp, &cl, max_batch);
                row.push(cell(&out));
            }
            t.row(row);
        }
        t.print();
        tables.push(t);
    }
    tables
}

/// Table II: 8 GPUs (titan8), budgets 8/12/16/20 G, 8 models, 11 methods.
pub fn table2(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&[
        "bert-huge-32",
        "bert-huge-48",
        "vit-huge-32",
        "vit-huge-48",
        "t5-large-32",
        "t5-large-48",
        "swin-huge-32",
        "swin-huge-48",
    ]);
    let budgets = opts.budgets_or(&[8.0, 12.0, 16.0, 20.0]);
    let methods = opts.methods_or(&method_names());
    throughput_table("Table II", "titan8", &budgets, &models, &methods, opts.max_batch)
}

/// Table III: 16 GPUs, low-perf (titan16) and high-perf (a100x16), 8/16 G.
pub fn table3(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&[
        "bert-huge-32",
        "bert-huge-48",
        "vit-huge-32",
        "vit-huge-48",
        "t5-512/4-32",
        "t5-512/4-48",
    ]);
    let budgets = opts.budgets_or(&[8.0, 16.0]);
    let methods = opts.methods_or(&method_names());
    let mut out = Vec::new();
    for cl in ["titan16", "a100x16"] {
        out.extend(throughput_table(
            &format!("Table III ({cl})"),
            cl,
            &budgets,
            &models,
            &methods,
            opts.max_batch,
        ));
    }
    out
}

/// Table IV: 64 GPUs (a100x64), 16/32 G, 10B-parameter models.
pub fn table4(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&["bert-xhuge", "vit-xhuge"]);
    let budgets = opts.budgets_or(&[16.0, 32.0]);
    let methods = opts.methods_or(&method_names());
    throughput_table("Table IV", "a100x64", &budgets, &models, &methods, opts.max_batch)
}

/// Table V: bi-objective ablation on a100x16 — memory-balanced vs
/// time-balanced vs bi-objective partitions, with partitions shown.
pub fn table5(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&["bert-huge-32", "bert-huge-48", "t5-512/4-32", "t5-512/4-48"]);
    let budgets = opts.budgets_or(&[8.0, 16.0]);
    let mut tables = Vec::new();
    for &budget in &budgets {
        println!("\n=== Table V | a100x16 | memory={budget}G ===");
        let mut header = vec!["Strategy".to_string()];
        header.extend(models.iter().cloned());
        let mut t = Table::new(header);
        let rows = [
            MethodSpec::Partition(crate::api::PartitionPolicy::Memory),
            MethodSpec::Partition(crate::api::PartitionPolicy::Time),
            MethodSpec::Bmw { ckpt: false },
        ];
        for spec in rows {
            let mut row = vec![spec.canonical_name().to_string()];
            for m in &models {
                let out = spec.run(&model(m), &cluster("a100x16", budget), opts.max_batch);
                row.push(match &out {
                    Some(o) => format!("{} {}", tp_cell(Some((o.throughput(), o.plan.batch))), partition_str(&o.plan.partition)),
                    None => "OOM".to_string(),
                });
            }
            t.row(row);
        }
        t.print();
        tables.push(t);
    }
    tables
}

/// Table VI: GPT-3 15B/39B/65B on 32x A100-80G, including the Alpa-like
/// baseline.
pub fn table6(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&["gpt3-15b", "gpt3-39b", "gpt3-65b"]);
    let budgets = opts.budgets_or(&[80.0]);
    let mut methods = opts.methods_or(&method_names());
    if opts.methods.is_empty() {
        methods.insert(methods.len() - 1, "Alpa".to_string());
    }
    throughput_table("Table VI", "a100-80g-x32", &budgets, &models, &methods, opts.max_batch)
}

/// Heterogeneous-cluster sweep (the mixed-fleet scenario family): zoo
/// models planned with Galvatron-BMW on a homogeneous baseline and the
/// mixed-island presets, reporting throughput, the pipeline partition and
/// the stage→island placement the planner chose (slot order; `hetero*`
/// presets list their small-memory island first, so non-identity slots
/// mean the placement pass moved memory-heavy stages onto big islands).
pub fn table_hetero(opts: &ExpOptions) -> Vec<Table> {
    let models = opts.models_or(&["bert-huge-32", "vit-huge-32", "t5-512/4-32"]);
    let clusters = ["titan8", "hetero4", "hetero16"];
    println!("\n=== Heterogeneous clusters | Galvatron-BMW | physical memory ===");
    let mut header = vec!["Model".to_string()];
    header.extend(clusters.iter().map(|s| s.to_string()));
    let mut t = Table::new(header);
    for m in &models {
        let mut row = vec![m.clone()];
        for cname in clusters {
            let cell = match PlanRequest::new(m, cname).max_batch(opts.max_batch).plan() {
                Ok(r) => {
                    let slots = r
                        .plan
                        .stage_slots
                        .as_ref()
                        .map(|v| {
                            format!(
                                " slots[{}]",
                                v.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(",")
                            )
                        })
                        .unwrap_or_default();
                    format!(
                        "{} {}{}",
                        tp_cell(Some((r.throughput, r.plan.batch))),
                        partition_str(&r.plan.partition),
                        slots
                    )
                }
                Err(_) => "OOM".to_string(),
            };
            row.push(cell);
        }
        t.row(row);
    }
    t.print();
    vec![t]
}

/// §VII-B headline speedups derived from a finished Table-II-style grid:
/// max speedup of Galvatron-BMW over (a) pure, (b) hybrid baselines.
pub fn speedup_summary(
    results: &[(String, String, Option<f64>)], // (method, model, throughput)
) -> (f64, f64) {
    let pure = [
        "PyTorch DDP (DP)",
        "Megatron (TP)",
        "PyTorch GPipe (PP)",
        "FSDP/ZeRO-3 (SDP)",
    ];
    let bmw: std::collections::BTreeMap<&str, f64> = results
        .iter()
        .filter(|(m, _, _)| m == "Galvatron-BMW")
        .filter_map(|(_, model, t)| (*t).map(|tp| (model.as_str(), tp)))
        .collect();
    let mut best_vs_pure: f64 = 0.0;
    let mut best_vs_hybrid: f64 = 0.0;
    for (method, model, tp) in results {
        let Some(tp) = tp else { continue };
        let Some(&bmw_tp) = bmw.get(model.as_str()) else { continue };
        if *tp <= 0.0 || method == "Galvatron-BMW" {
            continue;
        }
        let speedup = bmw_tp / tp;
        if pure.contains(&method.as_str()) {
            best_vs_pure = best_vs_pure.max(speedup);
        } else {
            best_vs_hybrid = best_vs_hybrid.max(speedup);
        }
    }
    (best_vs_pure, best_vs_hybrid)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn speedup_summary_math() {
        let rows = vec![
            ("PyTorch DDP (DP)".to_string(), "m".to_string(), Some(10.0)),
            ("DeepSpeed 3D".to_string(), "m".to_string(), Some(20.0)),
            ("Galvatron-BMW".to_string(), "m".to_string(), Some(40.0)),
        ];
        let (p, h) = speedup_summary(&rows);
        assert_eq!(p, 4.0);
        assert_eq!(h, 2.0);
    }
}
