//! Figure regenerators: Figures 4, 5, 6 and 7 of the paper.

use std::time::Instant;

use crate::cost::pipeline::{plan_cost, Schedule};
use crate::model::{LayerProfile, ModelProfile};
use crate::parallel::{Dim, ParallelPlan};
use crate::search::base::{evaluate_partition, SearchConfig};
use crate::search::bmw::{memory_balanced_partition, optimize_bmw, partition_str};
use crate::search::decision_tree::{total_candidates, SpaceOptions};
use crate::search::partition::balanced_partition;
use crate::search::optimize;
use crate::sim::simulate;
use crate::util::table::Table;
use crate::util::GIB;

use super::{cluster, model, ExpOptions};

/// Group a plan's per-layer strategies into "(strategy) ×N" runs — the
/// Fig. 6 visualization (shim over [`ParallelPlan::summary`]).
pub fn plan_summary(plan: &ParallelPlan) -> String {
    plan.summary()
}

/// Fig. 4: 4-way 1F1B pipelines under memory-/time-balanced/bi-objective
/// partitions — per-stage memory & time bars, balance degrees, throughput.
pub fn fig4(opts: &ExpOptions) -> Vec<Table> {
    let cases = [("bert-huge-48", 32usize), ("t5-512/4-48", 64usize)];
    let budget = opts.budgets_or(&[16.0])[0];
    let m = 8usize;
    let pp = 4usize;
    let mut tables = Vec::new();
    for (mname, batch) in cases {
        let mp = model(mname);
        let cl = cluster("a100x16", budget);
        println!("\n=== Fig 4 | {mname} | B={batch} m={m} P={pp} | {budget}G ===");
        let mut t = Table::new([
            "partition".to_string(),
            "p".to_string(),
            "stage mem (GiB)".to_string(),
            "stage time (norm)".to_string(),
            "alpha_t".to_string(),
            "alpha_m".to_string(),
            "throughput".to_string(),
        ]);
        let cfg = SearchConfig {
            space: SpaceOptions::default().no_ckpt(),
            pp_degrees: Some(vec![pp]),
            max_batch: batch,
            ..Default::default()
        };
        let group = cl.n_devices() / pp;
        let b_m = batch as f64 / m as f64;
        let act_w: Vec<f64> = mp.layers.iter().map(|l| l.act_bytes * b_m / group as f64).collect();
        let ms_w: Vec<f64> = (0..mp.n_layers())
            .map(|i| (mp.layers[i].params + mp.extra_params(i)) * 16.0 / group as f64)
            .collect();
        let flops_w: Vec<f64> = mp.layers.iter().map(|l| l.flops_fwd).collect();

        let partitions: Vec<(&str, Vec<usize>)> = vec![
            ("memory-balanced", memory_balanced_partition(&act_w, &ms_w, pp, m, Schedule::OneFOneB)),
            ("time-balanced", balanced_partition(&flops_w, pp)),
            (
                "bi-objective",
                optimize_bmw(&mp, &cl, &cfg).map(|o| o.plan.partition).unwrap_or_else(|| vec![mp.n_layers() / pp; pp]),
            ),
        ];
        for (label, part) in partitions {
            match evaluate_partition(&mp, &cl, &cfg, batch, pp, m, &part) {
                Some((out, _)) => {
                    let sim = simulate(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3);
                    let max_t = sim.stage_mb_time.iter().cloned().fold(0.0, f64::max);
                    let mems = sim
                        .stage_peak_mem
                        .iter()
                        .map(|x| format!("{:.1}", x / GIB))
                        .collect::<Vec<_>>()
                        .join("/");
                    let times = sim
                        .stage_mb_time
                        .iter()
                        .map(|x| format!("{:.2}", x / max_t))
                        .collect::<Vec<_>>()
                        .join("/");
                    t.row([
                        label.to_string(),
                        partition_str(&part),
                        mems,
                        times,
                        format!("{:.3}", sim.alpha_t()),
                        format!("{:.3}", sim.alpha_m()),
                        format!("{:.2}", sim.throughput),
                    ]);
                }
                None => t.row([
                    label.to_string(),
                    partition_str(&part),
                    "OOM".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        t.print();
        tables.push(t);
    }
    tables
}

/// Synthetic homogeneous model for scaling studies.
fn synth_model(layers: usize) -> ModelProfile {
    ModelProfile {
        name: format!("synth-{layers}"),
        layers: (0..layers)
            .map(|i| LayerProfile::encoder(&format!("l{i}"), 1280, 512, 20))
            .collect(),
        pre_params: 39e6,
        post_params: 1.7e6,
    }
}

/// Fig. 5a: search time vs #layers (linear in L and E — paper claim).
pub fn fig5a(opts: &ExpOptions) -> Table {
    println!("\n=== Fig 5(a): search time vs model size ===");
    let mut t = Table::new(["layers", "memory (G)", "search time (s)"]);
    for &layers in &[8usize, 16, 24, 32, 48, 64] {
        for budget in opts.budgets_or(&[8.0, 16.0, 24.0]) {
            let mp = synth_model(layers);
            let cl = cluster("titan8", budget);
            let cfg = SearchConfig { max_batch: opts.max_batch.min(64), ..Default::default() };
            let t0 = Instant::now();
            let _ = optimize(&mp, &cl, &cfg);
            t.row([
                layers.to_string(),
                format!("{budget}"),
                format!("{:.3}", t0.elapsed().as_secs_f64()),
            ]);
        }
    }
    t.print();
    t
}

/// Fig. 5b: search time vs strategy-space size (DP+TP / DP+PP / Galvatron
/// / Galvatron-BMW candidate sets).
pub fn fig5b(opts: &ExpOptions) -> Table {
    println!("\n=== Fig 5(b): search time vs #strategies (8 GPUs) ===");
    let mut t = Table::new(["space", "#candidates", "search time (s)"]);
    let spaces: Vec<(&str, SearchConfig)> = vec![
        (
            "DP+TP",
            SearchConfig {
                space: SpaceOptions::default().with_dims(&[Dim::Dp, Dim::Tp]).no_ckpt(),
                pp_degrees: Some(vec![1]),
                ..Default::default()
            },
        ),
        (
            "DP+PP",
            SearchConfig {
                space: SpaceOptions::default().with_dims(&[Dim::Dp]).no_ckpt(),
                ..Default::default()
            },
        ),
        (
            "Galvatron",
            SearchConfig { space: SpaceOptions::default().no_ckpt(), ..Default::default() },
        ),
        ("Galvatron-BMW", SearchConfig::default()),
    ];
    let mp = synth_model(24);
    let cl = cluster("titan8", 16.0);
    for (name, mut cfg) in spaces {
        cfg.max_batch = opts.max_batch.min(64);
        let count = total_candidates(8, &cfg.space);
        let t0 = Instant::now();
        let _ = optimize(&mp, &cl, &cfg);
        t.row([
            name.to_string(),
            count.to_string(),
            format!("{:.3}", t0.elapsed().as_secs_f64()),
        ]);
    }
    t.print();
    t
}

/// Fig. 6: optimal parallelism plan visualizations (cases A/B/C).
pub fn fig6(opts: &ExpOptions) -> Vec<String> {
    let cases: Vec<(&str, &str, f64)> = vec![
        ("bert-huge-32", "titan8", 8.0),  // case A
        ("swin-huge-32", "titan8", 8.0),  // case B
        ("t5-512/4-32", "titan16", 8.0),  // case C (low-perf)
        ("t5-512/4-32", "a100x16", 8.0),  // case C (high-perf)
    ];
    let mut outputs = Vec::new();
    for (mname, cname, budget) in cases {
        let mp = model(mname);
        let cl = cluster(cname, budget);
        let cfg = SearchConfig { max_batch: opts.max_batch, ..Default::default() };
        println!("\n=== Fig 6 | {mname} on {cname} @ {budget}G ===");
        match optimize_bmw(&mp, &cl, &cfg) {
            Some(out) => {
                let s = plan_summary(&out.plan);
                println!("{s}  est. throughput {:.2} samples/s", out.throughput());
                outputs.push(s);
            }
            None => {
                println!("OOM");
                outputs.push("OOM".to_string());
            }
        }
    }
    outputs
}

/// Fig. 7: cost-estimation error with and without the overlap slowdown,
/// against the DES ground truth.
pub fn fig7(opts: &ExpOptions) -> Table {
    println!("\n=== Fig 7: estimation error vs simulator ===");
    let models = opts.models_or(&[
        "bert-huge-32",
        "vit-huge-32",
        "t5-large-32",
        "swin-huge-32",
    ]);
    let mut t = Table::new(["model", "err w/ slowdown (%)", "err w/o slowdown (%)"]);
    for mname in &models {
        let mp = model(mname);
        let cl = cluster("titan8", 16.0);
        // Use an overlap-heavy plan (DP/SDP gradient comm overlapping the
        // backward) — the regime the paper's Fig. 7 profiles.
        let Some(out) = crate::api::MethodSpec::Pure(Dim::Sdp).run(&mp, &cl, opts.max_batch.min(128))
            .or_else(|| optimize(&mp, &cl, &SearchConfig { max_batch: opts.max_batch.min(128), ..Default::default() }))
        else {
            t.row([mname.clone(), "OOM".into(), "OOM".into()]);
            continue;
        };
        let sim = simulate(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3);
        let est_with = plan_cost(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3);
        let est_without = plan_cost(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.0);
        let err = |e: f64| (e - sim.iter_time) / sim.iter_time * 100.0;
        t.row([
            mname.clone(),
            format!("{:+.1}", err(est_with.iter_time)),
            format!("{:+.1}", err(est_without.iter_time)),
        ]);
    }
    t.print();
    t
}

/// Convenience wrapper returning the Fig. 7 numbers for tests.
pub fn estimation_errors(mname: &str) -> Option<(f64, f64)> {
    let mp = model(mname);
    let cl = cluster("titan8", 16.0);
    let out = crate::api::MethodSpec::Pure(Dim::Sdp).run(&mp, &cl, 64)?;
    let sim = simulate(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3);
    let with = plan_cost(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3).iter_time;
    let without = plan_cost(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.0).iter_time;
    Some((
        (with - sim.iter_time) / sim.iter_time,
        (without - sim.iter_time) / sim.iter_time,
    ))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::parallel::Strategy;

    #[test]
    fn plan_summary_groups_runs() {
        let plan = ParallelPlan {
            pp: 2,
            partition: vec![2, 2],
            strategies: vec![
                Strategy::single(Dim::Dp, 4, false),
                Strategy::single(Dim::Dp, 4, false),
                Strategy::single(Dim::Tp, 4, true),
                Strategy::single(Dim::Sdp, 4, false),
            ],
            batch: 16,
            microbatches: 4,
            stage_slots: None,
        };
        let s = plan_summary(&plan);
        assert!(s.contains("[DP4 ×2]"), "{s}");
        assert!(s.contains("[TP4+CKPT ×1]"), "{s}");
        assert!(s.contains("[SDP4 ×1]"), "{s}");
    }

    #[test]
    fn estimation_error_sign() {
        // Fig. 7's core claim: ignoring the slowdown underestimates; with
        // it the estimator is close to ground truth.
        let (with, without) = estimation_errors("bert-huge-32").expect("feasible");
        assert!(without < with, "without-slowdown must sit below");
        assert!(with.abs() < 0.15, "with-slowdown error too large: {with}");
    }
}
