//! Experiment regenerators: one function per paper table/figure
//! (DESIGN.md §5 experiment index). The CLI (`main.rs`), the examples, and
//! the benches all call into here.

pub mod figures;
pub mod tables;

use crate::api::{resolve_cluster_name, resolve_model_name};
use crate::cluster::ClusterSpec;
use crate::model::ModelProfile;
use crate::util::GIB;

/// Common knobs for experiment runs (runtime scales with `max_batch`).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Largest global batch the sweeps explore.
    pub max_batch: usize,
    /// Restrict to these models (names); empty = experiment defaults.
    pub models: Vec<String>,
    /// Restrict to these memory budgets in GB; empty = experiment defaults.
    pub budgets: Vec<f64>,
    /// Restrict to these method names; empty = all.
    pub methods: Vec<String>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions { max_batch: 512, models: vec![], budgets: vec![], methods: vec![] }
    }
}

impl ExpOptions {
    pub fn models_or<'a>(&'a self, default: &[&'a str]) -> Vec<String> {
        if self.models.is_empty() {
            default.iter().map(|s| s.to_string()).collect()
        } else {
            self.models.clone()
        }
    }

    pub fn budgets_or(&self, default: &[f64]) -> Vec<f64> {
        if self.budgets.is_empty() {
            default.to_vec()
        } else {
            self.budgets.clone()
        }
    }

    pub fn methods_or(&self, default: &[&str]) -> Vec<String> {
        if self.methods.is_empty() {
            default.iter().map(|s| s.to_string()).collect()
        } else {
            self.methods.clone()
        }
    }
}

/// Resolve a model or panic with a did-you-mean hint (the regenerators
/// are batch jobs; library users should prefer `api::resolve_model_name`).
/// Accepts zoo names and, like the CLI, `.json` ModelSpec file paths — so
/// `table2 --models my-model.json` sweeps a custom model.
pub fn model(name: &str) -> ModelProfile {
    resolve_model_name(name).unwrap_or_else(|e| panic!("{e}"))
}

/// Resolve a cluster with a memory budget in GB.
pub fn cluster(name: &str, budget_gb: f64) -> ClusterSpec {
    resolve_cluster_name(name)
        .unwrap_or_else(|e| panic!("{e}"))
        .with_memory_budget(budget_gb * GIB)
}
