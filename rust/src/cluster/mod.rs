//! Cluster topology model: devices, typed islands, and hierarchical
//! bandwidth.
//!
//! Paper Takeaway #1: PP prefers to be applied across device "islands"
//! (sets of devices with high-bandwidth interconnect); slower inter-island
//! links carry only pipeline boundary activations. The planner needs, for a
//! communication group of a given size at a given decision-tree level, the
//! effective bandwidth of the slowest link that group spans — this module
//! provides that.
//!
//! Since the heterogeneous-cluster generalization, a [`ClusterSpec`] is a
//! *list of typed islands* ([`IslandSpec`]): each island carries its own
//! GPU class (memory capacity + FLOP rate) and intra-island bus. A
//! homogeneous cluster is the degenerate single-class case and reproduces
//! the original model bit-for-bit. For a given pipeline degree the cluster
//! exposes per-stage [`StageSite`]s — the device class, bus bandwidth and
//! memory budget a pipeline stage sees on its slot — which the cost
//! estimator, the stage-level DP budget and the search engine's memoization
//! keys all consume.

use crate::util::{is_pow2, GIB};

/// GPU device class.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Device memory in bytes.
    pub mem_bytes: f64,
    /// Effective training-matmul throughput in FLOP/s (calibration constant
    /// that sets the absolute throughput scale; see DESIGN.md §2).
    pub flops: f64,
}

impl GpuSpec {
    pub fn titan_rtx() -> Self {
        GpuSpec { name: "RTX-TITAN-24G".into(), mem_bytes: 24.0 * GIB, flops: 10e12 }
    }

    pub fn a100_40g() -> Self {
        GpuSpec { name: "A100-40G".into(), mem_bytes: 40.0 * GIB, flops: 40e12 }
    }

    pub fn a100_80g() -> Self {
        GpuSpec { name: "A100-80G".into(), mem_bytes: 80.0 * GIB, flops: 40e12 }
    }
}

/// One island: `count` GPUs of one class behind a shared fast bus.
#[derive(Debug, Clone, PartialEq)]
pub struct IslandSpec {
    pub gpu: GpuSpec,
    /// Devices in this island (a power of two).
    pub count: usize,
    /// Intra-island effective bus bandwidth, bytes/s (NVLink or PCIe).
    pub intra_bw: f64,
}

/// Why a cluster description could not be constructed or parsed. Surfaces
/// through [`crate::api::PlanError`] as a CLI diagnostic instead of the
/// panics the original `ClusterSpec::new` asserts produced.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// The island list is empty (or an island has zero devices).
    Empty,
    /// The total device count must be a power of two.
    NonPow2Devices { n: usize },
    /// Every island's device count must be a power of two.
    NonPow2Island { count: usize },
    /// Homogeneous constructor: the island size must divide the device
    /// count (and not exceed it).
    BadIslandSize { island: usize, n: usize },
    /// An island-syntax GPU class name is not in the catalog.
    UnknownGpu { name: String },
    /// An island-syntax segment is malformed.
    Parse { segment: String, reason: String },
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Empty => write!(f, "cluster has no devices"),
            ClusterError::NonPow2Devices { n } => {
                write!(f, "total device count must be a power of two, got {n}")
            }
            ClusterError::NonPow2Island { count } => {
                write!(f, "island device count must be a power of two, got {count}")
            }
            ClusterError::BadIslandSize { island, n } => write!(
                f,
                "island size {island} must be a power of two dividing the {n} devices"
            ),
            ClusterError::UnknownGpu { name } => write!(
                f,
                "unknown GPU class {name:?} (known: {})",
                gpu_class_names().join(", ")
            ),
            ClusterError::Parse { segment, reason } => write!(
                f,
                "bad island segment {segment:?}: {reason} (expected e.g. \"2xA100-80G,2xRTX-TITAN-24G\")"
            ),
        }
    }
}

impl std::error::Error for ClusterError {}

/// The execution context a pipeline stage sees on its slot of the cluster:
/// the (floor) device class of the devices it occupies, the bus bandwidth
/// of intra-stage collectives, and how wide a group can grow before it
/// spills onto the inter-island link.
#[derive(Debug, Clone, PartialEq)]
pub struct StageSite {
    /// Distinct site-class id within one (cluster, pp) context. Two slots
    /// share a class iff their gpu/bandwidth/limit are identical — the
    /// search engine keys its memoized cost tables on this.
    pub class: u32,
    /// Effective device class. For a slot spanning several islands this is
    /// the floor: min memory AND min FLOP rate over the spanned islands.
    pub gpu: GpuSpec,
    /// Bus bandwidth for groups that fit inside one island of this slot.
    pub intra_bw: f64,
    /// Largest communication group that still rides intra-island links.
    pub intra_limit: usize,
}

/// Alpha-beta link time model: the calibrated generalization of the pure
/// `bytes / bw` division the analytic cost model uses everywhere. A
/// transfer of `b` bytes over a link of nominal bandwidth `bw` costs
///
/// ```text
///   alpha + b / (bw * efficiency)
/// ```
///
/// where `alpha` is the fixed per-collective launch latency and
/// `efficiency` is the achieved fraction of the nominal bandwidth
/// (`beta / ref_bw` of a fitted [`crate::cost::ProfileDb`]). Keeping the
/// calibration *relative* to the nominal bandwidth preserves the topology
/// model: faster links stay faster, and the [`LinkModel::ideal`] model
/// (`alpha = 0`, `efficiency = 1`) reproduces `bytes / bw` bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Fixed per-collective latency, seconds.
    pub alpha: f64,
    /// Achieved fraction of the nominal link bandwidth.
    pub efficiency: f64,
}

impl LinkModel {
    /// The analytic model: no latency, full nominal bandwidth.
    pub fn ideal() -> LinkModel {
        LinkModel { alpha: 0.0, efficiency: 1.0 }
    }

    /// Time to move `bytes` over a link of nominal bandwidth `bw`. Zero
    /// bytes cost zero (no collective is launched), so alpha is never
    /// charged for communication a strategy does not perform.
    pub fn time(&self, bytes: f64, bw: f64) -> f64 {
        if bytes <= 0.0 {
            0.0
        } else {
            self.alpha + bytes / (bw * self.efficiency)
        }
    }
}

fn floor_gpu(a: &GpuSpec, b: &GpuSpec) -> GpuSpec {
    GpuSpec {
        name: if b.mem_bytes < a.mem_bytes { b.name.clone() } else { a.name.clone() },
        mem_bytes: a.mem_bytes.min(b.mem_bytes),
        flops: a.flops.min(b.flops),
    }
}

fn site_shape_eq(a: &StageSite, b: &StageSite) -> bool {
    a.gpu == b.gpu && a.intra_bw == b.intra_bw && a.intra_limit == b.intra_limit
}

/// A training cluster: an ordered list of typed islands. Full bandwidth
/// inside an island, `inter_bw` across islands.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub name: String,
    pub islands: Vec<IslandSpec>,
    /// Inter-island effective bandwidth, bytes/s (IB / Ethernet).
    pub inter_bw: f64,
}

impl ClusterSpec {
    /// Homogeneous constructor (the original model): `n_devices` GPUs of
    /// one class grouped into equal islands of `island_size`. Returns a
    /// typed [`ClusterError`] instead of panicking on bad shapes.
    pub fn new(
        name: &str,
        gpu: GpuSpec,
        n_devices: usize,
        island_size: usize,
        intra_bw: f64,
        inter_bw: f64,
    ) -> Result<Self, ClusterError> {
        if !is_pow2(n_devices) {
            return Err(ClusterError::NonPow2Devices { n: n_devices });
        }
        if !is_pow2(island_size) || island_size > n_devices || n_devices % island_size != 0 {
            return Err(ClusterError::BadIslandSize { island: island_size, n: n_devices });
        }
        let islands = (0..n_devices / island_size)
            .map(|_| IslandSpec { gpu: gpu.clone(), count: island_size, intra_bw })
            .collect();
        Self::from_islands(name, islands, inter_bw)
    }

    /// General constructor from an explicit island list.
    pub fn from_islands(
        name: &str,
        islands: Vec<IslandSpec>,
        inter_bw: f64,
    ) -> Result<Self, ClusterError> {
        if islands.is_empty() || islands.iter().any(|i| i.count == 0) {
            return Err(ClusterError::Empty);
        }
        for isl in &islands {
            if !is_pow2(isl.count) {
                return Err(ClusterError::NonPow2Island { count: isl.count });
            }
        }
        let n: usize = islands.iter().map(|i| i.count).sum();
        if !is_pow2(n) {
            return Err(ClusterError::NonPow2Devices { n });
        }
        Ok(ClusterSpec { name: name.into(), islands, inter_bw })
    }

    pub fn n_devices(&self) -> usize {
        self.islands.iter().map(|i| i.count).sum()
    }

    pub fn n_islands(&self) -> usize {
        self.islands.len()
    }

    /// Smallest island size (the homogeneous `island_size` when uniform).
    pub fn island_size(&self) -> usize {
        self.islands.iter().map(|i| i.count).min().unwrap_or(0)
    }

    /// Slowest intra-island bus in the cluster (== every island's bus for
    /// homogeneous clusters).
    pub fn intra_bw(&self) -> f64 {
        self.islands.iter().map(|i| i.intra_bw).fold(f64::INFINITY, f64::min)
    }

    /// The floor device class: min memory AND min FLOP rate over all
    /// islands (== the single class for homogeneous clusters).
    pub fn gpu(&self) -> GpuSpec {
        let mut g = self.islands[0].gpu.clone();
        for isl in &self.islands[1..] {
            g = floor_gpu(&g, &isl.gpu);
        }
        g
    }

    /// True iff every island has the same GPU class and bus — the
    /// degenerate case that must reproduce the original homogeneous
    /// planner byte-for-byte.
    pub fn is_homogeneous(&self) -> bool {
        let first = &self.islands[0];
        self.islands
            .iter()
            .all(|i| i.gpu == first.gpu && i.intra_bw == first.intra_bw)
    }

    /// Canonical island-syntax label, e.g. `"2xA100-80G,2xRTX-TITAN-24G"`.
    pub fn islands_label(&self) -> String {
        self.islands
            .iter()
            .map(|i| format!("{}x{}", i.count, i.gpu.name))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Human budget summary: "16 GB budget" for homogeneous clusters, the
    /// island label for mixed fleets.
    pub fn budget_label(&self) -> String {
        if self.is_homogeneous() {
            format!("{:.0} GB budget", self.islands[0].gpu.mem_bytes / GIB)
        } else {
            self.islands_label()
        }
    }

    /// The per-slot [`StageSite`]s for a pipeline of `pp_degree` stages:
    /// slot `s` covers devices `[s·g, (s+1)·g)` in island order
    /// (`g = n/pp`). A slot spanning several islands gets the floor device
    /// class, the slowest spanned bus, and the smallest spanned island as
    /// its intra limit.
    pub fn stage_sites(&self, pp_degree: usize) -> Vec<StageSite> {
        let n = self.n_devices();
        let pp = pp_degree.clamp(1, n);
        let g = n / pp;
        let mut sites: Vec<StageSite> = Vec::with_capacity(pp);
        for s in 0..pp {
            let (lo, hi) = (s * g, (s + 1) * g);
            let mut gpu: Option<GpuSpec> = None;
            let mut intra = f64::INFINITY;
            let mut min_count = usize::MAX;
            let mut start = 0usize;
            for isl in &self.islands {
                let end = start + isl.count;
                if start < hi && end > lo {
                    gpu = Some(match &gpu {
                        None => isl.gpu.clone(),
                        Some(g0) => floor_gpu(g0, &isl.gpu),
                    });
                    intra = intra.min(isl.intra_bw);
                    min_count = min_count.min(isl.count);
                }
                start = end;
            }
            let gpu = gpu.unwrap_or_else(|| unreachable!("cluster has devices"));
            sites.push(StageSite { class: 0, gpu, intra_bw: intra, intra_limit: min_count.min(g) });
        }
        // Assign class ids by first occurrence of each distinct site shape.
        let mut reps: Vec<StageSite> = Vec::new();
        for site in &mut sites {
            match reps.iter().position(|r| site_shape_eq(r, site)) {
                Some(c) => site.class = c as u32,
                None => {
                    site.class = reps.len() as u32;
                    reps.push(site.clone());
                }
            }
        }
        sites
    }

    /// The conservative whole-cluster site for `pp_degree`: floor device
    /// class, slowest bus, smallest island. Identical to every slot site on
    /// a homogeneous cluster — [`crate::cost::CostEstimator::new`] binds to
    /// this when no specific slot is requested.
    pub fn floor_site(&self, pp_degree: usize) -> StageSite {
        let n = self.n_devices();
        let g = n / pp_degree.clamp(1, n);
        StageSite {
            class: 0,
            gpu: self.gpu(),
            intra_bw: self.intra_bw(),
            intra_limit: self.island_size().min(g),
        }
    }

    /// Effective bandwidth for a communication group of `group` devices,
    /// when the total devices are already partitioned into `pp` pipeline
    /// groups of `n_devices/pp` (Takeaway #1 placement: PP cuts across the
    /// slowest links first, so a group of size g inside one pipeline stage
    /// spans islands only if g exceeds what is left of an island inside the
    /// stage group). Floor-site view; slot-accurate pricing lives in
    /// [`crate::cost::CostEstimator`] via [`StageSite`].
    pub fn group_bandwidth(&self, pp_degree: usize, group: usize) -> f64 {
        let site = self.floor_site(pp_degree);
        if group <= site.intra_limit {
            site.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// Bandwidth of the link crossed by pipeline p2p at stage boundaries.
    pub fn pipeline_link_bw(&self, pp_degree: usize) -> f64 {
        if pp_degree <= self.n_islands() {
            // Stage boundaries align with island boundaries.
            self.inter_bw
        } else {
            // Some stage boundaries fall inside an island; conservatively
            // the bottleneck for cost purposes is the slower inter link if
            // any boundary crosses islands, otherwise intra.
            if self.n_islands() > 1 {
                self.inter_bw
            } else {
                self.intra_bw()
            }
        }
    }

    /// Memory budget per device possibly restricted below physical memory
    /// (the paper evaluates 8/12/16/20 GB budgets on 24 GB cards). Applies
    /// one uniform budget to every island — the public API only offers it
    /// for homogeneous clusters, where it preserves the original semantics.
    pub fn with_memory_budget(mut self, budget_bytes: f64) -> Self {
        for isl in &mut self.islands {
            isl.gpu.mem_bytes = budget_bytes;
        }
        self
    }
}

/// GPU class catalog for the island syntax (case-insensitive lookup).
/// Returns the spec plus the class's default intra-island bus bandwidth.
pub fn gpu_by_name(name: &str) -> Option<(GpuSpec, f64)> {
    Some(match name.trim().to_ascii_lowercase().as_str() {
        "rtx-titan-24g" | "rtx-titan" | "titan-rtx" | "titan" => {
            (GpuSpec::titan_rtx(), 10.0 * GIB)
        }
        "a100-40g" | "a100" => (GpuSpec::a100_40g(), 200.0 * GIB),
        "a100-80g" => (GpuSpec::a100_80g(), 200.0 * GIB),
        "cpu" => (GpuSpec { name: "cpu".into(), mem_bytes: 4.0 * GIB, flops: 30e9 }, 8.0 * GIB),
        _ => return None,
    })
}

/// Canonical GPU class names accepted by the island syntax.
pub fn gpu_class_names() -> Vec<&'static str> {
    vec!["A100-80G", "A100-40G", "RTX-TITAN-24G", "cpu"]
}

/// Quick shape check: does `name` look like island syntax rather than a
/// preset name? (`<count>x<gpu>[,<count>x<gpu>...]`, e.g.
/// `"2xA100-80G,2xRTX-TITAN-24G"`.)
pub fn looks_like_islands(name: &str) -> bool {
    let first = name.split(',').next().unwrap_or("");
    first
        .chars()
        .next()
        .map(|c| c.is_ascii_digit())
        .unwrap_or(false)
        && first.to_ascii_lowercase().contains('x')
}

/// Parse the island syntax `"<count>x<gpu>[,<count>x<gpu>...]"` into a
/// cluster, e.g. `"2xA100-80G,2xRTX-TITAN-24G"`. Each island gets its GPU
/// class's default intra bus; the inter-island link defaults to 10 GB/s
/// (100 Gb IB). The cluster's name is the canonical label, so artifacts
/// carrying it re-resolve through [`crate::api::resolve_cluster_name`].
pub fn parse_islands(spec: &str) -> Result<ClusterSpec, ClusterError> {
    let mut islands = Vec::new();
    for segment in spec.split(',') {
        let seg = segment.trim();
        if seg.is_empty() {
            return Err(ClusterError::Parse {
                segment: segment.to_string(),
                reason: "empty segment".into(),
            });
        }
        let split = seg
            .char_indices()
            .find(|(_, c)| *c == 'x' || *c == 'X')
            .map(|(i, _)| i)
            .ok_or_else(|| ClusterError::Parse {
                segment: seg.to_string(),
                reason: "missing 'x' between count and GPU class".into(),
            })?;
        let (count_str, rest) = seg.split_at(split);
        let gpu_name = &rest[1..];
        let count: usize = count_str.parse().map_err(|_| ClusterError::Parse {
            segment: seg.to_string(),
            reason: format!("bad device count {count_str:?}"),
        })?;
        let (gpu, intra_bw) = gpu_by_name(gpu_name)
            .ok_or_else(|| ClusterError::UnknownGpu { name: gpu_name.to_string() })?;
        islands.push(IslandSpec { gpu, count, intra_bw });
    }
    // 100 Gb IB across islands (~80% of line rate). The cluster's name is
    // its own canonical label, so one helper owns the format.
    let mut cluster = ClusterSpec::from_islands("islands", islands, 10.0 * GIB)?;
    cluster.name = cluster.islands_label();
    Ok(cluster)
}

/// Named cluster presets matching the paper's testbeds (§VII-A, §VII-D),
/// plus mixed-fleet presets for the heterogeneous scenario family.
pub fn cluster_by_name(name: &str) -> Option<ClusterSpec> {
    // Effective bandwidths (~80% of line rate): PCIe3 x16 ≈ 10 GB/s,
    // NVLink(A100) ≈ 200 GB/s, 100 Gb IB ≈ 10 GB/s, 400 Gb IB ≈ 40 GB/s.
    let preset = |c: Result<ClusterSpec, ClusterError>| {
        c.unwrap_or_else(|_| unreachable!("static preset is valid"))
    };
    Some(match name.to_ascii_lowercase().as_str() {
        // 8x RTX TITAN, single node, PCIe 3.0 (Table II).
        "titan8" => {
            preset(ClusterSpec::new("titan8", GpuSpec::titan_rtx(), 8, 8, 10.0 * GIB, 10.0 * GIB))
        }
        // 16x RTX TITAN over 2 servers, 100Gb IB — "low-perf" (Table III).
        "titan16" => {
            preset(ClusterSpec::new("titan16", GpuSpec::titan_rtx(), 16, 8, 10.0 * GIB, 10.0 * GIB))
        }
        // 16x A100 NVLink over 2 servers, 100Gb IB — "high-perf" (Table III).
        "a100x16" => {
            preset(ClusterSpec::new("a100x16", GpuSpec::a100_40g(), 16, 8, 200.0 * GIB, 10.0 * GIB))
        }
        // 64x A100 40GB, 8 servers, NVLink + 100Gb IB (Table IV).
        "a100x64" => {
            preset(ClusterSpec::new("a100x64", GpuSpec::a100_40g(), 64, 8, 200.0 * GIB, 10.0 * GIB))
        }
        // 32x A100 80GB, 400Gb IB (Table VI, GPT-3).
        "a100-80g-x32" => preset(ClusterSpec::new(
            "a100-80g-x32",
            GpuSpec::a100_80g(),
            32,
            8,
            200.0 * GIB,
            40.0 * GIB,
        )),
        // Mixed fleet: one PCIe TITAN server + one NVLink A100-80G server.
        // Islands deliberately ordered small-memory first, so the planner's
        // stage→island placement must actively move memory-heavy stages
        // onto the 80G island (it is not the device-order default).
        "hetero4" => preset(ClusterSpec::from_islands(
            "hetero4",
            vec![
                IslandSpec { gpu: GpuSpec::titan_rtx(), count: 2, intra_bw: 10.0 * GIB },
                IslandSpec { gpu: GpuSpec::a100_80g(), count: 2, intra_bw: 200.0 * GIB },
            ],
            10.0 * GIB,
        )),
        // Mixed fleet at server scale: 8x TITAN + 8x A100-40G over IB.
        "hetero16" => preset(ClusterSpec::from_islands(
            "hetero16",
            vec![
                IslandSpec { gpu: GpuSpec::titan_rtx(), count: 8, intra_bw: 10.0 * GIB },
                IslandSpec { gpu: GpuSpec::a100_40g(), count: 8, intra_bw: 200.0 * GIB },
            ],
            10.0 * GIB,
        )),
        // Small CPU-calibrated cluster used by the e2e runtime tests.
        "cpu4" => preset(ClusterSpec::new(
            "cpu4",
            GpuSpec { name: "cpu".into(), mem_bytes: 4.0 * GIB, flops: 30e9 },
            4,
            4,
            8.0 * GIB,
            8.0 * GIB,
        )),
        _ => return None,
    })
}

pub fn cluster_names() -> Vec<&'static str> {
    vec![
        "titan8",
        "titan16",
        "a100x16",
        "a100x64",
        "a100-80g-x32",
        "hetero4",
        "hetero16",
        "cpu4",
    ]
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in cluster_names() {
            let c = cluster_by_name(n).unwrap();
            assert!(c.n_devices() >= 4);
            assert!(c.intra_bw() >= c.inter_bw);
        }
    }

    #[test]
    fn group_bandwidth_hierarchy() {
        let c = cluster_by_name("a100x16").unwrap();
        // PP=2 puts one island per stage: all intra-stage groups use NVLink.
        assert_eq!(c.group_bandwidth(2, 8), c.intra_bw());
        // PP=1: a 16-wide group spans both islands -> IB.
        assert_eq!(c.group_bandwidth(1, 16), c.inter_bw);
        assert_eq!(c.group_bandwidth(1, 8), c.intra_bw());
    }

    #[test]
    fn memory_budget_override() {
        let c = cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB);
        assert_eq!(c.gpu().mem_bytes, 8.0 * GIB);
    }

    #[test]
    fn rejects_non_pow2_with_typed_error() {
        let err = ClusterSpec::new("bad", GpuSpec::titan_rtx(), 6, 2, 1.0, 1.0).unwrap_err();
        assert_eq!(err, ClusterError::NonPow2Devices { n: 6 });
        let err = ClusterSpec::new("bad", GpuSpec::titan_rtx(), 8, 3, 1.0, 1.0).unwrap_err();
        assert_eq!(err, ClusterError::BadIslandSize { island: 3, n: 8 });
        let err = ClusterSpec::new("bad", GpuSpec::titan_rtx(), 8, 16, 1.0, 1.0).unwrap_err();
        assert_eq!(err, ClusterError::BadIslandSize { island: 16, n: 8 });
        assert!(ClusterSpec::from_islands("bad", vec![], 1.0).is_err());
        // The happy path still constructs.
        let ok = ClusterSpec::new("ok", GpuSpec::titan_rtx(), 8, 4, 1.0, 1.0).unwrap();
        assert_eq!(ok.n_devices(), 8);
        assert_eq!(ok.n_islands(), 2);
    }

    #[test]
    fn homogeneous_detection_and_floor() {
        let hom = cluster_by_name("titan16").unwrap();
        assert!(hom.is_homogeneous());
        assert_eq!(hom.gpu(), GpuSpec::titan_rtx());
        let het = cluster_by_name("hetero4").unwrap();
        assert!(!het.is_homogeneous());
        // Floor: TITAN memory, TITAN flops.
        assert_eq!(het.gpu().mem_bytes, 24.0 * GIB);
        assert_eq!(het.gpu().flops, 10e12);
    }

    #[test]
    fn stage_sites_homogeneous_single_class() {
        let c = cluster_by_name("titan8").unwrap();
        for pp in [1usize, 2, 4, 8] {
            let sites = c.stage_sites(pp);
            assert_eq!(sites.len(), pp);
            assert!(sites.iter().all(|s| s.class == 0));
            assert!(sites.iter().all(|s| s.gpu == GpuSpec::titan_rtx()));
            // One island of 8: the limit is the stage group size itself.
            assert_eq!(sites[0].intra_limit, 8 / pp);
        }
    }

    #[test]
    fn stage_sites_mixed_islands() {
        let c = cluster_by_name("hetero4").unwrap();
        // PP=2: slot 0 = TITAN island, slot 1 = A100-80G island.
        let sites = c.stage_sites(2);
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].gpu.mem_bytes, 24.0 * GIB);
        assert_eq!(sites[1].gpu.mem_bytes, 80.0 * GIB);
        assert_ne!(sites[0].class, sites[1].class);
        // PP=1: the single slot spans both islands -> floor class.
        let sites = c.stage_sites(1);
        assert_eq!(sites.len(), 1);
        assert_eq!(sites[0].gpu.mem_bytes, 24.0 * GIB);
        assert_eq!(sites[0].gpu.flops, 10e12);
        assert_eq!(sites[0].intra_bw, 10.0 * GIB);
        // PP=4: one device per slot, two classes.
        let sites = c.stage_sites(4);
        assert_eq!(sites.len(), 4);
        assert_eq!(sites[0].class, sites[1].class);
        assert_eq!(sites[2].class, sites[3].class);
        assert_ne!(sites[0].class, sites[2].class);
    }

    #[test]
    fn island_syntax_round_trips() {
        let c = parse_islands("2xA100-80G,2xRTX-TITAN-24G").unwrap();
        assert_eq!(c.name, "2xA100-80G,2xRTX-TITAN-24G");
        assert_eq!(c.islands_label(), c.name);
        assert_eq!(c.n_devices(), 4);
        assert!(!c.is_homogeneous());
        // Case-insensitive classes and aliases.
        let c2 = parse_islands("2xa100-80g,2xtitan").unwrap();
        assert_eq!(c2.islands_label(), c.islands_label());
        // Homogeneous single island.
        let h = parse_islands("8xRTX-TITAN-24G").unwrap();
        assert!(h.is_homogeneous());
        assert_eq!(h.n_devices(), 8);
    }

    #[test]
    fn island_syntax_rejects_bad_input() {
        assert!(matches!(
            parse_islands("2xH100").unwrap_err(),
            ClusterError::UnknownGpu { .. }
        ));
        assert!(matches!(
            parse_islands("twoxA100-80G").unwrap_err(),
            ClusterError::Parse { .. }
        ));
        assert!(matches!(parse_islands("A100-80G").unwrap_err(), ClusterError::Parse { .. }));
        // 3 + 2 devices: island and total shape errors surface typed.
        assert!(matches!(
            parse_islands("3xA100-80G,2xtitan").unwrap_err(),
            ClusterError::NonPow2Island { count: 3 }
        ));
        assert!(matches!(
            parse_islands("4xA100-80G,2xtitan").unwrap_err(),
            ClusterError::NonPow2Devices { n: 6 }
        ));
    }

    #[test]
    fn looks_like_islands_shape_check() {
        assert!(looks_like_islands("2xA100-80G,2xRTX-TITAN-24G"));
        assert!(looks_like_islands("8xtitan"));
        assert!(!looks_like_islands("titan8"));
        assert!(!looks_like_islands("a100x16"));
        assert!(!looks_like_islands(""));
    }

    #[test]
    fn ideal_link_model_is_pure_division() {
        let l = LinkModel::ideal();
        let (bytes, bw) = (12345.678f64, 10.0 * GIB);
        assert_eq!(l.time(bytes, bw).to_bits(), (bytes / bw).to_bits());
        assert_eq!(l.time(0.0, bw), 0.0);
    }

    #[test]
    fn fitted_link_model_adds_latency_and_derates_bandwidth() {
        let l = LinkModel { alpha: 1e-5, efficiency: 0.5 };
        let bw = 10.0 * GIB;
        // Zero bytes never pay the latency.
        assert_eq!(l.time(0.0, bw), 0.0);
        // Nonzero transfers pay alpha plus the derated division.
        let t = l.time(1e6, bw);
        assert!((t - (1e-5 + 1e6 / (bw * 0.5))).abs() < 1e-15);
        assert!(t > LinkModel::ideal().time(1e6, bw));
    }
}
