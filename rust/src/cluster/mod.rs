//! Cluster topology model: devices, islands, and hierarchical bandwidth.
//!
//! Paper Takeaway #1: PP prefers to be applied across device "islands"
//! (sets of devices with high-bandwidth interconnect); slower inter-island
//! links carry only pipeline boundary activations. The planner needs, for a
//! communication group of a given size at a given decision-tree level, the
//! effective bandwidth of the slowest link that group spans — this module
//! provides that.

use crate::util::{is_pow2, GIB};

/// GPU device class.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Device memory in bytes.
    pub mem_bytes: f64,
    /// Effective training-matmul throughput in FLOP/s (calibration constant
    /// that sets the absolute throughput scale; see DESIGN.md §2).
    pub flops: f64,
}

impl GpuSpec {
    pub fn titan_rtx() -> Self {
        GpuSpec { name: "RTX-TITAN-24G".into(), mem_bytes: 24.0 * GIB, flops: 10e12 }
    }

    pub fn a100_40g() -> Self {
        GpuSpec { name: "A100-40G".into(), mem_bytes: 40.0 * GIB, flops: 40e12 }
    }

    pub fn a100_80g() -> Self {
        GpuSpec { name: "A100-80G".into(), mem_bytes: 80.0 * GIB, flops: 40e12 }
    }
}

/// A training cluster: `n_devices` homogeneous GPUs grouped into equal
/// islands; full bandwidth inside an island, `inter_bw` across.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub gpu: GpuSpec,
    pub n_devices: usize,
    /// Devices per island (e.g. one server).
    pub island_size: usize,
    /// Intra-island effective bus bandwidth, bytes/s (NVLink or PCIe).
    pub intra_bw: f64,
    /// Inter-island effective bandwidth, bytes/s (IB / Ethernet).
    pub inter_bw: f64,
}

impl ClusterSpec {
    pub fn new(
        name: &str,
        gpu: GpuSpec,
        n_devices: usize,
        island_size: usize,
        intra_bw: f64,
        inter_bw: f64,
    ) -> Self {
        assert!(is_pow2(n_devices), "device count must be a power of two");
        assert!(is_pow2(island_size) && island_size <= n_devices);
        assert_eq!(n_devices % island_size, 0);
        ClusterSpec {
            name: name.into(),
            gpu,
            n_devices,
            island_size,
            intra_bw,
            inter_bw,
        }
    }

    pub fn n_islands(&self) -> usize {
        self.n_devices / self.island_size
    }

    /// Effective bandwidth for a communication group of `group` devices,
    /// when the total devices are already partitioned into `pp` pipeline
    /// groups of `n_devices/pp` (Takeaway #1 placement: PP cuts across the
    /// slowest links first, so a group of size g inside one pipeline stage
    /// spans islands only if g exceeds what is left of an island inside the
    /// stage group).
    pub fn group_bandwidth(&self, pp_degree: usize, group: usize) -> f64 {
        let stage_devices = self.n_devices / pp_degree.max(1);
        // Devices of one island that belong to the same stage.
        let island_in_stage = self.island_size.min(stage_devices);
        if group <= island_in_stage {
            self.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// Bandwidth of the link crossed by pipeline p2p at stage boundaries.
    pub fn pipeline_link_bw(&self, pp_degree: usize) -> f64 {
        if pp_degree <= self.n_islands() {
            // Stage boundaries align with island boundaries.
            self.inter_bw
        } else {
            // Some stage boundaries fall inside an island; conservatively
            // the bottleneck for cost purposes is the slower inter link if
            // any boundary crosses islands, otherwise intra.
            if self.n_islands() > 1 {
                self.inter_bw
            } else {
                self.intra_bw
            }
        }
    }

    /// Memory budget per device possibly restricted below physical memory
    /// (the paper evaluates 8/12/16/20 GB budgets on 24 GB cards).
    pub fn with_memory_budget(mut self, budget_bytes: f64) -> Self {
        self.gpu.mem_bytes = budget_bytes;
        self
    }
}

/// Named cluster presets matching the paper's testbeds (§VII-A, §VII-D).
pub fn cluster_by_name(name: &str) -> Option<ClusterSpec> {
    // Effective bandwidths (~80% of line rate): PCIe3 x16 ≈ 10 GB/s,
    // NVLink(A100) ≈ 200 GB/s, 100 Gb IB ≈ 10 GB/s, 400 Gb IB ≈ 40 GB/s.
    Some(match name.to_ascii_lowercase().as_str() {
        // 8x RTX TITAN, single node, PCIe 3.0 (Table II).
        "titan8" => ClusterSpec::new("titan8", GpuSpec::titan_rtx(), 8, 8, 10.0 * GIB, 10.0 * GIB),
        // 16x RTX TITAN over 2 servers, 100Gb IB — "low-perf" (Table III).
        "titan16" => ClusterSpec::new("titan16", GpuSpec::titan_rtx(), 16, 8, 10.0 * GIB, 10.0 * GIB),
        // 16x A100 NVLink over 2 servers, 100Gb IB — "high-perf" (Table III).
        "a100x16" => ClusterSpec::new("a100x16", GpuSpec::a100_40g(), 16, 8, 200.0 * GIB, 10.0 * GIB),
        // 64x A100 40GB, 8 servers, NVLink + 100Gb IB (Table IV).
        "a100x64" => ClusterSpec::new("a100x64", GpuSpec::a100_40g(), 64, 8, 200.0 * GIB, 10.0 * GIB),
        // 32x A100 80GB, 400Gb IB (Table VI, GPT-3).
        "a100-80g-x32" => {
            ClusterSpec::new("a100-80g-x32", GpuSpec::a100_80g(), 32, 8, 200.0 * GIB, 40.0 * GIB)
        }
        // Small CPU-calibrated cluster used by the e2e runtime tests.
        "cpu4" => ClusterSpec::new("cpu4", GpuSpec { name: "cpu".into(), mem_bytes: 4.0 * GIB, flops: 30e9 }, 4, 4, 8.0 * GIB, 8.0 * GIB),
        _ => return None,
    })
}

pub fn cluster_names() -> Vec<&'static str> {
    vec!["titan8", "titan16", "a100x16", "a100x64", "a100-80g-x32", "cpu4"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in cluster_names() {
            let c = cluster_by_name(n).unwrap();
            assert!(c.n_devices >= 4);
            assert!(c.intra_bw >= c.inter_bw);
        }
    }

    #[test]
    fn group_bandwidth_hierarchy() {
        let c = cluster_by_name("a100x16").unwrap();
        // PP=2 puts one island per stage: all intra-stage groups use NVLink.
        assert_eq!(c.group_bandwidth(2, 8), c.intra_bw);
        // PP=1: a 16-wide group spans both islands -> IB.
        assert_eq!(c.group_bandwidth(1, 16), c.inter_bw);
        assert_eq!(c.group_bandwidth(1, 8), c.intra_bw);
    }

    #[test]
    fn memory_budget_override() {
        let c = cluster_by_name("titan8").unwrap().with_memory_budget(8.0 * GIB);
        assert_eq!(c.gpu.mem_bytes, 8.0 * GIB);
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2() {
        ClusterSpec::new("bad", GpuSpec::titan_rtx(), 6, 2, 1.0, 1.0);
    }
}
