//! galvatron — CLI for the Galvatron-BMW reproduction.
//!
//! Subcommands:
//!   plan      find the optimal plan for a model/cluster/budget
//!             (optionally persisting it with --out plan.json)
//!   simulate  cross-check a plan on the discrete-event simulator, either
//!             re-planned from names or loaded from --plan plan.json
//!   check     statically verify plan artifacts / ModelSpec files /
//!             frontier artifacts with typed GAL0xxx diagnostics (exit 1
//!             on any error)
//!   advise    elastic capacity planning: sweep a priced fleet search
//!             space to a Pareto frontier, or replan a plan artifact
//!             under lost islands (--degrade)
//!   serve     long-lived planning daemon: JSONL on stdin/stdout or
//!             HTTP/1.1 (--http), warm caches + in-flight request dedup
//!   table2..6 regenerate the paper's tables
//!   fig4..7   regenerate the paper's figures
//!   train     run real-numerics e2e training over the AOT artifacts
//!   profile   calibrate the cost model by profiling artifacts on PJRT-CPU
//!   calibrate write a ProfileDb (layer profiles + collectives micro-bench,
//!             or --synthetic from the analytic model) for plan --profile-db
//!   smoke     runtime smoke test (load + execute the axpy artifact)
//!   models    list the Table I model zoo (--json emits ModelSpec JSON,
//!             --file validates a spec file, --out-dir exports the zoo)
//!   clusters  list cluster presets
//!   methods   list the strategy catalog

use anyhow::{Context, Result};
use galvatron::api::{parse_schedule, MethodSpec, PlanError, PlanReport, PlanRequest, Planner};
use galvatron::experiments::{figures, tables, ExpOptions};
use galvatron::runtime::{HostTensor, Runtime};
use galvatron::util::cli::Args;

const USAGE: &str = "\
galvatron <command> [options]

commands:
  plan      --model <name> | --model-file model.json
            --cluster <name> --memory <GB> [--method <name>]
            [--islands 2xA100-80G,2xRTX-TITAN-24G] [--max-batch N]
            [--dtype fp32|fp16|bf16] [--optimizer sgd|adam] [--zero]
            [--profile-db db.json] [--schedule 1f1b|gpipe] [--threads N]
            [--cache-dir DIR] [--out plan.json]
  simulate  --plan plan.json [--profile-db db.json]
            | --model <name> --cluster <name> --memory <GB> [--method <name>]
  check     --plan plan.json and/or --model-file spec.json
            and/or --frontier frontier.json
            [--cluster <name> | --islands <spec>] [--json]
            (static verifier: exits 1 on any error-severity diagnostic)
  advise    --gpus A100-80G:0..8,RTX-TITAN-24G:0..8 [--max-islands N]
            [--model <name>] [--max-batch N] [--method <name>]
            [--min-throughput X] [--threads N] [--cache-dir DIR]
            [--out frontier.json] [--json]
            | --degrade plan.json [--lose N] [--threads N]
            [--cache-dir DIR] [--json]
  serve     [--cache-dir DIR] [--http ADDR:PORT] [--workers N] [--threads N]
            (planning daemon: JSONL requests on stdin, one response per
            line on stdout, until EOF; --http serves POST /plan,
            POST /plan/artifact, POST /advise and GET /health instead)
  table2    [--models a,b] [--budgets 8,16] [--methods m1,m2] [--max-batch N]
  table3 | table4 | table5 | table6     (same options)
  hetero    heterogeneous-cluster sweep [--models a,b] [--max-batch N]
  fig4 | fig5 | fig6 | fig7             [--max-batch N]
  train     [--artifacts DIR] [--steps N] [--dp N] [--microbatches N] [--csv FILE] [--repeat-batch]
  profile   [--artifacts DIR] [--reps N]
  calibrate [--out db.json] [--artifacts DIR] [--reps N] [--coll-reps N]
            | --synthetic [--cluster <name>] [--out db.json]
  smoke     [--artifacts DIR]
  models    [--json] [--file spec.json] [--out-dir DIR]
  clusters | methods
";

fn exp_options(args: &Args) -> Result<ExpOptions> {
    let list = |key: &str| -> Vec<String> {
        args.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_default()
    };
    Ok(ExpOptions {
        max_batch: args.usize("max-batch", 512)?,
        models: list("models"),
        budgets: args
            .get("budgets")
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().parse::<f64>().context("budget"))
                    .collect::<Result<Vec<_>>>()
            })
            .transpose()?
            .unwrap_or_default(),
        methods: list("methods"),
    })
}

/// Build a [`PlanRequest`] from the shared plan/simulate options. Unknown
/// model/cluster/method names surface as [`PlanError`]s with did-you-mean
/// suggestions (not panics).
fn plan_request(args: &Args) -> Result<PlanRequest> {
    // `--islands 2xA100-80G,2xRTX-TITAN-24G` describes a mixed fleet
    // inline; it takes precedence over `--cluster` preset names.
    let cluster = match args.get("islands") {
        Some(spec) => spec.to_string(),
        None => args.get_or("cluster", "titan8").to_string(),
    };
    // Heterogeneous clusters fix per-island budgets via their GPU classes,
    // so the paper's 16 GB default applies only to homogeneous clusters
    // (presets or single-class island strings, whichever flag carried
    // them); an explicit --memory is always forwarded (and diagnosed by
    // the API).
    let heterogeneous = match galvatron::cluster::cluster_by_name(&cluster) {
        Some(c) => !c.is_homogeneous(),
        None => {
            galvatron::cluster::looks_like_islands(&cluster)
                && galvatron::cluster::parse_islands(&cluster)
                    .map_or(true, |c| !c.is_homogeneous())
        }
    };
    let mut req = PlanRequest::new(args.get_or("model", "bert-huge-32"), &cluster)
        .max_batch(args.usize("max-batch", 512)?)
        .method_name(args.get_or("method", "Galvatron-BMW"));
    // `--model-file model.json` plans a declarative ModelSpec; it takes
    // precedence over `--model` zoo names (which also accept .json paths).
    if let Some(path) = args.get("model-file") {
        req = req.model_file(path);
    }
    // Training numerics: dtype / optimizer / ZeRO sharding. The defaults
    // (fp32 + Adam, unsharded) are the paper's setting.
    if let Some(d) = args.get("dtype") {
        req = req.dtype(d.parse::<galvatron::model::Dtype>().map_err(anyhow::Error::new)?);
    }
    if let Some(o) = args.get("optimizer") {
        req = req
            .optimizer(o.parse::<galvatron::model::OptimizerKind>().map_err(anyhow::Error::new)?);
    }
    if args.flag("zero") {
        req = req.zero(true);
    }
    if !heterogeneous || args.get("memory").is_some() {
        req = req.memory_gb(args.f64("memory", 16.0)?);
    }
    if let Some(s) = args.get("schedule") {
        req = req.schedule(parse_schedule(s)?);
    }
    if let Some(m) = args.get("microbatch-limit") {
        req = req.microbatch_limit(m.parse().context("--microbatch-limit expects an integer")?);
    }
    // Worker threads for the search engine (default: GALVATRON_THREADS or
    // the machine's available parallelism; plans are identical either way).
    if let Some(t) = args.get("threads") {
        req = req.threads(t.parse().context("--threads expects an integer")?);
    }
    // Calibrated cost-model backend from a `galvatron calibrate` DB.
    if let Some(db) = args.get("profile-db") {
        req = req.profile_db(db);
    }
    // Persistent planning cache (also reachable via GALVATRON_CACHE_DIR).
    if let Some(dir) = args.get("cache-dir") {
        req = req.cache_dir(dir);
    }
    Ok(req)
}

fn cmd_plan(args: &Args) -> Result<()> {
    let planner = Planner::new();
    let req = plan_request(args)?;
    let resolved = planner.resolve(&req)?;
    println!(
        "planning {} on {} ({} devices, {}) with {} ...",
        resolved.model.name,
        resolved.cluster_name,
        resolved.cluster.n_devices(),
        resolved.cluster.budget_label(),
        resolved.method.canonical_name()
    );
    // Plan from the resolution above (avoids re-reading --profile-db).
    let report = match planner.plan_resolved(&resolved) {
        Ok(report) => report,
        Err(PlanError::Infeasible { .. }) => {
            println!("OOM: no feasible plan under this budget");
            // Keep --out deterministic for CI `cmp` gates even on OOM.
            if let Some(path) = args.get("out") {
                std::fs::write(path, "OOM\n")?;
                println!("wrote OOM marker to {path}");
            }
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    print!("{}", report.render());
    // Wall-clock breakdown (cold vs warm-start); never part of the artifact.
    if let Some(t) = report.search_trace.as_ref().and_then(|t| t.timing_summary()) {
        println!("{t}");
    }
    // Cross-check on the simulator under the same cost-model backend the
    // search priced with (resolved once above).
    let sim = planner.simulate_plan_costed(
        &resolved.model,
        &resolved.cluster,
        &report,
        &resolved.cost_model,
    )?;
    println!(
        "simulated: {:.2} samples/s, iter {:.3}s, bubbles {:?}",
        sim.throughput,
        sim.iter_time,
        sim.bubble_fraction.iter().map(|b| format!("{b:.2}")).collect::<Vec<_>>()
    );
    if let Some(path) = args.get("out") {
        report.save(std::path::Path::new(path))?;
        println!("wrote plan artifact to {path}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    use galvatron::api::{CostModel, ProfileDb};
    let planner = Planner::new();
    // The cost-model backend the simulation prices tasks with.
    let cost_model = match args.get("profile-db") {
        Some(path) => CostModel::calibrated(
            ProfileDb::load(std::path::Path::new(path)).map_err(PlanError::from)?,
        ),
        None => CostModel::Analytic,
    };
    let report = match args.get("plan") {
        Some(path) => {
            let report = PlanReport::load(std::path::Path::new(path))?;
            println!(
                "loaded plan artifact {path}: {} on {} @ {:.0} GB ({})",
                report.model,
                report.cluster,
                report.memory_budget_gb,
                report.method.canonical_name()
            );
            report
        }
        None => {
            // Hand the already-loaded backend to the planner so the DB is
            // not read and validated from disk a second time.
            let mut req = plan_request(args)?;
            if !cost_model.is_analytic() {
                req = req.cost_model(cost_model.clone());
            }
            planner.plan(&req)?
        }
    };
    // Provenance check: a plan is only comparable to a simulation priced
    // by the same cost theory that produced it.
    if report.cost_model != cost_model.provenance() {
        let recorded = report
            .cost_model
            .as_ref()
            .map(|p| p.label())
            .unwrap_or_else(|| "analytic".into());
        let current = cost_model
            .provenance()
            .map(|p| p.label())
            .unwrap_or_else(|| "analytic".into());
        galvatron::util::diag::warn(&format!(
            "plan artifact records the {recorded} cost model but is being \
             simulated with {current}; estimated and simulated throughputs may not be \
             comparable (pass the matching --profile-db to align them)"
        ));
    }
    let sim = planner.simulate_report_costed(&report, &cost_model)?;
    println!(
        "plan: est {:.2} samples/s | sim {:.2} samples/s",
        report.throughput, sim.throughput
    );
    for (i, (mem, bub)) in sim.stage_peak_mem.iter().zip(&sim.bubble_fraction).enumerate() {
        println!("  stage {i}: peak {:.2} GiB, bubble {:.1}%", mem / galvatron::util::GIB, bub * 100.0);
    }
    Ok(())
}

/// `galvatron check`: run the static verifier (typed `GAL0xxx`
/// diagnostics; see README "Verifying plans and specs") over a plan
/// artifact and/or a ModelSpec file. Exit code 1 on any Error-severity
/// finding, 0 otherwise (warnings and notes are advisory).
fn cmd_check(args: &Args) -> Result<()> {
    use galvatron::check::{self, CheckReport};
    let mut report = CheckReport::default();
    let mut checked = Vec::new();
    let run = |report: &mut CheckReport, checked: &mut Vec<String>| -> Result<()> {
        if let Some(path) = args.get("plan") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading plan artifact {path}"))?;
            report.merge(check::check_plan_text(&text));
            checked.push(path.to_string());
        }
        if let Some(path) = args.get("model-file") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading model spec {path}"))?;
            let v = galvatron::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{path} is not JSON: {e}"))?;
            // Spec lints run standalone; with a cluster the never-fits
            // lints (GAL0030/GAL0031) run too.
            let cluster = match args.get("islands").or_else(|| args.get("cluster")) {
                Some(name) => Some(galvatron::api::resolve_cluster_name(name)?),
                None => None,
            };
            report.merge(check::check_model_json(&v, cluster.as_ref()));
            checked.push(path.to_string());
        }
        if let Some(path) = args.get("frontier") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading frontier artifact {path}"))?;
            report.merge(check::check_frontier_text(&text));
            checked.push(path.to_string());
        }
        Ok(())
    };
    // In --json mode operational warnings join the payload (the
    // `diag_warnings` array — distinct from the report's numeric
    // `warnings` count) instead of interleaving with it on stderr.
    let (result, diag_warnings) = if args.flag("json") {
        galvatron::util::diag::capture(|| run(&mut report, &mut checked))
    } else {
        (run(&mut report, &mut checked), Vec::new())
    };
    result?;
    anyhow::ensure!(
        !checked.is_empty(),
        "check needs --plan plan.json, --model-file spec.json and/or --frontier frontier.json"
    );
    if args.flag("json") {
        let mut payload = report.to_json();
        if !diag_warnings.is_empty() {
            if let galvatron::util::json::Json::Obj(map) = &mut payload {
                map.insert(
                    "diag_warnings".to_string(),
                    galvatron::util::json::Json::arr(
                        diag_warnings.iter().map(|w| galvatron::util::json::Json::str(w)),
                    ),
                );
            }
        }
        println!("{payload}");
    } else {
        for path in &checked {
            println!("checked {path}");
        }
        print!("{}", report.render());
    }
    if report.has_errors() {
        std::process::exit(1);
    }
    Ok(())
}

/// `galvatron advise`: elastic capacity planning. The sweep form
/// enumerates a priced fleet search space (`--gpus CLASS:lo..hi,...`),
/// plans every viable fleet through one shared warm cache, and prints
/// the Pareto frontier over (throughput, memory headroom, $/hr). The
/// `--degrade plan.json` form replans an existing plan artifact under
/// every combination of `--lose N` lost islands. See the README
/// "Capacity advice" section.
fn cmd_advise(args: &Args) -> Result<()> {
    use galvatron::advise::{advise, degrade, parse_fleet_spec, AdviseRequest, DegradeOptions};
    let threads: Option<usize> = match args.get("threads") {
        Some(t) => Some(t.parse().context("--threads expects an integer")?),
        None => None,
    };
    let cache_dir = args
        .get("cache-dir")
        .map(std::path::PathBuf::from)
        .or_else(|| std::env::var_os("GALVATRON_CACHE_DIR").map(std::path::PathBuf::from));

    // Failure-aware replanning of an existing plan artifact.
    if let Some(path) = args.get("degrade") {
        let base = PlanReport::load(std::path::Path::new(path))?;
        let opts = DegradeOptions { lose: args.usize("lose", 1)?, threads, cache_dir };
        let report = degrade(&base, &opts)?;
        if args.flag("json") {
            println!("{}", report.to_json());
        } else {
            print!("{}", report.render());
        }
        return Ok(());
    }

    // Fleet sweep to a Pareto frontier.
    let gpus = args
        .get("gpus")
        .ok_or_else(|| anyhow::anyhow!(
            "advise needs --gpus CLASS:lo..hi[,CLASS:lo..hi] (or --degrade plan.json)"
        ))?;
    let space = parse_fleet_spec(gpus, args.usize("max-islands", 3)?)?;
    let mut req = AdviseRequest::new(args.get_or("model", "bert-huge-32"), space)
        .max_batch(args.usize("max-batch", 64)?);
    if let Some(name) = args.get("method") {
        req = req.method(MethodSpec::parse(name)?);
    }
    if let Some(t) = threads {
        req = req.threads(t);
    }
    if let Some(dir) = cache_dir {
        req = req.cache_dir(dir);
    }
    let frontier = advise(&req)?;
    if args.flag("json") {
        print!("{}", frontier.to_pretty_string());
    } else {
        print!("{}", frontier.render());
    }
    if let Some(min) = args.get("min-throughput") {
        let min: f64 = min.parse().context("--min-throughput expects a number")?;
        match frontier.cheapest_at_least(min) {
            Some(p) => println!(
                "cheapest fleet >= {min} samples/s: {} at ${:.2}/hr ({:.2} samples/s)",
                p.cluster, p.cost_per_hour, p.throughput
            ),
            None => println!("no surveyed fleet reaches {min} samples/s"),
        }
    }
    if let Some(path) = args.get("out") {
        frontier.save(std::path::Path::new(path))?;
        println!("wrote frontier artifact to {path}");
    }
    Ok(())
}

/// `galvatron serve`: the long-lived planning daemon. Default transport
/// is JSONL on stdin/stdout (one request per line, one response per
/// line, exit at EOF); `--http ADDR` serves HTTP/1.1 instead. See the
/// README "Serving plans" section for the request/response schema.
fn cmd_serve(args: &Args) -> Result<()> {
    use galvatron::serve::{run_jsonl, serve_http, ServeState};
    let workers = args.usize("workers", 4)?.max(1);
    // Concurrent searches draw engine threads from one machine-wide
    // budget (sized like a single CLI run's pool) instead of each
    // spawning a full pool; grants never change plan bytes.
    let threads = match args.get("threads") {
        Some(t) => Some(t.parse().context("--threads expects an integer")?),
        None => None,
    };
    galvatron::util::parallelism::install_worker_budget(
        galvatron::util::parallelism::resolve_worker_count(threads),
    );
    let cache_dir = args
        .get("cache-dir")
        .map(std::path::PathBuf::from)
        .or_else(|| std::env::var_os("GALVATRON_CACHE_DIR").map(std::path::PathBuf::from));
    if let Some(dir) = &cache_dir {
        eprintln!("serve: persistent cache at {}", dir.display());
    }
    let state = std::sync::Arc::new(ServeState::new(cache_dir));
    match args.get("http") {
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .with_context(|| format!("binding {addr}"))?;
            let local = listener.local_addr()?;
            // Readiness line for supervisors; stdout is block-buffered
            // when piped, so flush explicitly.
            println!("serving http on {local} ({workers} workers)");
            use std::io::Write;
            std::io::stdout().flush()?;
            serve_http(listener, state, workers)?;
        }
        None => {
            let stdin = std::io::stdin();
            run_jsonl(&state, stdin.lock(), std::io::stdout(), workers)?;
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = galvatron::coordinator::TrainerConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        steps: args.usize("steps", 100)?,
        dp: args.usize("dp", 2)?,
        microbatches: args.usize("microbatches", 2)?,
        log_every: args.usize("log-every", 10)?,
        seed: args.usize("seed", 0)? as u64,
        repeat_batch: args.flag("repeat-batch"),
    };
    let mut trainer = galvatron::coordinator::Trainer::new(cfg.clone())?;
    println!(
        "training: {} params, dp={}, {} microbatches/step, {} samples/step",
        trainer.param_count,
        cfg.dp,
        cfg.microbatches,
        trainer.samples_per_step()
    );
    let report = trainer.train()?;
    println!(
        "done: loss {:.4} -> {:.4}, {:.2} samples/s",
        report.losses.first().unwrap_or(&f64::NAN),
        report.losses.last().unwrap_or(&f64::NAN),
        report.samples_per_sec()
    );
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, report.to_csv())?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let rt = Runtime::new(std::path::Path::new(args.get_or("artifacts", "artifacts")))?;
    let reps = args.usize("reps", 10)?;
    let ms = galvatron::runtime::profile::profile_layers(&rt, reps)?;
    for m in &ms {
        println!(
            "layer h={:<5} seq={:<5} batch={:<3} {:.2} ms/fwd  {:.2} GFLOP/s",
            m.hidden,
            m.seq,
            m.batch,
            m.seconds_per_fwd * 1e3,
            m.effective_flops / 1e9
        );
    }
    let spec = galvatron::runtime::profile::calibrated_host_spec(&ms, 4.0 * galvatron::util::GIB);
    println!("calibrated host spec: {:.2} GFLOP/s effective", spec.flops / 1e9);
    Ok(())
}

/// `galvatron calibrate`: write a cost-model [`galvatron::api::ProfileDb`]
/// for `plan --profile-db`. The default path measures this host (PJRT
/// layer profiles + in-process collectives micro-benchmark);
/// `--synthetic` derives a deterministic DB from the analytic model of a
/// cluster (exact zoo coverage, alpha=0) — the CI/byte-identity form.
fn cmd_calibrate(args: &Args) -> Result<()> {
    use galvatron::api::ProfileDb;
    let out = args.get_or("out", "profile-db.json").to_string();
    let db = if args.flag("synthetic") {
        let cluster = galvatron::api::resolve_cluster_name(args.get_or("cluster", "titan8"))?;
        println!("deriving synthetic profile db from the analytic model of {}", cluster.name);
        ProfileDb::synthetic(&cluster)
    } else {
        let rt = Runtime::new(std::path::Path::new(args.get_or("artifacts", "artifacts")))?;
        let reps = args.usize("reps", 10)?;
        let ms = galvatron::runtime::profile::profile_layers(&rt, reps)?;
        for m in &ms {
            println!(
                "layer h={:<5} seq={:<5} batch={:<3} {:.2} ms/fwd  {:.2} GFLOP/s",
                m.hidden,
                m.seq,
                m.batch,
                m.seconds_per_fwd * 1e3,
                m.effective_flops / 1e9
            );
        }
        let layers = galvatron::runtime::profile::to_layer_samples(&ms);
        let collectives =
            galvatron::cost::measure_collectives(args.usize("coll-reps", 5)?);
        // Efficiencies are recorded relative to the host device class's
        // nominal rates (the `cpu` catalog entry).
        let (host, host_bw) = galvatron::cluster::gpu_by_name("cpu")
            .ok_or_else(|| anyhow::anyhow!("cpu device class missing from the catalog"))?;
        ProfileDb::from_measurements("pjrt-cpu", host.flops, host_bw, layers, collectives)?
    };
    db.save(std::path::Path::new(&out))?;
    println!(
        "wrote profile db {out}: {} layer samples, {} collective points, alpha {:.3e} s, \
         beta {:.2} GB/s, hash {}",
        db.layers.len(),
        db.collectives.len(),
        db.alpha,
        db.beta / 1e9,
        db.content_hash_hex()
    );
    Ok(())
}

/// `galvatron models`: the zoo as a table; `--json` emits every model's
/// declarative `ModelSpec`; `--file spec.json` compiles (validates) a
/// single spec file instead; `--out-dir DIR` exports the zoo specs as
/// JSON files (the source of `examples/models/`).
fn cmd_models(args: &Args) -> Result<()> {
    use galvatron::model::{model_names, spec_by_name, ModelSpec};
    let entries: Vec<(String, ModelSpec)> = match args.get("file") {
        Some(path) => {
            let spec = ModelSpec::load(std::path::Path::new(path))?;
            vec![(path.to_string(), spec)]
        }
        None => model_names()
            .iter()
            .filter_map(|n| spec_by_name(n).map(|s| (n.to_string(), s)))
            .collect(),
    };
    if let Some(dir) = args.get("out-dir") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir)?;
        for (_, spec) in &entries {
            // Name the file after the spec itself (not the lookup key,
            // which is a whole path under --file).
            let slug = spec.name.to_ascii_lowercase().replace('/', "-");
            let path = dir.join(format!("{slug}.json"));
            spec.save(&path)?;
            println!("wrote {}", path.display());
        }
        return Ok(());
    }
    if args.flag("json") {
        println!(
            "{}",
            galvatron::util::json::Json::arr(entries.iter().map(|(_, s)| s.to_json()))
        );
        return Ok(());
    }
    let range = |lo: usize, hi: usize| {
        if lo == hi {
            format!("{lo}")
        } else {
            format!("{lo}-{hi}")
        }
    };
    for (key, spec) in &entries {
        let p = spec.compile()?;
        let hidden = range(
            spec.blocks.iter().map(|b| b.hidden).min().unwrap_or(0),
            spec.blocks.iter().map(|b| b.hidden).max().unwrap_or(0),
        );
        let seq = range(
            spec.blocks.iter().map(|b| b.seq).min().unwrap_or(0),
            spec.blocks.iter().map(|b| b.seq).max().unwrap_or(0),
        );
        println!(
            "{:<14} {:<15} {:>4} layers  {:>9.1}M params  hidden {:<9} seq {:<9} {:>9.1} MB act/sample",
            key,
            spec.family.key(),
            p.n_layers(),
            p.total_params() / 1e6,
            hidden,
            seq,
            p.total_act_bytes() / 1e6
        );
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let rt = Runtime::new(std::path::Path::new(args.get_or("artifacts", "artifacts")))?;
    let man = rt.manifest()?;
    let art = rt.load("smoke", &man.smoke.file, man.smoke.inputs.clone(), man.smoke.outputs.clone())?;
    let out = art.run(&[
        HostTensor::scalar_f32(3.0),
        HostTensor::F32 { shape: vec![16], data: vec![1.0; 16] },
        HostTensor::F32 { shape: vec![16], data: vec![0.5; 16] },
    ])?;
    anyhow::ensure!(out[0].as_f32()?.iter().all(|&v| (v - 3.5).abs() < 1e-6));
    println!(
        "smoke OK (platform: PJRT CPU; preset {}, {} params, kernels={})",
        man.preset, man.param_count, man.kernels
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env(&["repeat-batch", "speedups", "zero", "json", "synthetic"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "plan" => cmd_plan(&args)?,
        "table2" => {
            tables::table2(&exp_options(&args)?);
        }
        "table3" => {
            tables::table3(&exp_options(&args)?);
        }
        "table4" => {
            tables::table4(&exp_options(&args)?);
        }
        "table5" => {
            tables::table5(&exp_options(&args)?);
        }
        "table6" => {
            tables::table6(&exp_options(&args)?);
        }
        "hetero" => {
            tables::table_hetero(&exp_options(&args)?);
        }
        "fig4" => {
            figures::fig4(&exp_options(&args)?);
        }
        "fig5" => {
            let o = exp_options(&args)?;
            figures::fig5a(&o);
            figures::fig5b(&o);
        }
        "fig6" => {
            figures::fig6(&exp_options(&args)?);
        }
        "fig7" => {
            figures::fig7(&exp_options(&args)?);
        }
        "train" => cmd_train(&args)?,
        "profile" => cmd_profile(&args)?,
        "calibrate" => cmd_calibrate(&args)?,
        "smoke" => cmd_smoke(&args)?,
        "simulate" => cmd_simulate(&args)?,
        "check" => cmd_check(&args)?,
        "advise" => cmd_advise(&args)?,
        "serve" => cmd_serve(&args)?,
        "models" => cmd_models(&args)?,
        "clusters" => {
            for c in galvatron::cluster::cluster_names() {
                let Some(cl) = galvatron::cluster::cluster_by_name(c) else { continue };
                let islands = cl
                    .islands
                    .iter()
                    .map(|i| format!("{}x{}@{:.0}G", i.count, i.gpu.name, i.intra_bw / 1e9))
                    .collect::<Vec<_>>()
                    .join(" + ");
                println!(
                    "{:<13} {:>3} devices  {:<44} inter {:>5.0} GB/s",
                    c,
                    cl.n_devices(),
                    islands,
                    cl.inter_bw / 1e9
                );
            }
        }
        "methods" => {
            for m in MethodSpec::catalog_names() {
                println!("{m}");
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        unknown => {
            eprintln!("unknown command {unknown:?}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
