//! The profiling database behind the `Calibrated` cost-model backend
//! (paper §V: cost estimation "takes advantages from both sides" —
//! profiling for computation, simulation for communication).
//!
//! A [`ProfileDb`] holds two kinds of measured samples:
//!
//!   * **layer samples** — per-(hidden, seq) forward wallclock from the
//!     PJRT layer profiles, reduced to an *effective* FLOP rate. The
//!     calibrated backend turns each sample into a compute-efficiency
//!     ratio `effective_flops / ref_flops` against the nominal device
//!     rate, interpolates it over `hidden` inside the covered range, and
//!     falls back to the analytic roofline (ratio 1.0) outside coverage;
//!   * **collective samples** — (wire bytes → seconds) points from an
//!     in-process collectives micro-benchmark
//!     ([`crate::coordinator::collectives`]), fitted by least squares to
//!     the alpha-beta link model `t = alpha + bytes / beta`. Planning
//!     applies the fit *relative* to the topology
//!     ([`crate::cluster::LinkModel`]: latency `alpha` + bandwidth
//!     efficiency `beta / ref_bw`), so multi-island bandwidth hierarchies
//!     survive calibration.
//!
//! `galvatron calibrate` writes a DB from real measurements;
//! `galvatron calibrate --synthetic` derives one deterministically from
//! the analytic model (`alpha = 0`, efficiency 1.0, exact zoo shape
//! coverage) — by construction that DB reproduces analytic plans
//! bit-for-bit, which is what pins the backend seam in CI. The on-disk
//! format is canonical pretty JSON ([`Json::to_pretty`]); the compact
//! serialization defines the content hash recorded as plan provenance.

use std::path::Path;

use crate::cluster::{ClusterSpec, LinkModel};
use crate::util::json::Json;
use crate::util::MIB;

/// Profile database format version (bump on breaking schema changes).
pub const PROFILE_DB_VERSION: usize = 1;

/// One profiled layer shape: measured forward wallclock on the
/// calibration host, reduced to an effective FLOP rate.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSample {
    pub hidden: usize,
    pub seq: usize,
    /// Samples per measured forward.
    pub batch: usize,
    /// Analytic forward FLOPs per sample of this shape.
    pub flops_fwd: f64,
    /// Measured seconds per sample.
    pub seconds_per_sample: f64,
    /// Achieved FLOP rate (`flops_fwd / seconds_per_sample`).
    pub effective_flops: f64,
}

/// One measured collective: ring wire bytes per device → seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveSample {
    /// "all_reduce" | "all_gather" | "reduce_scatter".
    pub kind: String,
    /// Wire bytes per participating device (ring-normalized).
    pub bytes: f64,
    pub seconds: f64,
}

/// Why a profile DB could not be loaded or used. `Malformed` covers
/// unreadable/ill-typed/out-of-range data; `Coverage` covers structurally
/// valid DBs that lack the samples the calibrated backend needs.
#[derive(Debug, Clone, PartialEq)]
pub enum ProfileDbError {
    Malformed { reason: String },
    Coverage { reason: String },
}

impl std::fmt::Display for ProfileDbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProfileDbError::Malformed { reason } => write!(f, "malformed profile db: {reason}"),
            ProfileDbError::Coverage { reason } => {
                write!(f, "insufficient profile db coverage: {reason}")
            }
        }
    }
}

impl std::error::Error for ProfileDbError {}

/// A calibration database: layer compute samples + fitted link model.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileDb {
    /// Where the samples came from ("pjrt-cpu", "synthetic:titan8", ...).
    pub source: String,
    /// Nominal FLOP rate the layer efficiencies are measured against.
    pub ref_flops: f64,
    /// Nominal bandwidth of the measured link (the beta reference).
    pub ref_bw: f64,
    /// Fitted per-collective latency, seconds.
    pub alpha: f64,
    /// Fitted effective bandwidth, bytes/s.
    pub beta: f64,
    pub layers: Vec<LayerSample>,
    pub collectives: Vec<CollectiveSample>,
}

impl ProfileDb {
    /// Deterministic DB derived from the analytic model of `cluster`:
    /// every distinct (hidden, seq) shape of the Table I zoo at exactly
    /// the nominal FLOP rate, and collective points exactly on the
    /// `bytes / intra_bw` line (`alpha = 0`, `beta = ref_bw`). Planning
    /// with this DB reproduces analytic plans bit-for-bit.
    pub fn synthetic(cluster: &ClusterSpec) -> ProfileDb {
        let ref_flops = cluster.gpu().flops;
        let ref_bw = cluster.intra_bw();
        let mut layers: Vec<LayerSample> = Vec::new();
        for name in crate::model::model_names() {
            let Some(m) = crate::model::model_by_name(name) else { continue };
            for l in &m.layers {
                if !layers.iter().any(|s| s.hidden == l.hidden && s.seq == l.seq) {
                    layers.push(LayerSample {
                        hidden: l.hidden,
                        seq: l.seq,
                        batch: 1,
                        flops_fwd: l.flops_fwd,
                        seconds_per_sample: l.flops_fwd / ref_flops,
                        effective_flops: ref_flops,
                    });
                }
            }
        }
        layers.sort_by_key(|s| (s.hidden, s.seq));
        let sizes = [1.0 * MIB, 4.0 * MIB, 16.0 * MIB, 64.0 * MIB];
        let collectives = ["all_reduce", "all_gather", "reduce_scatter"]
            .iter()
            .flat_map(|kind| {
                sizes.iter().map(move |&bytes| CollectiveSample {
                    kind: kind.to_string(),
                    bytes,
                    seconds: bytes / ref_bw,
                })
            })
            .collect();
        ProfileDb {
            source: format!("synthetic:{}", cluster.name),
            ref_flops,
            ref_bw,
            alpha: 0.0,
            beta: ref_bw,
            layers,
            collectives,
        }
    }

    /// Build a DB from real measurements, fitting the alpha-beta link
    /// model from the collective points.
    pub fn from_measurements(
        source: &str,
        ref_flops: f64,
        ref_bw: f64,
        layers: Vec<LayerSample>,
        collectives: Vec<CollectiveSample>,
    ) -> Result<ProfileDb, ProfileDbError> {
        let points: Vec<(f64, f64)> = collectives.iter().map(|c| (c.bytes, c.seconds)).collect();
        let (alpha, beta) = fit_alpha_beta(&points).ok_or_else(|| ProfileDbError::Coverage {
            reason: "need at least two collective samples of distinct sizes (with positive \
                     slope) to fit the alpha-beta link model"
                .into(),
        })?;
        let db = ProfileDb {
            source: source.to_string(),
            ref_flops,
            ref_bw,
            alpha,
            beta,
            layers,
            collectives,
        };
        db.validate()?;
        Ok(db)
    }

    /// Structural + coverage validation (run on every load).
    pub fn validate(&self) -> Result<(), ProfileDbError> {
        let bad = |reason: String| ProfileDbError::Malformed { reason };
        let pos = |name: &str, v: f64| -> Result<(), ProfileDbError> {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(bad(format!("{name} must be a positive finite number, got {v}")))
            }
        };
        pos("ref_flops", self.ref_flops)?;
        pos("ref_bw", self.ref_bw)?;
        pos("beta", self.beta)?;
        if !(self.alpha.is_finite() && self.alpha >= 0.0) {
            return Err(bad(format!("alpha must be finite and >= 0, got {}", self.alpha)));
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.hidden == 0 || l.seq == 0 || l.batch == 0 {
                return Err(bad(format!("layer sample {i}: hidden/seq/batch must be >= 1")));
            }
            pos(&format!("layer sample {i}: flops_fwd"), l.flops_fwd)?;
            pos(&format!("layer sample {i}: seconds_per_sample"), l.seconds_per_sample)?;
            pos(&format!("layer sample {i}: effective_flops"), l.effective_flops)?;
        }
        for (i, c) in self.collectives.iter().enumerate() {
            pos(&format!("collective sample {i}: bytes"), c.bytes)?;
            pos(&format!("collective sample {i}: seconds"), c.seconds)?;
        }
        // Coverage: the calibrated backend needs at least one compute
        // sample and a fittable link model.
        if self.layers.is_empty() {
            return Err(ProfileDbError::Coverage {
                reason: "no layer samples (the calibrated compute model has nothing to \
                         interpolate; run `galvatron calibrate`)"
                    .into(),
            });
        }
        let mut sizes: Vec<u64> = self.collectives.iter().map(|c| c.bytes.to_bits()).collect();
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.len() < 2 {
            return Err(ProfileDbError::Coverage {
                reason: format!(
                    "need collective samples at >= 2 distinct sizes to pin the alpha-beta \
                     link model, got {}",
                    sizes.len()
                ),
            });
        }
        Ok(())
    }

    /// The fitted link model, expressed relative to the measured link's
    /// nominal bandwidth (see [`LinkModel`]).
    pub fn link_model(&self) -> LinkModel {
        LinkModel { alpha: self.alpha, efficiency: self.beta / self.ref_bw }
    }

    /// Compute-efficiency ratio for a (hidden, seq) layer shape: exact
    /// sample match, else linear interpolation over `hidden` inside the
    /// covered range (per hidden, the sample with the closest seq is
    /// used), else `None` — outside coverage the caller falls back to the
    /// analytic roofline.
    pub fn efficiency_for(&self, hidden: usize, seq: usize) -> Option<f64> {
        let mut by_hidden: Vec<&LayerSample> = Vec::new();
        for s in &self.layers {
            if s.hidden == hidden && s.seq == seq {
                return Some(s.effective_flops / self.ref_flops);
            }
            match by_hidden.iter_mut().find(|b| b.hidden == s.hidden) {
                Some(best) => {
                    if (s.seq.abs_diff(seq), s.seq) < (best.seq.abs_diff(seq), best.seq) {
                        *best = s;
                    }
                }
                None => by_hidden.push(s),
            }
        }
        let lo = by_hidden.iter().filter(|s| s.hidden <= hidden).max_by_key(|s| s.hidden)?;
        let hi = by_hidden.iter().filter(|s| s.hidden >= hidden).min_by_key(|s| s.hidden)?;
        let e0 = lo.effective_flops / self.ref_flops;
        let e1 = hi.effective_flops / self.ref_flops;
        if lo.hidden == hi.hidden {
            Some(e0)
        } else {
            let t = (hidden - lo.hidden) as f64 / (hi.hidden - lo.hidden) as f64;
            Some(e0 + (e1 - e0) * t)
        }
    }

    // ---- JSON (de)serialization -----------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(PROFILE_DB_VERSION as f64)),
            ("source", Json::str(&self.source)),
            ("ref_flops", Json::num(self.ref_flops)),
            ("ref_bw", Json::num(self.ref_bw)),
            ("alpha", Json::num(self.alpha)),
            ("beta", Json::num(self.beta)),
            (
                "layers",
                Json::arr(self.layers.iter().map(|l| {
                    Json::obj(vec![
                        ("hidden", Json::num(l.hidden as f64)),
                        ("seq", Json::num(l.seq as f64)),
                        ("batch", Json::num(l.batch as f64)),
                        ("flops_fwd", Json::num(l.flops_fwd)),
                        ("seconds_per_sample", Json::num(l.seconds_per_sample)),
                        ("effective_flops", Json::num(l.effective_flops)),
                    ])
                })),
            ),
            (
                "collectives",
                Json::arr(self.collectives.iter().map(|c| {
                    Json::obj(vec![
                        ("kind", Json::str(&c.kind)),
                        ("bytes", Json::num(c.bytes)),
                        ("seconds", Json::num(c.seconds)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ProfileDb, ProfileDbError> {
        let bad = |reason: String| ProfileDbError::Malformed { reason };
        check_keys(
            v,
            &["version", "source", "ref_flops", "ref_bw", "alpha", "beta", "layers", "collectives"],
            "profile db",
        )?;
        let version = v
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad("missing or invalid version".into()))?;
        if version != PROFILE_DB_VERSION {
            return Err(bad(format!(
                "unsupported profile db version {version} (supported: {PROFILE_DB_VERSION})"
            )));
        }
        let getf = |key: &str| -> Result<f64, ProfileDbError> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| bad(format!("missing or invalid {key}")))
        };
        let source = v
            .get("source")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing or invalid source".into()))?
            .to_string();
        let mut layers = Vec::new();
        for (i, lv) in v
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing or invalid layers array".into()))?
            .iter()
            .enumerate()
        {
            check_keys(
                lv,
                &["hidden", "seq", "batch", "flops_fwd", "seconds_per_sample", "effective_flops"],
                &format!("layer sample {i}"),
            )?;
            let u = |key: &str| -> Result<usize, ProfileDbError> {
                lv.get(key)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| bad(format!("layer sample {i}: missing or invalid {key}")))
            };
            let f = |key: &str| -> Result<f64, ProfileDbError> {
                lv.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad(format!("layer sample {i}: missing or invalid {key}")))
            };
            layers.push(LayerSample {
                hidden: u("hidden")?,
                seq: u("seq")?,
                batch: u("batch")?,
                flops_fwd: f("flops_fwd")?,
                seconds_per_sample: f("seconds_per_sample")?,
                effective_flops: f("effective_flops")?,
            });
        }
        let mut collectives = Vec::new();
        for (i, cv) in v
            .get("collectives")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("missing or invalid collectives array".into()))?
            .iter()
            .enumerate()
        {
            check_keys(cv, &["kind", "bytes", "seconds"], &format!("collective sample {i}"))?;
            let f = |key: &str| -> Result<f64, ProfileDbError> {
                cv.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| bad(format!("collective sample {i}: missing or invalid {key}")))
            };
            collectives.push(CollectiveSample {
                kind: cv
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad(format!("collective sample {i}: missing or invalid kind")))?
                    .to_string(),
                bytes: f("bytes")?,
                seconds: f("seconds")?,
            });
        }
        let db = ProfileDb {
            source,
            ref_flops: getf("ref_flops")?,
            ref_bw: getf("ref_bw")?,
            alpha: getf("alpha")?,
            beta: getf("beta")?,
            layers,
            collectives,
        };
        db.validate()?;
        Ok(db)
    }

    /// Canonical on-disk form (2-space pretty JSON, sorted keys, trailing
    /// newline — the [`Json::to_pretty`] format, byte-reproducible).
    pub fn to_pretty_string(&self) -> String {
        self.to_json().to_pretty()
    }

    pub fn save(&self, path: &Path) -> Result<(), ProfileDbError> {
        std::fs::write(path, self.to_pretty_string()).map_err(|e| ProfileDbError::Malformed {
            reason: format!("writing {}: {e}", path.display()),
        })
    }

    pub fn load(path: &Path) -> Result<ProfileDb, ProfileDbError> {
        let text = std::fs::read_to_string(path).map_err(|e| ProfileDbError::Malformed {
            reason: format!("reading {}: {e}", path.display()),
        })?;
        let v = Json::parse(&text).map_err(|e| ProfileDbError::Malformed {
            reason: format!("{}: {e}", path.display()),
        })?;
        Self::from_json(&v)
    }

    /// Content fingerprint (FNV-1a over the compact JSON serialization):
    /// stable across save/load round trips, used as the memoization
    /// provenance key and — in hex — as the `db_hash` a plan artifact
    /// records.
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.to_json().to_string().as_bytes())
    }

    pub fn content_hash_hex(&self) -> String {
        format!("{:016x}", self.content_hash())
    }
}

/// Strict-key validation ([`crate::util::json::check_object_keys`])
/// surfaced as a malformed-DB error.
fn check_keys(v: &Json, allowed: &[&str], ctx: &str) -> Result<(), ProfileDbError> {
    crate::util::json::check_object_keys(v, allowed, ctx)
        .map_err(|reason| ProfileDbError::Malformed { reason })
}

/// FNV-1a 64-bit hash (deterministic across platforms/runs, unlike the
/// std hasher).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Least-squares fit of `seconds = alpha + bytes / beta` over (bytes,
/// seconds) points. Returns `(alpha, beta)` with alpha clamped to >= 0;
/// `None` when fewer than two distinct sizes exist or the slope is not
/// positive (no meaningful bandwidth).
pub fn fit_alpha_beta(points: &[(f64, f64)]) -> Option<(f64, f64)> {
    if points.len() < 2 {
        return None;
    }
    let n = points.len() as f64;
    let mx = points.iter().map(|p| p.0).sum::<f64>() / n;
    let my = points.iter().map(|p| p.1).sum::<f64>() / n;
    let var: f64 = points.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    let cov: f64 = points.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    if var <= 0.0 {
        return None;
    }
    let slope = cov / var;
    if !(slope.is_finite() && slope > 0.0) {
        return None;
    }
    Some(((my - slope * mx).max(0.0), 1.0 / slope))
}

/// In-process collectives micro-benchmark: time ring-semantics
/// all-reduce / all-gather / reduce-scatter over host buffers and report
/// (ring wire bytes per device → seconds) points for the alpha-beta fit.
/// Wallclock-derived — use [`ProfileDb::synthetic`] where determinism
/// matters (CI).
pub fn measure_collectives(reps: usize) -> Vec<CollectiveSample> {
    use crate::coordinator::collectives::{all_gather, all_reduce, reduce_scatter};
    use crate::parallel::comm::{allgather_bytes, allreduce_bytes};
    use std::time::Instant;

    let n = 4usize;
    let reps = reps.max(1);
    let mut rng = crate::util::rng::Rng::new(0xCA11B);
    let mut out = Vec::new();
    for shift in [14usize, 16, 18, 20] {
        let len = (1usize << shift) / n * n;
        let base: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32 * 0.1).collect())
            .collect();
        let full_bytes = len as f64 * 4.0;

        // Time only the collective: the per-rep buffer reset (all_reduce
        // mutates in place) stays outside the clock so it cannot bias the
        // alpha-beta fit against the copy-free collectives below.
        let mut elapsed = std::time::Duration::ZERO;
        for _ in 0..reps {
            let mut bufs = base.clone();
            let mut refs: Vec<&mut [f32]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            let t0 = Instant::now();
            all_reduce(&mut refs);
            elapsed += t0.elapsed();
        }
        out.push(CollectiveSample {
            kind: "all_reduce".into(),
            bytes: allreduce_bytes(n, full_bytes),
            seconds: elapsed.as_secs_f64() / reps as f64,
        });

        let shards: Vec<&[f32]> = base.iter().map(|b| &b[..len / n]).collect();
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = all_gather(&shards);
        }
        out.push(CollectiveSample {
            kind: "all_gather".into(),
            bytes: allgather_bytes(n, full_bytes),
            seconds: t0.elapsed().as_secs_f64() / reps as f64,
        });

        let full: Vec<&[f32]> = base.iter().map(|b| b.as_slice()).collect();
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = reduce_scatter(&full);
        }
        out.push(CollectiveSample {
            kind: "reduce_scatter".into(),
            bytes: allgather_bytes(n, full_bytes),
            seconds: t0.elapsed().as_secs_f64() / reps as f64,
        });
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;

    #[test]
    fn synthetic_db_is_exact_analytic() {
        let c = cluster_by_name("titan8").unwrap();
        let db = ProfileDb::synthetic(&c);
        db.validate().unwrap();
        // Every zoo shape is covered at exactly ratio 1.0.
        for s in &db.layers {
            assert_eq!(db.efficiency_for(s.hidden, s.seq), Some(1.0));
        }
        // The link model is the ideal one.
        assert_eq!(db.link_model(), LinkModel::ideal());
        assert!(db.layers.len() > 3);
        assert_eq!(db.collectives.len(), 12);
    }

    #[test]
    fn efficiency_interpolates_and_falls_back() {
        let mk = |hidden: usize, seq: usize, eff: f64| LayerSample {
            hidden,
            seq,
            batch: 1,
            flops_fwd: 1e9,
            seconds_per_sample: 1e9 / eff,
            effective_flops: eff,
        };
        let db = ProfileDb {
            source: "test".into(),
            ref_flops: 10.0,
            ref_bw: 1e9,
            alpha: 0.0,
            beta: 1e9,
            layers: vec![mk(1000, 512, 5.0), mk(2000, 512, 10.0), mk(2000, 128, 20.0)],
            collectives: vec![],
        };
        // Exact (hidden, seq) hit.
        assert_eq!(db.efficiency_for(1000, 512), Some(0.5));
        assert_eq!(db.efficiency_for(2000, 128), Some(2.0));
        // Exact hidden, nearest seq (ties -> smaller seq).
        assert_eq!(db.efficiency_for(2000, 100), Some(2.0));
        assert_eq!(db.efficiency_for(2000, 600), Some(1.0));
        // Interpolation over hidden, per-hidden nearest seq: midway between
        // eff 0.5 (h=1000) and eff 1.0 (h=2000@512).
        assert_eq!(db.efficiency_for(1500, 512), Some(0.75));
        // Outside coverage: analytic fallback.
        assert_eq!(db.efficiency_for(100, 512), None);
        assert_eq!(db.efficiency_for(4096, 512), None);
    }

    #[test]
    fn alpha_beta_fit_recovers_exact_lines() {
        // Points exactly on t = 2e-5 + bytes / 1e9.
        let pts: Vec<(f64, f64)> = [1e6, 4e6, 16e6]
            .iter()
            .map(|&b| (b, 2e-5 + b / 1e9))
            .collect();
        let (alpha, beta) = fit_alpha_beta(&pts).unwrap();
        assert!((alpha - 2e-5).abs() < 1e-12, "{alpha}");
        assert!((beta - 1e9).abs() / 1e9 < 1e-9, "{beta}");
        // Negative intercepts clamp to zero.
        let pts: Vec<(f64, f64)> = [1e6, 4e6].iter().map(|&b| (b, b / 1e9 - 1e-6)).collect();
        let (alpha, _) = fit_alpha_beta(&pts).unwrap();
        assert_eq!(alpha, 0.0);
        // Degenerate inputs refuse to fit.
        assert!(fit_alpha_beta(&[(1e6, 1.0)]).is_none());
        assert!(fit_alpha_beta(&[(1e6, 1.0), (1e6, 2.0)]).is_none());
        assert!(fit_alpha_beta(&[(1e6, 2.0), (2e6, 1.0)]).is_none()); // negative slope
    }

    #[test]
    fn json_round_trip_and_stable_hash() {
        let db = ProfileDb::synthetic(&cluster_by_name("hetero4").unwrap());
        let text = db.to_pretty_string();
        let back = ProfileDb::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, db);
        assert_eq!(back.content_hash(), db.content_hash());
        // Distinct sources hash differently.
        let other = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        assert_ne!(other.content_hash(), db.content_hash());
        assert_eq!(db.content_hash_hex().len(), 16);
    }

    #[test]
    fn malformed_and_coverage_errors_are_typed() {
        // Unknown key.
        let v = Json::parse(r#"{"version":1,"bogus":2}"#).unwrap();
        assert!(matches!(
            ProfileDb::from_json(&v),
            Err(ProfileDbError::Malformed { .. })
        ));
        // Empty layer table is a coverage error.
        let mut db = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        db.layers.clear();
        assert!(matches!(db.validate(), Err(ProfileDbError::Coverage { .. })));
        // One collective size cannot pin the fit.
        let mut db = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        db.collectives.truncate(1);
        assert!(matches!(db.validate(), Err(ProfileDbError::Coverage { .. })));
        // Nonpositive rates are malformed, not coverage.
        let mut db = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        db.beta = 0.0;
        assert!(matches!(db.validate(), Err(ProfileDbError::Malformed { .. })));
    }

    #[test]
    fn measured_collectives_fit() {
        let samples = measure_collectives(1);
        assert_eq!(samples.len(), 12);
        assert!(samples.iter().all(|s| s.bytes > 0.0 && s.seconds > 0.0));
        // The measured points are fittable (alpha-beta may be noisy but
        // must exist: sizes span a 64x range).
        let pts: Vec<(f64, f64)> = samples.iter().map(|s| (s.bytes, s.seconds)).collect();
        assert!(fit_alpha_beta(&pts).is_some());
    }
}
