//! Pluggable cost-model backends: where per-layer compute rates and link
//! times come from.
//!
//! The planner historically had one hardwired cost theory — closed-form
//! FLOP rooflines and `bytes / bw` ring divisions. [`CostModel`] makes the
//! provenance a first-class, swappable backend:
//!
//!   * [`CostModel::Analytic`] — the original formulas, unchanged. The
//!     default everywhere; plans and artifacts are byte-identical to the
//!     pre-backend planner.
//!   * [`CostModel::Calibrated`] — a loaded [`ProfileDb`] of measured
//!     samples: compute times scale by the profiled per-(hidden, seq)
//!     efficiency (interpolated inside coverage, analytic outside it) and
//!     link times follow the fitted alpha-beta model
//!     (`alpha + bytes / beta`; `alpha = 0` at full efficiency reproduces
//!     the analytic division exactly).
//!
//! Every consumer of costs — [`super::CostEstimator`], the search
//! engine's memoized [`crate::search::engine::CostCache`] (whose keys
//! carry [`CostModel::cache_fingerprint`] so entries never mix backends),
//! [`super::pipeline::plan_cost_full`], and the simulator — takes the
//! backend explicitly; [`CostModel::provenance`] is what a
//! [`crate::api::PlanReport`] records so artifacts know which cost theory
//! produced them.

use std::sync::Arc;

use crate::cluster::LinkModel;
use crate::util::json::Json;

use super::calibration::ProfileDb;

/// The source of compute rates and link times for cost estimation.
#[derive(Debug, Clone, Default)]
pub enum CostModel {
    /// Closed-form FLOP roofline + pure `bytes / bw` divisions (the
    /// original cost theory; the default).
    #[default]
    Analytic,
    /// Profiled compute efficiencies + fitted alpha-beta links from a
    /// [`ProfileDb`] (shared — cloning a calibrated model is cheap).
    Calibrated(Arc<ProfileDb>),
}

impl CostModel {
    /// Wrap a loaded database as a calibrated backend.
    pub fn calibrated(db: ProfileDb) -> CostModel {
        CostModel::Calibrated(Arc::new(db))
    }

    pub fn is_analytic(&self) -> bool {
        matches!(self, CostModel::Analytic)
    }

    /// Stable backend name ("analytic" / "calibrated").
    pub fn backend_name(&self) -> &'static str {
        match self {
            CostModel::Analytic => "analytic",
            CostModel::Calibrated(_) => "calibrated",
        }
    }

    /// Provenance to record into plan artifacts; `None` for the default
    /// analytic backend so existing artifacts stay byte-identical.
    pub fn provenance(&self) -> Option<CostProvenance> {
        match self {
            CostModel::Analytic => None,
            CostModel::Calibrated(db) => Some(CostProvenance {
                backend: self.backend_name().to_string(),
                db_hash: db.content_hash_hex(),
            }),
        }
    }

    /// Fingerprint folded into memoized cost-cache keys so entries from
    /// different backends can never be confused (0 = analytic).
    pub fn cache_fingerprint(&self) -> u64 {
        match self {
            CostModel::Analytic => 0,
            CostModel::Calibrated(db) => db.content_hash(),
        }
    }

    /// Compute-rate efficiency for a (hidden, seq) layer shape — the
    /// factor the nominal device FLOP rate is scaled by. Exactly 1.0 for
    /// the analytic backend and outside a calibrated DB's coverage.
    pub fn compute_efficiency(&self, hidden: usize, seq: usize) -> f64 {
        match self {
            CostModel::Analytic => 1.0,
            CostModel::Calibrated(db) => db.efficiency_for(hidden, seq).unwrap_or(1.0),
        }
    }

    /// The link time model (ideal for analytic).
    pub fn link(&self) -> LinkModel {
        match self {
            CostModel::Analytic => LinkModel::ideal(),
            CostModel::Calibrated(db) => db.link_model(),
        }
    }
}

/// Which cost model produced a plan — recorded into [`crate::api::PlanReport`]
/// artifacts (only when non-default) so `simulate --plan` can warn when a
/// plan is re-evaluated under a different cost theory.
#[derive(Debug, Clone, PartialEq)]
pub struct CostProvenance {
    /// Backend name ("calibrated").
    pub backend: String,
    /// Content hash of the profile DB ([`ProfileDb::content_hash_hex`]).
    pub db_hash: String,
}

impl CostProvenance {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::str(&self.backend)),
            ("db_hash", Json::str(&self.db_hash)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<CostProvenance> {
        Some(CostProvenance {
            backend: v.get("backend")?.as_str()?.to_string(),
            db_hash: v.get("db_hash")?.as_str()?.to_string(),
        })
    }

    /// Short display form, e.g. "calibrated (db 1a2b3c4d5e6f7081)".
    pub fn label(&self) -> String {
        format!("{} (db {})", self.backend, self.db_hash)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;

    #[test]
    fn analytic_is_the_silent_default() {
        let m = CostModel::default();
        assert!(m.is_analytic());
        assert_eq!(m.provenance(), None);
        assert_eq!(m.cache_fingerprint(), 0);
        assert_eq!(m.compute_efficiency(1280, 512), 1.0);
        assert_eq!(m.link(), LinkModel::ideal());
    }

    #[test]
    fn calibrated_carries_provenance_and_fingerprint() {
        let db = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        let hash = db.content_hash();
        let m = CostModel::calibrated(db);
        let p = m.provenance().unwrap();
        assert_eq!(p.backend, "calibrated");
        assert_eq!(p.db_hash, format!("{hash:016x}"));
        assert_eq!(m.cache_fingerprint(), hash);
        assert!(p.label().contains("calibrated"));
        // Provenance JSON round-trips.
        let v = Json::parse(&p.to_json().to_string()).unwrap();
        assert_eq!(CostProvenance::from_json(&v), Some(p));
    }
}
