//! Per-layer cost estimation: c(l, s), O_f, O_b, O_ms of the paper's DP
//! search, plus the transformation cost R.

use crate::cluster::{ClusterSpec, StageSite};
use crate::model::{LayerProfile, TrainConfig};
use crate::parallel::comm::{ckpt_recompute_comm, layer_comm_volumes_with};
use crate::parallel::memory::{layer_memory_with, LayerMemory};
use crate::parallel::{transform, Dim, Strategy};

use super::model::CostModel;
use super::overlapped_time;

/// Full cost of one layer under one strategy for one microbatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// Forward time (compute + blocking TP collectives + overlapped SDP
    /// parameter gather), seconds.
    pub fwd: f64,
    /// Backward time without gradient synchronization (microbatches 1..m-1).
    pub bwd: f64,
    /// Backward time of the last microbatch (DP gradient all-reduce
    /// overlaps backward compute).
    pub bwd_sync: f64,
    /// Memory footprint.
    pub mem: LayerMemory,
}

impl LayerCost {
    /// Total per-microbatch time (no grad sync).
    pub fn step(&self) -> f64 {
        self.fwd + self.bwd
    }
}

/// Source of per-layer and transform costs for the stage-level DP kernel
/// ([`crate::search::dp`]). `layer_idx` is the model-global layer index
/// (stage offset + local index): a direct [`CostEstimator`] ignores it, the
/// engine's memoized [`crate::search::engine::CostCache`] keys on it.
///
/// Method names carry the `_at` suffix so they never shadow (or get
/// shadowed by) the inherent `CostEstimator` methods of the same shape.
pub trait StageCosts: Sync {
    /// c(l, s) for the layer at model-global index `layer_idx`.
    fn layer_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost;

    /// R(l, S_prev, S_cur) where `layer_idx` indexes the *current* layer.
    fn transform_cost_at(
        &self,
        layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64;
}

impl StageCosts for CostEstimator {
    fn layer_cost_at(
        &self,
        _layer_idx: usize,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        self.layer_cost(layer, strategy, b_m, extra_params)
    }

    fn transform_cost_at(
        &self,
        _layer_idx: usize,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        self.transform_cost(layer, prev, cur, b_m)
    }
}

/// Estimator bound to a model's placement context: cluster + PP degree +
/// the island [`StageSite`] the priced stage runs on. `new` binds the
/// cluster's floor site (identical to every slot on a homogeneous
/// cluster); `for_slot`/`with_site` bind a specific pipeline slot of a
/// heterogeneous cluster, so stage time scales with that island's FLOP
/// rate and its intra-island bus.
#[derive(Debug, Clone)]
pub struct CostEstimator {
    pub cluster: ClusterSpec,
    /// Pipeline degree the stage strategies live under (affects which links
    /// intra-stage groups span).
    pub pp: usize,
    /// Compute/communication contention factor (§V).
    pub overlap_slowdown: f64,
    /// The island site this estimator prices (device FLOPs/memory + bus).
    pub site: StageSite,
    /// Training numerics (dtype/optimizer/ZeRO) for the memory accounting
    /// and the parameter-collective wire bytes. The default (fp32 + Adam,
    /// unsharded) reproduces the historical hardwired constants
    /// bit-for-bit; fp16/bf16 halves DP/SDP communication volume while
    /// compute and activation (TP) volumes stay fp32-calibrated (README).
    pub train: TrainConfig,
    /// Where compute rates and link times come from: the analytic
    /// formulas (default) or a calibrated [`crate::cost::ProfileDb`]
    /// backend. The analytic backend reproduces the pre-backend estimator
    /// bit-for-bit.
    pub cost_model: CostModel,
}

impl CostEstimator {
    pub fn new(cluster: &ClusterSpec, pp: usize, overlap_slowdown: f64) -> Self {
        let site = cluster.floor_site(pp);
        Self::with_site(cluster, pp, overlap_slowdown, site)
    }

    /// Estimator for pipeline slot `slot` of `cluster` at degree `pp`.
    pub fn for_slot(cluster: &ClusterSpec, pp: usize, overlap_slowdown: f64, slot: usize) -> Self {
        let site = cluster.stage_sites(pp)[slot].clone();
        Self::with_site(cluster, pp, overlap_slowdown, site)
    }

    /// Estimator bound to an explicit (precomputed) site.
    pub fn with_site(
        cluster: &ClusterSpec,
        pp: usize,
        overlap_slowdown: f64,
        site: StageSite,
    ) -> Self {
        CostEstimator {
            cluster: cluster.clone(),
            pp,
            overlap_slowdown,
            site,
            train: TrainConfig::default(),
            cost_model: CostModel::Analytic,
        }
    }

    /// Bind explicit training numerics (builder-style).
    pub fn with_train(mut self, train: TrainConfig) -> Self {
        self.train = train;
        self
    }

    /// Bind a cost-model backend (builder-style; default analytic).
    pub fn with_cost_model(mut self, cost_model: CostModel) -> Self {
        self.cost_model = cost_model;
        self
    }

    /// Memory budget of the priced stage's devices, bytes.
    pub fn mem_budget(&self) -> f64 {
        self.site.gpu.mem_bytes
    }

    /// Bandwidth of a `group`-wide collective inside the priced stage.
    fn group_bw(&self, group: usize) -> f64 {
        if group <= self.site.intra_limit {
            self.site.intra_bw
        } else {
            self.cluster.inter_bw
        }
    }

    /// Bandwidth seen by strategy level `i` of `strategy`: the level's
    /// communication group spans the product of its own and all inner
    /// degrees of contiguous devices (outer levels ride slower links).
    fn level_bw(&self, strategy: &Strategy, i: usize) -> f64 {
        let span: usize = strategy.levels[i..].iter().map(|(_, d)| d).product();
        self.group_bw(span)
    }

    fn dim_bw(&self, strategy: &Strategy, dim: Dim) -> f64 {
        strategy
            .levels
            .iter()
            .position(|(d, _)| *d == dim)
            .map(|i| self.level_bw(strategy, i))
            .unwrap_or(self.site.intra_bw)
    }

    /// c(l, s): the paper's per-layer cost under strategy `s` with
    /// microbatch size `b_m` and `extra_params` (embeddings/heads).
    ///
    /// Compute rides the device's nominal FLOP rate scaled by the cost
    /// model's profiled per-shape efficiency; every collective goes
    /// through the backend's [`crate::cluster::LinkModel`]. The analytic
    /// backend (efficiency 1.0, ideal link) reproduces the historical
    /// roofline + `bytes / bw` numbers bit-for-bit.
    pub fn layer_cost(
        &self,
        layer: &LayerProfile,
        strategy: &Strategy,
        b_m: f64,
        extra_params: f64,
    ) -> LayerCost {
        let local_samples = b_m / strategy.batch_split() as f64;
        let rate = self.site.gpu.flops
            * self.cost_model.compute_efficiency(layer.hidden, layer.seq);
        let comp_fwd = layer.flops_fwd * local_samples / strategy.tp() as f64 / rate;
        let comp_bwd = 2.0 * comp_fwd;

        let link = self.cost_model.link();
        let vols = layer_comm_volumes_with(layer, strategy, b_m, extra_params, &self.train);
        let tp_bw = self.dim_bw(strategy, Dim::Tp);
        let sdp_bw = self.dim_bw(strategy, Dim::Sdp);
        let dp_bw = self.dim_bw(strategy, Dim::Dp);

        // Forward: TP all-reduces are blocking (activations are inputs of
        // the next op); SDP parameter gather overlaps compute.
        let fwd = overlapped_time(
            comp_fwd + link.time(vols.tp_fwd, tp_bw),
            link.time(vols.sdp_fwd, sdp_bw),
            self.overlap_slowdown,
        );

        // Backward (no sync): compute (+ CKPT recompute) + blocking TP,
        // overlapped with SDP gather/reduce-scatter.
        let recompute = if strategy.ckpt {
            comp_fwd + link.time(ckpt_recompute_comm(&vols), tp_bw)
        } else {
            0.0
        };
        let bwd_blocking = comp_bwd + recompute + link.time(vols.tp_bwd, tp_bw);
        let bwd =
            overlapped_time(bwd_blocking, link.time(vols.sdp_bwd, sdp_bw), self.overlap_slowdown);

        // Last microbatch also carries the DP gradient all-reduce.
        let bwd_sync = overlapped_time(
            bwd_blocking,
            link.time(vols.sdp_bwd, sdp_bw) + link.time(vols.dp_grad, dp_bw),
            self.overlap_slowdown,
        );

        LayerCost {
            fwd,
            bwd,
            bwd_sync,
            mem: layer_memory_with(layer, strategy, b_m, extra_params, &self.train),
        }
    }

    /// Transformation cost R(l, S_prev, S_cur) in seconds (Eq. 4).
    pub fn transform_cost(
        &self,
        layer: &LayerProfile,
        prev: &Strategy,
        cur: &Strategy,
        b_m: f64,
    ) -> f64 {
        // Redistribution rides the stage group's slowest internal link.
        let group = cur.degree().max(prev.degree());
        let bw = self.group_bw(group.max(1));
        self.cost_model.link().time(transform::transform_bytes(layer, prev, cur, b_m), bw)
    }

    /// Pipeline p2p time to ship a stage-boundary activation (and its
    /// gradient on the way back) for one microbatch.
    pub fn p2p_time(&self, boundary: &LayerProfile, strategy: &Strategy, b_m: f64) -> f64 {
        let local = b_m / strategy.batch_split() as f64;
        self.cost_model
            .link()
            .time(boundary.bnd_bytes * local, self.cluster.pipeline_link_bw(self.pp))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;

    fn est(pp: usize) -> CostEstimator {
        CostEstimator::new(&cluster_by_name("titan8").unwrap(), pp, 1.3)
    }

    fn layer() -> LayerProfile {
        LayerProfile::encoder("enc", 1280, 512, 20)
    }

    #[test]
    fn serial_cost_is_pure_compute() {
        let e = est(1);
        let c = e.layer_cost(&layer(), &Strategy::serial(false), 8.0, 0.0);
        let expect = layer().flops_fwd * 8.0 / e.site.gpu.flops;
        assert!((c.fwd - expect).abs() / expect < 1e-9);
        assert!((c.bwd - 2.0 * expect).abs() / expect < 1e-9);
        assert_eq!(c.bwd, c.bwd_sync); // no DP -> no sync cost
    }

    #[test]
    fn bwd_twice_fwd_for_compute_bound() {
        let e = est(1);
        let c = e.layer_cost(&layer(), &Strategy::serial(false), 4.0, 0.0);
        assert!((c.bwd / c.fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ckpt_adds_forward_recompute() {
        let e = est(1);
        let plain = e.layer_cost(&layer(), &Strategy::serial(false), 4.0, 0.0);
        let ck = e.layer_cost(&layer(), &Strategy::serial(true), 4.0, 0.0);
        assert_eq!(plain.fwd, ck.fwd);
        assert!((ck.bwd - plain.bwd - plain.fwd).abs() < 1e-12);
    }

    #[test]
    fn dp_sync_slower_than_nosync() {
        let e = est(1);
        let c = e.layer_cost(&layer(), &Strategy::single(Dim::Dp, 8, false), 8.0, 0.0);
        assert!(c.bwd_sync > c.bwd);
    }

    #[test]
    fn tp_reduces_compute_adds_comm() {
        let e = est(1);
        let serial = e.layer_cost(&layer(), &Strategy::serial(false), 8.0, 0.0);
        let tp8 = e.layer_cost(&layer(), &Strategy::single(Dim::Tp, 8, false), 8.0, 0.0);
        // TP split compute by 8 but added all-reduce time.
        let comp_only = serial.fwd / 8.0;
        assert!(tp8.fwd > comp_only);
    }

    #[test]
    fn overlap_slowdown_increases_sync_cost() {
        let l = layer();
        let s = Strategy::single(Dim::Dp, 8, false);
        let no_slow = CostEstimator::new(&cluster_by_name("titan8").unwrap(), 1, 1.0);
        let slow = est(1);
        let a = no_slow.layer_cost(&l, &s, 8.0, 0.0);
        let b = slow.layer_cost(&l, &s, 8.0, 0.0);
        assert!(b.bwd_sync >= a.bwd_sync);
    }

    #[test]
    fn innermost_tp_gets_fast_link() {
        // On a two-island cluster with PP=1, a TP2 placed innermost spans 2
        // adjacent devices (NVLink); placed outermost it spans 16 (IB).
        let c = cluster_by_name("a100x16").unwrap();
        let e = CostEstimator::new(&c, 1, 1.3);
        let l = layer();
        let tp_inner = Strategy { levels: vec![(Dim::Dp, 8), (Dim::Tp, 2)], ckpt: false };
        let tp_outer = Strategy { levels: vec![(Dim::Tp, 2), (Dim::Dp, 8)], ckpt: false };
        let ci = e.layer_cost(&l, &tp_inner, 16.0, 0.0);
        let co = e.layer_cost(&l, &tp_outer, 16.0, 0.0);
        assert!(ci.fwd < co.fwd, "inner TP {} must beat outer TP {}", ci.fwd, co.fwd);
    }

    #[test]
    fn site_binding_scales_stage_time_and_budget() {
        // hetero4 at PP=2: slot 0 is the TITAN island (10 TFLOP/s, 24G),
        // slot 1 the A100-80G island (40 TFLOP/s, 80G).
        let c = cluster_by_name("hetero4").unwrap();
        let slow = CostEstimator::for_slot(&c, 2, 1.3, 0);
        let fast = CostEstimator::for_slot(&c, 2, 1.3, 1);
        let l = layer();
        let cs = slow.layer_cost(&l, &Strategy::serial(false), 4.0, 0.0);
        let cf = fast.layer_cost(&l, &Strategy::serial(false), 4.0, 0.0);
        assert!((cs.fwd / cf.fwd - 4.0).abs() < 1e-9, "{} vs {}", cs.fwd, cf.fwd);
        assert!(slow.mem_budget() < fast.mem_budget());
        // The floor estimator prices the slowest class.
        let floor = CostEstimator::new(&c, 2, 1.3);
        let cfl = floor.layer_cost(&l, &Strategy::serial(false), 4.0, 0.0);
        assert_eq!(cfl.fwd, cs.fwd);
    }

    #[test]
    fn train_config_shrinks_memory_and_param_comm() {
        use crate::model::{Dtype, TrainConfig};
        let e = est(1);
        let lean = est(1).with_train(TrainConfig {
            dtype: Dtype::Bf16,
            zero: true,
            ..Default::default()
        });
        let l = layer();
        let s = Strategy::single(Dim::Dp, 8, false);
        let c32 = e.layer_cost(&l, &s, 8.0, 0.0);
        let c16 = lean.layer_cost(&l, &s, 8.0, 0.0);
        // bf16 activations halve, ZeRO shards the optimizer state over DP8.
        assert!(c16.mem.o_f < 0.6 * c32.mem.o_f);
        assert!(c16.mem.o_ms < c32.mem.o_ms);
        // Compute and activation comm stay fp32-calibrated...
        assert_eq!(c16.fwd, c32.fwd);
        assert_eq!(c16.bwd, c32.bwd);
        // ...but the DP gradient all-reduce rides the wire in bf16, so the
        // syncing microbatch gets cheaper.
        assert!(c16.bwd_sync <= c32.bwd_sync);
    }

    #[test]
    fn analytic_backend_is_bitwise_default() {
        use crate::cost::CostModel;
        let e = est(2);
        let explicit = est(2).with_cost_model(CostModel::Analytic);
        let l = layer();
        for s in [
            Strategy::serial(true),
            Strategy::single(Dim::Dp, 4, false),
            Strategy { levels: vec![(Dim::Dp, 2), (Dim::Tp, 2)], ckpt: false },
        ] {
            let a = e.layer_cost(&l, &s, 8.0, 1e6);
            let b = explicit.layer_cost(&l, &s, 8.0, 1e6);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn calibrated_backend_scales_compute_and_links() {
        use crate::cluster::LinkModel;
        use crate::cost::{CostModel, ProfileDb};
        let l = layer();
        // A DB claiming the device achieves half its nominal FLOP rate on
        // this shape, over a link with latency and 50% efficiency.
        let mut db = ProfileDb::synthetic(&cluster_by_name("titan8").unwrap());
        let ref_flops = db.ref_flops;
        for s in &mut db.layers {
            s.effective_flops = ref_flops / 2.0;
        }
        db.alpha = 1e-4;
        db.beta = db.ref_bw / 2.0;
        assert_eq!(db.link_model(), LinkModel { alpha: 1e-4, efficiency: 0.5 });

        let analytic = est(1);
        let cal = est(1).with_cost_model(CostModel::calibrated(db));
        // Pure compute: exactly 2x slower at half the effective rate.
        let a = analytic.layer_cost(&l, &Strategy::serial(false), 8.0, 0.0);
        let c = cal.layer_cost(&l, &Strategy::serial(false), 8.0, 0.0);
        assert!((c.fwd / a.fwd - 2.0).abs() < 1e-9, "{} vs {}", c.fwd, a.fwd);
        // Memory accounting is backend-independent.
        assert_eq!(a.mem, c.mem);
        // Transform and p2p pay the fitted latency + derated bandwidth.
        let s1 = Strategy::single(Dim::Dp, 8, false);
        let s2 = Strategy::single(Dim::Tp, 8, false);
        let rt_a = analytic.transform_cost(&l, &s1, &s2, 8.0);
        let rt_c = cal.transform_cost(&l, &s1, &s2, 8.0);
        assert!(rt_c > 2.0 * rt_a, "{rt_c} vs {rt_a}");
        assert!(cal.p2p_time(&l, &s1, 8.0) > 2.0 * analytic.p2p_time(&l, &s1, 8.0));
        // Same-strategy transforms stay free: alpha is never charged for
        // communication that does not happen.
        assert_eq!(cal.transform_cost(&l, &s1, &s1, 8.0), 0.0);
    }

    #[test]
    fn transform_cost_zero_for_same() {
        let e = est(1);
        let s = Strategy::single(Dim::Dp, 4, false);
        assert_eq!(e.transform_cost(&layer(), &s, &s, 8.0), 0.0);
    }
}
