//! Whole-plan cost: pipeline composition (paper Eq. 5 / Eq. 9) + memory
//! feasibility under 1F1B-Flush or GPipe scheduling.

use crate::cluster::ClusterSpec;
use crate::model::{ModelProfile, TrainConfig};
use crate::parallel::memory::{stage_peak_memory, LayerMemory};
use crate::parallel::ParallelPlan;

use super::estimator::CostEstimator;
use super::model::CostModel;

/// Pipeline schedule flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// 1F1B-Flush (PipeDream-Flush): stage i keeps P-i microbatches live.
    OneFOneB,
    /// GPipe: all m microbatches live at the peak.
    GPipe,
}

impl Schedule {
    /// Live microbatches at peak for stage `i` (0-based) of `p` stages.
    pub fn live_microbatches(&self, i: usize, p: usize, m: usize) -> usize {
        match self {
            Schedule::OneFOneB => (p - i).min(m),
            Schedule::GPipe => m,
        }
    }
}

/// Cost summary for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageCost {
    /// Per-microbatch time, no gradient sync.
    pub time_nosync: f64,
    /// Per-microbatch time of the last microbatch (with DP grad sync).
    pub time_sync: f64,
    /// Peak memory bytes (given the schedule's live microbatch count).
    pub peak_mem: f64,
    /// Layer memory records (for diagnostics).
    pub mems: Vec<LayerMemory>,
}

/// Cost summary for an entire plan.
#[derive(Debug, Clone)]
pub struct PlanCost {
    /// End-to-end iteration time (seconds) per global batch, Eq. 9.
    pub iter_time: f64,
    /// Throughput, samples/second.
    pub throughput: f64,
    /// Whether every stage fits in the device memory budget.
    pub feasible: bool,
    pub stages: Vec<StageCost>,
    /// Time balance degree alpha_t (Eq. 6).
    pub alpha_t: f64,
    /// Memory balance degree alpha_m (Eq. 6).
    pub alpha_m: f64,
}

/// Estimate the full cost of `plan` for `model` on `cluster` (Eq. 5/9)
/// under the default training numerics (fp32 + Adam, no ZeRO).
pub fn plan_cost(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
) -> PlanCost {
    plan_cost_with(model, cluster, plan, schedule, overlap_slowdown, TrainConfig::default())
}

/// [`plan_cost`] under explicit training numerics: the per-layer memory
/// accounting (and thus per-stage peaks and feasibility) and the
/// parameter-collective wire bytes follow the dtype/optimizer/ZeRO
/// configuration. The default `train` reproduces [`plan_cost`]
/// bit-for-bit.
pub fn plan_cost_with(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
    train: TrainConfig,
) -> PlanCost {
    plan_cost_full(model, cluster, plan, schedule, overlap_slowdown, train, &CostModel::Analytic)
}

/// [`plan_cost_with`] under an explicit cost-model backend: compute rates
/// and link times come from `cost_model` (profiled efficiencies + fitted
/// alpha-beta links when calibrated). The analytic backend reproduces
/// [`plan_cost_with`] bit-for-bit.
pub fn plan_cost_full(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
    train: TrainConfig,
    cost_model: &CostModel,
) -> PlanCost {
    // Each stage is priced on its assigned island slot (identity placement
    // unless the plan carries a heterogeneous stage→slot map); on a
    // homogeneous cluster every slot shares site class 0 and this reduces
    // to the original single-estimator path. Estimators are built once per
    // distinct site class — plan_cost runs once per evaluated partition,
    // so per-stage construction would churn ClusterSpec clones on the
    // planner's hot path.
    let sites = cluster.stage_sites(plan.pp);
    let n_classes = sites.iter().map(|s| s.class).max().map(|c| c as usize + 1).unwrap_or(1);
    let ests: Vec<CostEstimator> = (0..n_classes)
        .map(|c| {
            let site = sites
                .iter()
                .find(|s| s.class == c as u32)
                .unwrap_or_else(|| unreachable!("contiguous site class ids"))
                .clone();
            CostEstimator::with_site(cluster, plan.pp, overlap_slowdown, site)
                .with_train(train)
                .with_cost_model(cost_model.clone())
        })
        .collect();
    let b_m = plan.microbatch_size();
    let m = plan.microbatches;
    let p = plan.pp;

    let mut stages = Vec::with_capacity(p);
    for s in 0..p {
        let est = &ests[sites[plan.slot_of(s)].class as usize];
        let range = plan.stage_layers(s);
        let mut time_nosync = 0.0;
        let mut time_sync = 0.0;
        let mut mems = Vec::new();
        let mut prev_strategy: Option<&crate::parallel::Strategy> = None;
        for li in range.clone() {
            let layer = &model.layers[li];
            let strat = &plan.strategies[li];
            let c = est.layer_cost(layer, strat, b_m, model.extra_params(li));
            time_nosync += c.fwd + c.bwd;
            time_sync += c.fwd + c.bwd_sync;
            if let Some(prev) = prev_strategy {
                let r = est.transform_cost(layer, prev, strat, b_m);
                time_nosync += r;
                time_sync += r;
            }
            mems.push(c.mem);
            prev_strategy = Some(strat);
        }
        // Stage-boundary p2p (attributed to the sending stage).
        if s + 1 < p {
            let boundary_layer = &model.layers[range.end - 1];
            let strat = &plan.strategies[range.end - 1];
            let t = est.p2p_time(boundary_layer, strat, b_m) * 2.0; // fwd + bwd
            time_nosync += t;
            time_sync += t;
        }
        let live = schedule.live_microbatches(s, p, m);
        let peak_mem = stage_peak_memory(&mems, live);
        stages.push(StageCost { time_nosync, time_sync, peak_mem, mems });
    }

    // Eq. 9: (m-1)·max_i C_nosync + Σ_i C_sync.
    let max_nosync = stages.iter().map(|s| s.time_nosync).fold(0.0, f64::max);
    let sum_sync: f64 = stages.iter().map(|s| s.time_sync).sum();
    let iter_time = (m as f64 - 1.0) * max_nosync + sum_sync;

    // Per-stage feasibility against the assigned island's capacity.
    let feasible = stages
        .iter()
        .enumerate()
        .all(|(s, st)| st.peak_mem <= sites[plan.slot_of(s)].gpu.mem_bytes);

    // Balance degrees (Eq. 6).
    let sum_nosync: f64 = stages.iter().map(|s| s.time_nosync).sum();
    let max_mem = stages.iter().map(|s| s.peak_mem).fold(0.0, f64::max);
    let sum_mem: f64 = stages.iter().map(|s| s.peak_mem).sum();
    let alpha_t = if sum_nosync > 0.0 { 1.0 - max_nosync / sum_nosync } else { 0.0 };
    let alpha_m = if sum_mem > 0.0 { 1.0 - max_mem / sum_mem } else { 0.0 };

    PlanCost {
        iter_time,
        throughput: if iter_time > 0.0 { plan.batch as f64 / iter_time } else { 0.0 },
        feasible,
        stages,
        alpha_t,
        alpha_m,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::model::model_by_name;
    use crate::parallel::{Dim, Strategy};

    fn uniform_plan(model: &ModelProfile, pp: usize, n_dev: usize, strat: Strategy, batch: usize, m: usize) -> ParallelPlan {
        let l = model.n_layers();
        let base = l / pp;
        let mut partition = vec![base; pp];
        let rem = l - base * pp;
        for i in 0..rem {
            partition[i] += 1;
        }
        let _ = n_dev;
        ParallelPlan {
            pp,
            partition,
            strategies: vec![strat; l],
            batch,
            microbatches: m,
            stage_slots: None,
        }
    }

    #[test]
    fn schedule_live_counts() {
        assert_eq!(Schedule::OneFOneB.live_microbatches(0, 4, 8), 4);
        assert_eq!(Schedule::OneFOneB.live_microbatches(3, 4, 8), 1);
        assert_eq!(Schedule::OneFOneB.live_microbatches(0, 4, 2), 2);
        assert_eq!(Schedule::GPipe.live_microbatches(0, 4, 8), 8);
        assert_eq!(Schedule::GPipe.live_microbatches(3, 4, 8), 8);
    }

    #[test]
    fn onefoneb_memory_imbalanced_by_depth() {
        // Paper §II-B: "1F1B-Flush causes distinct memory cost across
        // different PP stages, where shallower stages consume more memory."
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let plan = uniform_plan(&model, 4, 8, Strategy::single(Dim::Dp, 2, false), 16, 8);
        let pc = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        assert!(pc.stages[0].peak_mem > pc.stages[3].peak_mem);
    }

    #[test]
    fn gpipe_peak_exceeds_1f1b() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let plan = uniform_plan(&model, 4, 8, Strategy::single(Dim::Dp, 2, false), 32, 8);
        let g = plan_cost(&model, &cluster, &plan, Schedule::GPipe, 1.3);
        let f = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        assert!(g.stages[0].peak_mem >= f.stages[0].peak_mem);
        assert!(g.stages[3].peak_mem > f.stages[3].peak_mem);
        // Identical bubble math -> identical time.
        assert!((g.iter_time - f.iter_time).abs() < 1e-12);
    }

    #[test]
    fn eq9_structure() {
        // With pp=1, iter time = m-1 max + sum reduces to per-stage totals.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let plan = uniform_plan(&model, 1, 8, Strategy::single(Dim::Dp, 8, false), 8, 1);
        let pc = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        assert_eq!(pc.stages.len(), 1);
        assert!((pc.iter_time - pc.stages[0].time_sync).abs() < 1e-12);
        assert_eq!(pc.alpha_t, 0.0); // single stage: 1 - max/sum = 0
    }

    #[test]
    fn more_microbatches_reduce_bubble_share() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let p2 = uniform_plan(&model, 2, 8, Strategy::single(Dim::Dp, 4, false), 32, 2);
        let p8 = uniform_plan(&model, 2, 8, Strategy::single(Dim::Dp, 4, false), 32, 8);
        let c2 = plan_cost(&model, &cluster, &p2, Schedule::OneFOneB, 1.3);
        let c8 = plan_cost(&model, &cluster, &p8, Schedule::OneFOneB, 1.3);
        // Bubble fraction (P-1)/m shrinks with m; per-sample time improves
        // as long as per-microbatch efficiency doesn't collapse.
        assert!(c8.iter_time < c2.iter_time, "{} vs {}", c8.iter_time, c2.iter_time);
    }

    #[test]
    fn infeasible_when_budget_tiny() {
        let model = model_by_name("bert-huge-48").unwrap();
        let cluster = cluster_by_name("titan8")
            .unwrap()
            .with_memory_budget(1.0 * crate::util::GIB);
        let plan = uniform_plan(&model, 1, 8, Strategy::single(Dim::Dp, 8, false), 8, 1);
        let pc = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        assert!(!pc.feasible);
    }

    #[test]
    fn balance_degrees_bounds() {
        let model = model_by_name("t5-512/4-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let plan = uniform_plan(&model, 4, 8, Strategy::single(Dim::Dp, 2, true), 32, 8);
        let pc = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        let bound = 1.0 - 1.0 / 4.0;
        assert!(pc.alpha_t >= 0.0 && pc.alpha_t <= bound);
        assert!(pc.alpha_m >= 0.0 && pc.alpha_m <= bound);
    }
}
