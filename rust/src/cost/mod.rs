//! Cost estimator (paper §V): computation + communication + memory, with
//! the compute/communication *overlap slowdown* the paper highlights.
//!
//! Estimation structure follows the paper exactly:
//!   * compute time   = per-sample profiled/analytic time × local samples;
//!     backward = 2× forward (dense-matmul dominated);
//!   * communication  = ring-collective volume / link bandwidth, with the
//!     link chosen from the level's span in the topology (decision-tree
//!     order maps outer levels to slower links);
//!   * overlapped DP/SDP communication contends with backward compute:
//!     both slow down by `overlap_slowdown` (~1.3×, §V);
//!   * CKPT adds one forward recompute (+ its TP collectives) to backward;
//!   * pipeline cost follows Eq. 5 / Eq. 9 with the last-microbatch
//!     gradient-sync distinction.
//!
//! Cost *provenance* is a pluggable backend ([`model::CostModel`]): the
//! analytic formulas above are the default, and a calibrated backend
//! ([`calibration::ProfileDb`]) swaps in profiled compute efficiencies and
//! a fitted alpha-beta link model — the paper's "take advantages from both
//! sides" cost pipeline (profiling for computation, simulation for
//! communication).

pub mod calibration;
pub mod estimator;
pub mod model;
pub mod pipeline;

pub use calibration::{
    fit_alpha_beta, measure_collectives, CollectiveSample, LayerSample, ProfileDb, ProfileDbError,
    PROFILE_DB_VERSION,
};
pub use estimator::{CostEstimator, LayerCost, StageCosts};
pub use model::{CostModel, CostProvenance};
pub use pipeline::{plan_cost, plan_cost_full, plan_cost_with, PlanCost, StageCost};

/// Default GPU streaming-multiprocessor contention factor (paper §V: "such
/// contention could slow down the computation and communication by 1.3×").
pub const DEFAULT_OVERLAP_SLOWDOWN: f64 = 1.3;

/// Duration of a backward region where `comp` seconds of kernels overlap
/// `comm` seconds of NCCL-style collectives, with mutual slowdown.
///
/// Bounds: never faster than running alone, never slower than serialized.
pub fn overlapped_time(comp: f64, comm: f64, slowdown: f64) -> f64 {
    if comm <= 0.0 {
        return comp;
    }
    if comp <= 0.0 {
        return comm;
    }
    (comp.max(comm) * slowdown).clamp(comp.max(comm), comp + comm)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn overlap_bounds() {
        // No comm -> pure compute.
        assert_eq!(overlapped_time(2.0, 0.0, 1.3), 2.0);
        // No comp -> pure comm.
        assert_eq!(overlapped_time(0.0, 3.0, 1.3), 3.0);
        // Balanced: slowdown applies.
        assert!((overlapped_time(1.0, 1.0, 1.3) - 1.3).abs() < 1e-12);
        // Never worse than serialized.
        assert!(overlapped_time(1.0, 1.0, 5.0) <= 2.0);
        // Never better than the max alone.
        assert!(overlapped_time(1.0, 0.1, 1.0) >= 1.0);
    }

    #[test]
    fn slowdown_1_means_max() {
        assert_eq!(overlapped_time(2.0, 1.5, 1.0), 2.0);
    }
}
