//! Galvatron-BMW reproduction: automatic parallel Transformer training via
//! balanced memory workload optimization (TKDE 2023/2024).
//!
//! Library layout (see DESIGN.md):
//!   * [`api`]     — the public planning surface: [`api::PlanRequest`]
//!     builder, [`api::MethodSpec`] strategy catalog, [`api::Planner`],
//!     and serializable [`api::PlanReport`] artifacts.
//!   * [`model`]   — Transformer model profiles (Table I zoo).
//!   * [`cluster`] — device/island topology + bandwidth model.
//!   * [`parallel`]— DP/SDP/TP/PP/CKPT strategy representation, memory and
//!     collective-communication accounting.
//!   * [`cost`]    — the paper's cost estimator (§V), incl. overlap
//!     slowdown, behind pluggable [`cost::CostModel`] backends: the
//!     analytic formulas (default) or a calibrated
//!     [`cost::ProfileDb`] of profiled compute/collective samples.
//!   * [`search`]  — decision-tree search space (§III), dynamic-programming
//!     layer assignment + Galvatron-Base (§IV-A) and the BMW bi-objective
//!     workload balancer (§IV-B), plus all baselines — all driven by the
//!     parallel memoized [`search::engine`] (shared cost caches,
//!     thread-fanned batch × PP sweeps, deterministic reduction, and
//!     [`search::engine::SearchTrace`] artifacts).
//!   * [`check`]   — static analysis over planner artifacts: typed
//!     `GAL0xxx` diagnostics re-proving plan legality, artifact
//!     consistency and spec/cluster lints (`galvatron check`).
//!   * [`advise`]  — elastic capacity planning (`galvatron advise`):
//!     priced fleet sweeps, Pareto frontiers over
//!     (throughput, headroom, $/hr), and failure-aware replanning.
//!   * [`sim`]     — discrete-event cluster simulator (ground truth for
//!     Fig. 4/7-style experiments; substitutes the GPU testbed).
//!   * [`serve`]   — long-lived planning-as-a-service daemon (JSONL +
//!     HTTP/1.1 transports, in-flight request dedup, warm caches).
//!   * [`runtime`] — PJRT-CPU execution of AOT artifacts (HLO text).
//!   * [`coordinator`] — real-numerics distributed training driver
//!     (pipeline + data parallel + collectives) over the runtime.
//!   * [`util`]    — JSON/RNG/CLI/table/bench substrates.

pub mod advise;
pub mod api;
pub mod check;
pub mod cluster;
pub mod search;
pub mod serve;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod cost;
pub mod experiments;
pub mod model;
pub mod parallel;
pub mod util;

pub use api::{MethodSpec, PlanError, PlanReport, PlanRequest, Planner};

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
