//! In-process collectives over host buffers — the NCCL substitute for the
//! real-numerics runtime (DESIGN.md §2). Semantics match ring collectives:
//! all-reduce sums elementwise; all-gather concatenates shards;
//! reduce-scatter sums then splits.

/// All-reduce (sum) across replicas: every buffer ends up with the
/// elementwise sum. Panics if shapes mismatch.
pub fn all_reduce(buffers: &mut [&mut [f32]]) {
    let n = buffers.len();
    if n <= 1 {
        return;
    }
    let len = buffers[0].len();
    assert!(buffers.iter().all(|b| b.len() == len), "shard length mismatch");
    let mut acc = vec![0.0f32; len];
    for b in buffers.iter() {
        for (a, &x) in acc.iter_mut().zip(b.iter()) {
            *a += x;
        }
    }
    for b in buffers.iter_mut() {
        b.copy_from_slice(&acc);
    }
}

/// All-reduce followed by mean (gradient averaging across DP replicas).
pub fn all_reduce_mean(buffers: &mut [&mut [f32]]) {
    let n = buffers.len() as f32;
    all_reduce(buffers);
    if n > 1.0 {
        if let Some(first) = buffers.first_mut() {
            for x in first.iter_mut() {
                *x /= n;
            }
        }
        // Propagate the scaled copy (all buffers identical post-allreduce).
        if buffers.len() > 1 {
            let (head, tail) = buffers.split_at_mut(1);
            for b in tail {
                b.copy_from_slice(head[0]);
            }
        }
    }
}

/// All-gather: each replica holds a shard; returns the concatenation (the
/// same full buffer every replica would see).
pub fn all_gather(shards: &[&[f32]]) -> Vec<f32> {
    let mut out = Vec::with_capacity(shards.iter().map(|s| s.len()).sum());
    for s in shards {
        out.extend_from_slice(s);
    }
    out
}

/// Reduce-scatter: sum the full buffers, return each replica's shard.
/// `full[i]` must all have the same length divisible by the replica count.
pub fn reduce_scatter(full: &[&[f32]]) -> Vec<Vec<f32>> {
    let n = full.len();
    assert!(n >= 1);
    let len = full[0].len();
    assert!(full.iter().all(|b| b.len() == len));
    assert_eq!(len % n, 0, "length must divide replica count");
    let mut acc = vec![0.0f32; len];
    for b in full {
        for (a, &x) in acc.iter_mut().zip(b.iter()) {
            *a += x;
        }
    }
    let shard = len / n;
    (0..n).map(|i| acc[i * shard..(i + 1) * shard].to_vec()).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn all_reduce_sums() {
        let mut a = vec![1.0, 2.0];
        let mut b = vec![10.0, 20.0];
        let mut c = vec![100.0, 200.0];
        all_reduce(&mut [&mut a, &mut b, &mut c]);
        assert_eq!(a, vec![111.0, 222.0]);
        assert_eq!(a, b);
        assert_eq!(b, c);
    }

    #[test]
    fn all_reduce_mean_averages() {
        let mut a = vec![1.0, 3.0];
        let mut b = vec![3.0, 5.0];
        all_reduce_mean(&mut [&mut a, &mut b]);
        assert_eq!(a, vec![2.0, 4.0]);
        assert_eq!(b, vec![2.0, 4.0]);
    }

    #[test]
    fn single_replica_noop() {
        let mut a = vec![1.0, 2.0];
        all_reduce(&mut [&mut a]);
        assert_eq!(a, vec![1.0, 2.0]);
    }

    #[test]
    fn gather_scatter_compose_to_allreduce() {
        // Property (paper Takeaway #3 premise): all-gather ∘ reduce-scatter
        // ≡ all-reduce.
        let mut rng = Rng::new(42);
        for _ in 0..20 {
            let n = 4usize;
            let len = 8usize;
            let bufs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
                .collect();
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            let shards = reduce_scatter(&refs);
            let shard_refs: Vec<&[f32]> = shards.iter().map(|s| s.as_slice()).collect();
            let gathered = all_gather(&shard_refs);

            let mut expect = bufs.clone();
            let mut refs_mut: Vec<&mut [f32]> =
                expect.iter_mut().map(|b| b.as_mut_slice()).collect();
            all_reduce(&mut refs_mut);
            for (g, e) in gathered.iter().zip(expect[0].iter()) {
                assert!((g - e).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let mut a = vec![1.0];
        let mut b = vec![1.0, 2.0];
        all_reduce(&mut [&mut a, &mut b]);
    }
}
