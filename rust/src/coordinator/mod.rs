//! L3 coordinator: the distributed-training driver that turns a plan into
//! real execution over the PJRT runtime — pipeline stages, data-parallel
//! replicas, in-process collectives, synthetic data, and Adam.

pub mod collectives;
pub mod data;
pub mod trainer;

pub use trainer::{TrainReport, Trainer, TrainerConfig};
