//! Real-numerics distributed training driver: executes the AOT pipeline
//! stages over PJRT with pipeline (PP) × data (DP) parallelism, in-process
//! collectives, microbatch gradient accumulation, and Adam updates.
//!
//! Numerics are bit-faithful to the plan semantics: per-microbatch forward
//! chains, recompute-based stage backwards (stage-granular CKPT — the
//! paper's CKPT dimension), gradient mean over microbatches and DP
//! replicas, then the AOT Adam step. The *temporal* interleaving (1F1B
//! bubble structure) is the simulator's concern; on a single host the
//! dependency-ordered execution below produces identical numbers.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::collectives::all_reduce_mean;
use crate::coordinator::data::SyntheticCorpus;
use crate::runtime::{Artifact, HostTensor, Runtime, StageManifest};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub artifacts_dir: PathBuf,
    /// Optimizer steps to run.
    pub steps: usize,
    /// Data-parallel replica count (each replica runs the full pipeline).
    pub dp: usize,
    /// Microbatches accumulated per step per replica.
    pub microbatches: usize,
    pub log_every: usize,
    pub seed: u64,
    /// Reuse the same batches every step (memorization mode — used by the
    /// fast integration tests to get a strong learning signal in seconds).
    pub repeat_batch: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 50,
            dp: 2,
            microbatches: 2,
            log_every: 10,
            seed: 0,
            repeat_batch: false,
        }
    }
}

/// One pipeline stage bound to its executables and per-replica state.
struct StageRuntime {
    man: StageManifest,
    fwd: Artifact,
    bwd: Artifact,
    adam: Artifact,
    /// Per-replica parameters / Adam moments (replicated).
    params: Vec<Vec<HostTensor>>,
    m: Vec<Vec<HostTensor>>,
    v: Vec<Vec<HostTensor>>,
    /// §Perf: cached XLA literals of `params`, rebuilt only after Adam —
    /// forward/backward calls reuse them instead of re-copying ~all model
    /// bytes per microbatch.
    param_lits: Vec<Vec<xla::Literal>>,
}

impl StageRuntime {
    fn n_params(&self) -> usize {
        self.man.param_names.len()
    }

    fn refresh_param_lits(&mut self) -> Result<()> {
        self.param_lits = self
            .params
            .iter()
            .map(|rep| rep.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>())
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// Step-by-step training report.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub step_seconds: Vec<f64>,
    pub samples_per_step: usize,
    pub param_count: usize,
}

impl TrainReport {
    pub fn samples_per_sec(&self) -> f64 {
        let total: f64 = self.step_seconds.iter().sum();
        if total > 0.0 {
            self.samples_per_step as f64 * self.losses.len() as f64 / total
        } else {
            0.0
        }
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,loss,seconds\n");
        for (i, (l, t)) in self.losses.iter().zip(&self.step_seconds).enumerate() {
            s.push_str(&format!("{},{:.6},{:.4}\n", i + 1, l, t));
        }
        s
    }
}

/// The coordinator's training loop.
pub struct Trainer {
    cfg: TrainerConfig,
    stages: Vec<StageRuntime>,
    corpora: Vec<SyntheticCorpus>,
    /// Pre-drawn batches for repeat_batch mode: [replica][microbatch].
    fixed_batches: Vec<Vec<(Vec<i32>, Vec<i32>)>>,
    microbatch: usize,
    seq: usize,
    step: usize,
    pub param_count: usize,
}

impl Trainer {
    pub fn new(cfg: TrainerConfig) -> Result<Trainer> {
        let rt = Runtime::new(&cfg.artifacts_dir)?;
        let man = rt.manifest().context("loading manifest")?;
        anyhow::ensure!(cfg.dp >= 1 && cfg.microbatches >= 1);

        let mut stages = Vec::with_capacity(man.stages.len());
        for sm in &man.stages {
            let fwd = rt.load(
                &format!("stage{}_fwd", sm.index),
                &sm.fwd.file,
                sm.fwd.inputs.clone(),
                sm.fwd.outputs.clone(),
            )?;
            let bwd = rt.load(
                &format!("stage{}_bwd", sm.index),
                &sm.bwd.file,
                sm.bwd.inputs.clone(),
                sm.bwd.outputs.clone(),
            )?;
            let adam = rt.load(
                &format!("stage{}_adam", sm.index),
                &sm.adam.file,
                sm.adam.inputs.clone(),
                sm.adam.outputs.clone(),
            )?;
            let init = rt.load_params(&sm.param_file, &sm.param_shapes)?;
            let zeros: Vec<HostTensor> =
                sm.param_shapes.iter().map(|s| HostTensor::zeros(s)).collect();
            let params: Vec<Vec<HostTensor>> = (0..cfg.dp).map(|_| init.clone()).collect();
            let m: Vec<Vec<HostTensor>> = (0..cfg.dp).map(|_| zeros.clone()).collect();
            let v: Vec<Vec<HostTensor>> = (0..cfg.dp).map(|_| zeros.clone()).collect();
            let mut st = StageRuntime { man: sm.clone(), fwd, bwd, adam, params, m, v, param_lits: Vec::new() };
            st.refresh_param_lits()?;
            stages.push(st);
        }
        let mut corpora: Vec<SyntheticCorpus> = (0..cfg.dp)
            .map(|d| SyntheticCorpus::new(man.config.vocab, cfg.seed.wrapping_add(d as u64 * 7919)))
            .collect();
        let fixed_batches = if cfg.repeat_batch {
            corpora
                .iter_mut()
                .map(|c| {
                    (0..cfg.microbatches)
                        .map(|_| c.next_batch(man.config.microbatch, man.config.seq))
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(Trainer {
            microbatch: man.config.microbatch,
            seq: man.config.seq,
            param_count: man.param_count,
            cfg,
            stages,
            corpora,
            fixed_batches,
            step: 0,
        })
    }

    pub fn samples_per_step(&self) -> usize {
        self.cfg.dp * self.cfg.microbatches * self.microbatch
    }

    /// One optimizer step; returns the mean loss.
    pub fn train_step(&mut self) -> Result<f64> {
        self.step += 1;
        let p = self.stages.len();
        let dp = self.cfg.dp;
        // grad accumulators: [stage][replica][param] -> Vec<f32>
        let mut grads: Vec<Vec<Vec<Vec<f32>>>> = self
            .stages
            .iter()
            .map(|s| {
                (0..dp)
                    .map(|_| s.man.param_shapes.iter().map(|sh| vec![0f32; sh.iter().product()]).collect())
                    .collect()
            })
            .collect();
        let mut loss_sum = 0.0;
        let mut loss_n = 0usize;

        for d in 0..dp {
            for mb in 0..self.cfg.microbatches {
                let (tokens, targets) = if self.cfg.repeat_batch {
                    self.fixed_batches[d][mb].clone()
                } else {
                    self.corpora[d].next_batch(self.microbatch, self.seq)
                };
                let x0 = HostTensor::I32 { shape: vec![self.microbatch, self.seq], data: tokens };
                let tgt = HostTensor::I32 { shape: vec![self.microbatch, self.seq], data: targets };
                let tgt_lit = tgt.to_literal()?;

                // Forward chain: stash each stage's input (as a literal —
                // the backward recompute reuses it directly).
                let mut stage_inputs: Vec<xla::Literal> = Vec::with_capacity(p);
                let mut x_lit = x0.to_literal()?;
                for s in 0..p {
                    stage_inputs.push(x_lit);
                    if s + 1 < p {
                        let stage = &self.stages[s];
                        let mut args: Vec<&xla::Literal> = stage.param_lits[d].iter().collect();
                        args.push(&stage_inputs[s]);
                        let mut out = stage.fwd.run_literals(&args)?;
                        x_lit = out.remove(0).to_literal()?;
                    } else {
                        x_lit = HostTensor::scalar_f32(0.0).to_literal()?; // placeholder
                    }
                }

                // Backward chain (recompute-based).
                let mut dy: Option<xla::Literal> = None;
                for s in (0..p).rev() {
                    let stage = &self.stages[s];
                    let n = stage.n_params();
                    let mut args: Vec<&xla::Literal> = stage.param_lits[d].iter().collect();
                    args.push(&stage_inputs[s]);
                    let dy_lit;
                    if stage.man.last {
                        args.push(&tgt_lit);
                    } else {
                        dy_lit = dy.take().context("missing upstream grad")?;
                        args.push(&dy_lit);
                    }
                    let mut out = stage.bwd.run_literals(&args)?;
                    // Output layout: [dx]? + grads[n] + [loss]?
                    if stage.man.last {
                        let loss = out.pop().context("loss missing")?;
                        loss_sum += loss.as_f32()?[0] as f64;
                        loss_n += 1;
                    }
                    let has_dx = !stage.man.first;
                    let grad_start = usize::from(has_dx);
                    for (gi, g) in out[grad_start..grad_start + n].iter().enumerate() {
                        let src = g.as_f32()?;
                        let acc = &mut grads[s][d][gi];
                        for (a, &x) in acc.iter_mut().zip(src) {
                            *a += x;
                        }
                    }
                    if has_dx {
                        dy = Some(out.swap_remove(0).to_literal()?);
                    }
                }
            }
        }

        // Scale by 1/microbatches, then all-reduce-mean across DP replicas.
        let inv_m = 1.0 / self.cfg.microbatches as f32;
        for sgrads in grads.iter_mut() {
            for rep in sgrads.iter_mut() {
                for g in rep.iter_mut() {
                    for x in g.iter_mut() {
                        *x *= inv_m;
                    }
                }
            }
            let n_params = sgrads[0].len();
            for gi in 0..n_params {
                let mut refs: Vec<&mut [f32]> = Vec::with_capacity(dp);
                // Split borrows across replicas.
                let mut rest = &mut sgrads[..];
                while let Some((head, tail)) = rest.split_first_mut() {
                    refs.push(head[gi].as_mut_slice());
                    rest = tail;
                }
                all_reduce_mean(&mut refs);
            }
        }

        // Adam update on replica 0, broadcast to the others (identical
        // averaged grads -> identical updates; broadcast saves compute).
        let step_t = HostTensor::scalar_f32(self.step as f32);
        for (s, stage) in self.stages.iter_mut().enumerate() {
            let n = stage.n_params();
            let mut args: Vec<HostTensor> = Vec::with_capacity(4 * n + 1);
            args.extend(stage.params[0].iter().cloned());
            for (gi, shape) in stage.man.param_shapes.iter().enumerate() {
                args.push(HostTensor::F32 { shape: shape.clone(), data: grads[s][0][gi].clone() });
            }
            args.extend(stage.m[0].iter().cloned());
            args.extend(stage.v[0].iter().cloned());
            args.push(step_t.clone());
            let out = stage.adam.run(&args)?;
            anyhow::ensure!(out.len() == 3 * n, "adam output arity");
            let new_p = out[..n].to_vec();
            let new_m = out[n..2 * n].to_vec();
            let new_v = out[2 * n..].to_vec();
            for d in 0..dp {
                stage.params[d] = new_p.clone();
                stage.m[d] = new_m.clone();
                stage.v[d] = new_v.clone();
            }
            stage.refresh_param_lits()?;
        }

        Ok(loss_sum / loss_n.max(1) as f64)
    }

    /// Run the configured number of steps.
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut times = Vec::with_capacity(self.cfg.steps);
        for i in 0..self.cfg.steps {
            let t0 = Instant::now();
            let loss = self.train_step()?;
            let dt = t0.elapsed().as_secs_f64();
            losses.push(loss);
            times.push(dt);
            if self.cfg.log_every > 0 && (i + 1) % self.cfg.log_every == 0 {
                eprintln!(
                    "step {:>4}  loss {:.4}  {:.2}s/step  {:.1} samples/s",
                    i + 1,
                    loss,
                    dt,
                    self.samples_per_step() as f64 / dt
                );
            }
        }
        Ok(TrainReport {
            losses,
            step_seconds: times,
            samples_per_step: self.samples_per_step(),
            param_count: self.param_count,
        })
    }

    /// Verify all DP replicas hold identical parameters (invariant).
    pub fn replicas_in_sync(&self) -> Result<bool> {
        for stage in &self.stages {
            for d in 1..self.cfg.dp {
                for (a, b) in stage.params[0].iter().zip(&stage.params[d]) {
                    let (a, b) = (a.as_f32()?, b.as_f32()?);
                    if a.iter().zip(b).any(|(x, y)| x != y) {
                        return Ok(false);
                    }
                }
            }
        }
        Ok(true)
    }
}
