//! Synthetic corpus generator (substitutes Wikipedia/ImageNet — DESIGN.md
//! §2): a Zipf-weighted first-order Markov chain over the vocabulary, so
//! next-token prediction has real learnable structure and the e2e loss
//! curve drops well below the uniform-entropy baseline.

use crate::util::rng::Rng;

/// Deterministic synthetic token stream.
pub struct SyntheticCorpus {
    vocab: usize,
    /// Per-state transition sparsity: each token can be followed by one of
    /// `branch` successors with Zipf weights.
    successors: Vec<Vec<u32>>,
    weights: Vec<f64>,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        let branch = 8usize.min(vocab);
        let mut setup = Rng::new(seed ^ 0x5EED);
        let successors: Vec<Vec<u32>> = (0..vocab)
            .map(|_| (0..branch).map(|_| setup.below(vocab as u64) as u32).collect())
            .collect();
        // Zipf weights over the branch choices.
        let weights: Vec<f64> = (1..=branch).map(|r| 1.0 / r as f64).collect();
        SyntheticCorpus { vocab, successors, weights, rng: Rng::new(seed) }
    }

    /// Sample a (tokens, targets) pair of shape [batch, seq]; targets are
    /// the next-token shift of tokens.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut cur = self.rng.below(self.vocab as u64) as u32;
            let mut row = Vec::with_capacity(seq + 1);
            row.push(cur);
            for _ in 0..seq {
                let choice = self.rng.categorical(&self.weights);
                cur = self.successors[cur as usize][choice];
                row.push(cur);
            }
            tokens.extend(row[..seq].iter().map(|&t| t as i32));
            targets.extend(row[1..=seq].iter().map(|&t| t as i32));
        }
        (tokens, targets)
    }

    /// Entropy upper bound of the chain (bits->nats of branch Zipf), used
    /// by tests to check the model learns below uniform entropy.
    pub fn transition_entropy(&self) -> f64 {
        let z: f64 = self.weights.iter().sum();
        -self
            .weights
            .iter()
            .map(|w| {
                let p = w / z;
                p * p.ln()
            })
            .sum::<f64>()
    }

    pub fn uniform_entropy(&self) -> f64 {
        (self.vocab as f64).ln()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut c = SyntheticCorpus::new(512, 7);
        let (t, y) = c.next_batch(4, 64);
        assert_eq!(t.len(), 256);
        assert_eq!(y.len(), 256);
        assert!(t.iter().all(|&x| (0..512).contains(&x)));
        assert!(y.iter().all(|&x| (0..512).contains(&x)));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut c = SyntheticCorpus::new(128, 3);
        let (t, y) = c.next_batch(2, 32);
        // Within each row, y[i] == t[i+1].
        for row in 0..2 {
            for i in 0..31 {
                assert_eq!(y[row * 32 + i], t[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(256, 9);
        let mut b = SyntheticCorpus::new(256, 9);
        assert_eq!(a.next_batch(2, 16), b.next_batch(2, 16));
    }

    #[test]
    fn learnable_structure() {
        // Transition entropy must be far below uniform entropy, otherwise
        // the e2e loss curve would be flat.
        let c = SyntheticCorpus::new(8192, 1);
        assert!(c.transition_entropy() < 0.5 * c.uniform_entropy());
    }

    #[test]
    fn chain_follows_successor_table() {
        let mut c = SyntheticCorpus::new(64, 5);
        let (t, y) = c.next_batch(1, 40);
        for i in 0..39 {
            let cur = t[i] as usize;
            assert!(c.successors[cur].contains(&(t[i + 1] as u32)));
            let _ = y;
        }
    }
}
