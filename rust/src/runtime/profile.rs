//! Cost-model calibration by profiling (paper §V: "take advantages from
//! both sides" — profiling for compute, simulation for communication).
//!
//! Executes the `profile_layer_h*` artifacts on the PJRT CPU client,
//! measures per-forward wallclock, and derives the effective FLOP/s of
//! this host — producing a calibrated [`GpuSpec`] so planner tests and the
//! e2e example can agree with real execution on this machine.

use std::time::Instant;

use anyhow::Result;

use crate::cluster::GpuSpec;
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;

/// One profiled artifact's measurement.
#[derive(Debug, Clone)]
pub struct ProfileMeasurement {
    pub hidden: usize,
    pub seq: usize,
    pub batch: usize,
    pub flops_fwd: f64,
    pub seconds_per_fwd: f64,
    pub effective_flops: f64,
}

/// Profile every entry in the manifest; `reps` timed repetitions each.
pub fn profile_layers(rt: &Runtime, reps: usize) -> Result<Vec<ProfileMeasurement>> {
    let man = rt.manifest()?;
    let mut rng = Rng::new(0xC0FFEE);
    let mut out = Vec::new();
    for p in &man.profiles {
        let art = rt.load(
            &format!("profile_h{}", p.hidden),
            &p.artifact.file,
            p.artifact.inputs.clone(),
            p.artifact.outputs.clone(),
        )?;
        let args: Vec<HostTensor> = art
            .inputs
            .iter()
            .map(|spec| {
                let n = spec.numel();
                HostTensor::F32 {
                    shape: spec.shape.clone(),
                    data: (0..n).map(|_| rng.normal() as f32 * 0.05).collect(),
                }
            })
            .collect();
        // Warmup.
        art.run(&args)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            art.run(&args)?;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        out.push(ProfileMeasurement {
            hidden: p.hidden,
            seq: p.seq,
            batch: p.batch,
            flops_fwd: p.flops_fwd,
            seconds_per_fwd: secs,
            effective_flops: p.flops_fwd / secs,
        });
    }
    Ok(out)
}

/// Calibrated "GPU" spec for this host: median effective FLOP/s.
pub fn calibrated_host_spec(measurements: &[ProfileMeasurement], mem_bytes: f64) -> GpuSpec {
    let mut fl: Vec<f64> = measurements.iter().map(|m| m.effective_flops).collect();
    fl.sort_by(f64::total_cmp);
    let flops = if fl.is_empty() { 30e9 } else { fl[fl.len() / 2] };
    GpuSpec { name: "calibrated-host".into(), mem_bytes, flops }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_median() {
        let ms: Vec<ProfileMeasurement> = [1e9, 3e9, 2e9]
            .iter()
            .map(|&f| ProfileMeasurement {
                hidden: 256,
                seq: 128,
                batch: 4,
                flops_fwd: 1e9,
                seconds_per_fwd: 1.0,
                effective_flops: f,
            })
            .collect();
        let spec = calibrated_host_spec(&ms, 1e9);
        assert_eq!(spec.flops, 2e9);
        // Empty falls back to a sane default.
        assert!(calibrated_host_spec(&[], 1e9).flops > 0.0);
    }
}
