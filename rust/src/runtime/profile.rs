//! Cost-model calibration by profiling (paper §V: "take advantages from
//! both sides" — profiling for compute, simulation for communication).
//!
//! Executes the `profile_layer_h*` artifacts on the PJRT CPU client,
//! measures per-forward wallclock, and derives the effective FLOP/s of
//! this host — producing a calibrated [`GpuSpec`] so planner tests and the
//! e2e example can agree with real execution on this machine.
//!
//! `galvatron calibrate` feeds these measurements (via
//! [`to_layer_samples`]) plus the in-process collectives micro-benchmark
//! into a persistent [`crate::cost::ProfileDb`], closing the loop from
//! real execution back into planning.

use std::time::Instant;

use anyhow::Result;

use crate::cluster::GpuSpec;
use crate::cost::LayerSample;
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;

/// One profiled artifact's measurement.
#[derive(Debug, Clone)]
pub struct ProfileMeasurement {
    pub hidden: usize,
    pub seq: usize,
    pub batch: usize,
    pub flops_fwd: f64,
    pub seconds_per_fwd: f64,
    pub effective_flops: f64,
}

/// Profile every entry in the manifest; `reps` timed repetitions each.
pub fn profile_layers(rt: &Runtime, reps: usize) -> Result<Vec<ProfileMeasurement>> {
    let man = rt.manifest()?;
    let mut rng = Rng::new(0xC0FFEE);
    let mut out = Vec::new();
    for p in &man.profiles {
        let art = rt.load(
            &format!("profile_h{}", p.hidden),
            &p.artifact.file,
            p.artifact.inputs.clone(),
            p.artifact.outputs.clone(),
        )?;
        let args: Vec<HostTensor> = art
            .inputs
            .iter()
            .map(|spec| {
                let n = spec.numel();
                HostTensor::F32 {
                    shape: spec.shape.clone(),
                    data: (0..n).map(|_| rng.normal() as f32 * 0.05).collect(),
                }
            })
            .collect();
        // Warmup.
        art.run(&args)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            art.run(&args)?;
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        out.push(ProfileMeasurement {
            hidden: p.hidden,
            seq: p.seq,
            batch: p.batch,
            flops_fwd: p.flops_fwd,
            seconds_per_fwd: secs,
            effective_flops: p.flops_fwd / secs,
        });
    }
    Ok(out)
}

/// Convert PJRT measurements into [`crate::cost::ProfileDb`] layer
/// samples (the compute half of `galvatron calibrate`).
pub fn to_layer_samples(measurements: &[ProfileMeasurement]) -> Vec<LayerSample> {
    measurements
        .iter()
        .map(|m| {
            // Manifest flops_fwd is per *forward* (batch included); the DB
            // schema is per sample, so both flops and seconds divide by
            // batch — preserving effective_flops = flops / seconds.
            let batch = m.batch.max(1) as f64;
            LayerSample {
                hidden: m.hidden,
                seq: m.seq,
                batch: m.batch,
                flops_fwd: m.flops_fwd / batch,
                seconds_per_sample: m.seconds_per_fwd / batch,
                effective_flops: m.effective_flops,
            }
        })
        .collect()
}

/// Calibrated "GPU" spec for this host: median effective FLOP/s.
pub fn calibrated_host_spec(measurements: &[ProfileMeasurement], mem_bytes: f64) -> GpuSpec {
    let mut fl: Vec<f64> = measurements.iter().map(|m| m.effective_flops).collect();
    fl.sort_by(f64::total_cmp);
    let flops = if fl.is_empty() { 30e9 } else { fl[fl.len() / 2] };
    GpuSpec { name: "calibrated-host".into(), mem_bytes, flops }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn calibration_median() {
        let ms: Vec<ProfileMeasurement> = [1e9, 3e9, 2e9]
            .iter()
            .map(|&f| ProfileMeasurement {
                hidden: 256,
                seq: 128,
                batch: 4,
                flops_fwd: 1e9,
                seconds_per_fwd: 1.0,
                effective_flops: f,
            })
            .collect();
        let spec = calibrated_host_spec(&ms, 1e9);
        assert_eq!(spec.flops, 2e9);
        // Empty falls back to a sane default.
        assert!(calibrated_host_spec(&[], 1e9).flops > 0.0);
    }

    #[test]
    fn measurements_convert_to_db_samples() {
        let m = ProfileMeasurement {
            hidden: 256,
            seq: 128,
            batch: 4,
            flops_fwd: 1e9,
            seconds_per_fwd: 0.2,
            effective_flops: 5e9,
        };
        let s = to_layer_samples(&[m]);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].hidden, 256);
        assert_eq!(s[0].seconds_per_sample, 0.05);
        // Per-sample flops: the manifest's per-forward count over batch.
        assert_eq!(s[0].flops_fwd, 2.5e8);
        assert_eq!(s[0].effective_flops, 5e9);
        // The documented invariant holds: eff = flops / seconds.
        assert_eq!(s[0].flops_fwd / s[0].seconds_per_sample, 5e9);
    }
}
