//! AOT manifest parsing (artifacts/manifest.json written by aot.py).

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::DType;
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = match j.req("dtype")?.as_str() {
            Some("f32") => DType::F32,
            Some("i32") => DType::I32,
            other => anyhow::bail!("unknown dtype {other:?}"),
        };
        let shape = j
            .req("shape")?
            .as_usize_vec()
            .context("shape must be an int array")?;
        Ok(TensorSpec { dtype, shape })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry: file + signature.
#[derive(Debug, Clone)]
pub struct ArtifactDesc {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactDesc {
    fn from_json(j: &Json) -> Result<ArtifactDesc> {
        Ok(ArtifactDesc {
            file: j.req("file")?.as_str().context("file")?.to_string(),
            inputs: j
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

/// One pipeline stage's artifacts + parameter layout.
#[derive(Debug, Clone)]
pub struct StageManifest {
    pub index: usize,
    pub first: bool,
    pub last: bool,
    pub layers: Vec<usize>,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub param_file: String,
    pub fwd: ArtifactDesc,
    pub bwd: ArtifactDesc,
    pub adam: ArtifactDesc,
}

/// Model configuration captured at AOT time.
#[derive(Debug, Clone)]
pub struct AotConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub microbatch: usize,
    pub use_pallas: bool,
}

/// Profiling artifact entry (cost-model calibration).
#[derive(Debug, Clone)]
pub struct ProfileDesc {
    pub artifact: ArtifactDesc,
    pub hidden: usize,
    pub seq: usize,
    pub batch: usize,
    pub flops_fwd: f64,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub kernels: String,
    pub config: AotConfig,
    pub param_count: usize,
    pub partition: Vec<usize>,
    pub stages: Vec<StageManifest>,
    pub profiles: Vec<ProfileDesc>,
    pub smoke: ArtifactDesc,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            j.req("format_version")?.as_usize() == Some(1),
            "unsupported manifest version"
        );
        let cfg = j.req("config")?;
        let config = AotConfig {
            vocab: cfg.req("vocab")?.as_usize().context("vocab")?,
            hidden: cfg.req("hidden")?.as_usize().context("hidden")?,
            layers: cfg.req("layers")?.as_usize().context("layers")?,
            heads: cfg.req("heads")?.as_usize().context("heads")?,
            seq: cfg.req("seq")?.as_usize().context("seq")?,
            microbatch: cfg.req("microbatch")?.as_usize().context("microbatch")?,
            use_pallas: cfg.req("use_pallas")?.as_bool().unwrap_or(true),
        };
        let stages = j
            .req("stages")?
            .as_arr()
            .context("stages")?
            .iter()
            .map(|s| {
                Ok(StageManifest {
                    index: s.req("index")?.as_usize().context("index")?,
                    first: s.req("first")?.as_bool().context("first")?,
                    last: s.req("last")?.as_bool().context("last")?,
                    layers: s.req("layers")?.as_usize_vec().context("layers")?,
                    param_names: s
                        .req("param_names")?
                        .as_arr()
                        .context("param_names")?
                        .iter()
                        .map(|n| Ok(n.as_str().context("name")?.to_string()))
                        .collect::<Result<_>>()?,
                    param_shapes: s
                        .req("param_shapes")?
                        .as_arr()
                        .context("param_shapes")?
                        .iter()
                        .map(|v| v.as_usize_vec().context("shape"))
                        .collect::<Result<_>>()?,
                    param_file: s.req("param_file")?.as_str().context("param_file")?.to_string(),
                    fwd: ArtifactDesc::from_json(s.req("fwd")?)?,
                    bwd: ArtifactDesc::from_json(s.req("bwd")?)?,
                    adam: ArtifactDesc::from_json(s.req("adam")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let profiles = j
            .req("profiles")?
            .as_arr()
            .context("profiles")?
            .iter()
            .map(|p| {
                Ok(ProfileDesc {
                    artifact: ArtifactDesc::from_json(p)?,
                    hidden: p.req("hidden")?.as_usize().context("hidden")?,
                    seq: p.req("seq")?.as_usize().context("seq")?,
                    batch: p.req("batch")?.as_usize().context("batch")?,
                    flops_fwd: p.req("flops_fwd")?.as_f64().context("flops")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            preset: j.req("preset")?.as_str().unwrap_or("?").to_string(),
            kernels: j.req("kernels")?.as_str().unwrap_or("?").to_string(),
            config,
            param_count: j.req("param_count")?.as_usize().context("param_count")?,
            partition: j.req("partition")?.as_usize_vec().context("partition")?,
            stages,
            profiles,
            smoke: ArtifactDesc::from_json(j.req("smoke")?)?,
        })
    }

    /// Total parameter count across stages from the declared shapes.
    pub fn declared_params(&self) -> usize {
        self.stages
            .iter()
            .flat_map(|s| s.param_shapes.iter())
            .map(|s| s.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format_version": 1, "preset": "tiny", "kernels": "pallas",
      "config": {"vocab": 512, "hidden": 128, "layers": 2, "heads": 4,
                 "seq": 64, "microbatch": 2, "ffn_mult": 4, "use_pallas": true},
      "param_count": 536064, "partition": [1, 1],
      "adam": {"lr": 0.001, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
      "stages": [{
        "index": 0, "first": true, "last": false, "layers": [0],
        "param_names": ["emb.tok"], "param_shapes": [[512, 128]],
        "param_file": "stage0_params.bin",
        "fwd": {"file": "stage0_fwd.hlo.txt",
                "inputs": [{"dtype":"f32","shape":[512,128]},{"dtype":"i32","shape":[2,64]}],
                "outputs": [{"dtype":"f32","shape":[2,64,128]}]},
        "bwd": {"file": "b", "inputs": [], "outputs": []},
        "adam": {"file": "a", "inputs": [], "outputs": []}
      }],
      "profiles": [{"file": "p.hlo.txt", "inputs": [], "outputs": [],
                    "hidden": 256, "seq": 128, "batch": 4, "flops_fwd": 1e9}],
      "smoke": {"file": "s.hlo.txt", "inputs": [{"dtype":"f32","shape":[]}],
                "outputs": [{"dtype":"f32","shape":[16]}]}
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("galvatron_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        std::fs::write(&path, SAMPLE).unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.config.hidden, 128);
        assert_eq!(m.partition, vec![1, 1]);
        assert_eq!(m.stages.len(), 1);
        assert!(m.stages[0].first && !m.stages[0].last);
        assert_eq!(m.stages[0].fwd.inputs[1].dtype, DType::I32);
        assert_eq!(m.stages[0].fwd.outputs[0].shape, vec![2, 64, 128]);
        assert_eq!(m.declared_params(), 512 * 128);
        assert_eq!(m.profiles[0].flops_fwd, 1e9);
        assert_eq!(m.smoke.inputs[0].numel(), 1);
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("galvatron_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        std::fs::write(&path, SAMPLE.replace("\"format_version\": 1", "\"format_version\": 9")).unwrap();
        assert!(Manifest::load(&path).is_err());
    }
}
