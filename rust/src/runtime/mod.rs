//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client — the only place Rust touches XLA. Python never runs here.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id protos that xla_extension 0.5.1
//! rejects.

pub mod manifest;
pub mod profile;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::{Manifest, StageManifest, TensorSpec};

/// Element type of a tensor crossing the FFI boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A host-side tensor (what the coordinator shuttles around).
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    /// Convert to an XLA literal (host copy). Public so the coordinator
    /// can cache parameter literals across calls (§Perf).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            HostTensor::F32 { shape, data } => {
                let flat = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                flat.reshape(&dims)?
            }
            HostTensor::I32 { shape, data } => {
                let flat = xla::Literal::vec1(data.as_slice());
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                flat.reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            DType::F32 => HostTensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? },
            DType::I32 => HostTensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? },
        })
    }
}

/// A compiled executable plus its manifest-declared signature.
pub struct Artifact {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host tensors; returns the unpacked output tuple.
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()
            .with_context(|| format!("packing inputs of {}", self.name))?;
        self.run_literals(&literals.iter().collect::<Vec<_>>())
    }

    /// Execute with pre-built literals (hot path: the coordinator caches
    /// parameter literals across microbatches instead of re-copying them).
    pub fn run_literals(&self, literals: &[&xla::Literal]) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(
            literals.len() == self.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.name,
            self.inputs.len(),
            literals.len()
        );
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        // AOT lowers with return_tuple=True: always a tuple root.
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.outputs.len(),
            parts.len()
        );
        parts
            .iter()
            .zip(&self.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec))
            .collect()
    }
}

/// The PJRT runtime: client + artifact loader.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, dir: artifacts_dir.to_path_buf() })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load the manifest from the artifacts directory.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir.join("manifest.json"))
    }

    /// Load + compile one artifact described by (file, inputs, outputs).
    pub fn load(&self, name: &str, file: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>) -> Result<Artifact> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact { name: name.to_string(), inputs, outputs, exe })
    }

    /// Read a raw little-endian f32 parameter file, split per the shapes.
    pub fn load_params(&self, file: &str, shapes: &[Vec<usize>]) -> Result<Vec<HostTensor>> {
        let bytes = std::fs::read(self.dir.join(file))
            .with_context(|| format!("reading {file}"))?;
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        anyhow::ensure!(bytes.len() == 4 * total, "{file}: size mismatch");
        let mut floats = vec![0f32; total];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            floats[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        let mut out = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for shape in shapes {
            let n: usize = shape.iter().product();
            out.push(HostTensor::F32 { shape: shape.clone(), data: floats[off..off + n].to_vec() });
            off += n;
        }
        Ok(out)
    }
}
