//! Micro-benchmark harness (substrate: criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with mean/p50/p95 reporting, used by
//! every target under `benches/` (each declared with `harness = false`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<5} mean={:>12?} p50={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Run `f` repeatedly for at least `budget` (after warmup) and report stats.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: one call, or more if the call is very fast.
    let w0 = Instant::now();
    f();
    let first = w0.elapsed();
    let warmups = if first < Duration::from_millis(5) { 10 } else { 0 };
    for _ in 0..warmups {
        f();
    }

    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1);
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[p95_idx],
        min: samples[0],
    };
    println!("{}", res.report());
    res
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep() {
        let r = bench("sleep-2ms", Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(r.mean >= Duration::from_millis(2));
        assert!(r.iters >= 3);
    }
}
