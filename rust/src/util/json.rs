//! Minimal JSON parser/serializer (substrate: serde/serde_json are not
//! available offline in this image; the AOT manifest and result files need
//! structured interchange, so we implement RFC 8259 parsing ourselves).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that fails loudly with the missing key's name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- pretty serialization -------------------------------------------

    /// Human-oriented serialization: 2-space indent, stable (sorted) key
    /// order, trailing newline — the on-disk format of exported
    /// `ModelSpec` files, byte-reproducible so regeneration is diff-clean.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    out.push_str("  ");
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    out.push_str("  ");
                    out.push_str(&Json::Str(k.clone()).to_string());
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad);
                out.push('}');
            }
            scalar => out.push_str(&scalar.to_string()),
        }
    }
}

/// Strict-object validation shared by user-authored JSON schemas
/// (`ModelSpec`, `ProfileDb`): reject non-objects and unknown keys — a
/// misspelled optional key or a scalar where an object belongs must
/// error, not silently describe something else. Returns the diagnostic as
/// a plain `String`; each schema wraps it in its own error type.
pub fn check_object_keys(v: &Json, allowed: &[&str], ctx: &str) -> Result<(), String> {
    let Json::Obj(m) = v else {
        return Err(format!(
            "{ctx}: expected a JSON object with keys {{{}}}",
            allowed.join(", ")
        ));
    };
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(format!("{ctx}: unknown key {k:?} (allowed: {})", allowed.join(", ")));
        }
    }
    Ok(())
}

impl fmt::Display for Json {
    /// Compact serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {text}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our manifests.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (valid UTF-8 input).
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"hidden":256,"use_pallas":true},"partition":[2,2],"names":["emb.tok","l0.qkv.w"]}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[3,1,2]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![3, 1, 2]));
        assert_eq!(Json::parse(r#"[1,"x"]"#).unwrap().as_usize_vec(), None);
    }

    #[test]
    fn pretty_round_trips_and_indents() {
        let src = r#"{"b":[1,2,{"x":"y"}],"a":true,"empty":{},"none":[]}"#;
        let v = Json::parse(src).unwrap();
        let pretty = v.to_pretty();
        // Parses back to the same value.
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        // Shape: sorted keys, 2-space indent, trailing newline, empty
        // containers stay compact.
        assert!(pretty.starts_with("{\n  \"a\": true"), "{pretty}");
        assert!(pretty.contains("\"empty\": {}"), "{pretty}");
        assert!(pretty.contains("\"none\": []"), "{pretty}");
        assert!(pretty.contains("    {\n      \"x\": \"y\"\n    }"), "{pretty}");
        assert!(pretty.ends_with("}\n"), "{pretty}");
    }

    #[test]
    fn strict_key_check() {
        let v = Json::parse(r#"{"a":1,"b":2}"#).unwrap();
        assert!(check_object_keys(&v, &["a", "b", "c"], "ctx").is_ok());
        let err = check_object_keys(&v, &["a"], "ctx").unwrap_err();
        assert!(err.contains("unknown key \"b\"") && err.contains("ctx"), "{err}");
        let err = check_object_keys(&Json::num(3.0), &["a"], "ctx").unwrap_err();
        assert!(err.contains("expected a JSON object"), "{err}");
    }

    #[test]
    fn escapes_on_output() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
