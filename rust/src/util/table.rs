//! ASCII table rendering for the paper-table regenerators.

/// A simple left-aligned table with a header row.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row arity mismatch");
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        out.push_str(&sep);
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a throughput cell like the paper: "19.06 (184)" or "OOM".
pub fn tp_cell(throughput: Option<(f64, usize)>) -> String {
    match throughput {
        Some((tp, batch)) => format!("{tp:.2} ({batch})"),
        None => "OOM".to_string(),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["Strategy", "BERT"]);
        t.row(["Megatron (TP)", "5.72 (8)"]);
        t.row(["Galvatron-BMW", "OOM"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 6); // sep, header, sep, 2 rows, sep
        let len = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == len), "{s}");
        assert!(s.contains("| Megatron (TP) | 5.72 (8) |"));
    }

    #[test]
    fn tp_cells() {
        assert_eq!(tp_cell(Some((19.061, 184))), "19.06 (184)");
        assert_eq!(tp_cell(None), "OOM");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
