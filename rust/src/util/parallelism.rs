//! Worker-count resolution for the parallel search engine.
//!
//! One precedence order, used everywhere a worker pool is sized:
//!
//!   1. an explicit request (`--threads N` on the CLI, or
//!      [`crate::api::PlanRequest::threads`] in the API),
//!   2. the `GALVATRON_THREADS` environment variable,
//!   3. [`std::thread::available_parallelism`].
//!
//! A value of `0` at any level means "auto" and falls through to the next
//! source, so `GALVATRON_THREADS=0` behaves like the variable being unset.

/// Environment variable consulted when no explicit thread count is given.
pub const THREADS_ENV: &str = "GALVATRON_THREADS";

/// Resolve the worker count for a search run. `requested` is the explicit
/// CLI/API value (`None` or `Some(0)` = auto).
pub fn resolve_worker_count(requested: Option<usize>) -> usize {
    let detected = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    resolve_from(requested, std::env::var(THREADS_ENV).ok().as_deref(), detected)
}

/// Pure core of [`resolve_worker_count`] with every input explicit, so the
/// precedence order is testable without mutating process environment.
///
/// Precedence: `requested` > `env` > `detected`; zero or unparsable values
/// fall through to the next source; the result is always >= 1.
pub fn resolve_from(requested: Option<usize>, env: Option<&str>, detected: usize) -> usize {
    if let Some(n) = requested {
        if n >= 1 {
            return n;
        }
    }
    if let Some(s) = env {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    detected.max(1)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn explicit_request_wins_over_everything() {
        assert_eq!(resolve_from(Some(3), Some("8"), 16), 3);
        assert_eq!(resolve_from(Some(1), Some("8"), 16), 1);
    }

    #[test]
    fn env_wins_over_detection() {
        assert_eq!(resolve_from(None, Some("8"), 16), 8);
        assert_eq!(resolve_from(None, Some(" 2 "), 16), 2);
    }

    #[test]
    fn detection_is_the_fallback() {
        assert_eq!(resolve_from(None, None, 6), 6);
        assert_eq!(resolve_from(None, None, 0), 1);
    }

    #[test]
    fn zero_and_garbage_fall_through() {
        // Requested 0 = auto -> env.
        assert_eq!(resolve_from(Some(0), Some("4"), 16), 4);
        // Env 0 or unparsable = auto -> detected.
        assert_eq!(resolve_from(None, Some("0"), 5), 5);
        assert_eq!(resolve_from(None, Some("lots"), 5), 5);
        assert_eq!(resolve_from(Some(0), Some("nope"), 7), 7);
    }

    #[test]
    fn real_resolver_returns_at_least_one() {
        assert!(resolve_worker_count(None) >= 1);
        assert_eq!(resolve_worker_count(Some(5)), 5);
    }
}
