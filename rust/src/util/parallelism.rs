//! Worker-count resolution for the parallel search engine.
//!
//! One precedence order, used everywhere a worker pool is sized:
//!
//!   1. an explicit request (`--threads N` on the CLI, or
//!      [`crate::api::PlanRequest::threads`] in the API),
//!   2. the `GALVATRON_THREADS` environment variable,
//!   3. [`std::thread::available_parallelism`].
//!
//! A value of `0` at any level means "auto" and falls through to the next
//! source, so `GALVATRON_THREADS=0` behaves like the variable being unset.

/// Environment variable consulted when no explicit thread count is given.
pub const THREADS_ENV: &str = "GALVATRON_THREADS";

/// Resolve the worker count for a search run. `requested` is the explicit
/// CLI/API value (`None` or `Some(0)` = auto).
pub fn resolve_worker_count(requested: Option<usize>) -> usize {
    let detected = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    resolve_from(requested, std::env::var(THREADS_ENV).ok().as_deref(), detected)
}

/// Pure core of [`resolve_worker_count`] with every input explicit, so the
/// precedence order is testable without mutating process environment.
///
/// Precedence: `requested` > `env` > `detected`; zero or unparsable values
/// fall through to the next source; the result is always >= 1.
pub fn resolve_from(requested: Option<usize>, env: Option<&str>, detected: usize) -> usize {
    if let Some(n) = requested {
        if n >= 1 {
            return n;
        }
    }
    if let Some(s) = env {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    detected.max(1)
}

/// A cap on the total number of engine worker threads active at once,
/// shared by every concurrent search in the process.
///
/// One-shot CLI runs never install a budget: a single search owns the
/// machine and sizes its pool exactly as requested, byte- and
/// thread-count-identical to the historical behavior. The serve daemon
/// installs one at startup (see [`install_worker_budget`]) so that
/// concurrent plan requests multiplex the same cores at wave granularity
/// instead of each spawning a full pool and oversubscribing.
///
/// Grants never block and are always at least one worker, so a flood of
/// requests degrades toward one-thread-per-search execution instead of
/// deadlocking or starving anyone. Worker counts are proven not to affect
/// plan bytes (the determinism gates), so granting fewer threads than
/// requested never changes a result.
pub struct WorkerBudget {
    capacity: usize,
    active: std::sync::Mutex<usize>,
}

impl WorkerBudget {
    pub fn new(capacity: usize) -> WorkerBudget {
        WorkerBudget { capacity: capacity.max(1), active: std::sync::Mutex::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Workers currently checked out (diagnostics and tests).
    pub fn active(&self) -> usize {
        *self.active.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Check out up to `want` workers: the grant is `want` capped by the
    /// capacity still free, but never less than one — a search always
    /// makes progress on its own thread.
    pub fn acquire(&self, want: usize) -> WorkerGrant<'_> {
        let want = want.max(1);
        let mut active = self.active.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let free = self.capacity.saturating_sub(*active);
        let granted = want.min(free).max(1);
        *active += granted;
        WorkerGrant { budget: Some(self), granted }
    }
}

/// RAII grant from [`WorkerBudget::acquire`]; returns its workers to the
/// budget on drop.
pub struct WorkerGrant<'a> {
    budget: Option<&'a WorkerBudget>,
    granted: usize,
}

impl WorkerGrant<'_> {
    pub fn workers(&self) -> usize {
        self.granted
    }
}

impl Drop for WorkerGrant<'_> {
    fn drop(&mut self) {
        if let Some(budget) = self.budget {
            let mut active =
                budget.active.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            *active = active.saturating_sub(self.granted);
        }
    }
}

static BUDGET: std::sync::OnceLock<WorkerBudget> = std::sync::OnceLock::new();

/// Install the process-wide worker budget. The first call wins (returns
/// `true`); later calls are no-ops (`false`). Plain CLI runs never call
/// this, so their searches keep exactly the pool size they resolved.
pub fn install_worker_budget(capacity: usize) -> bool {
    let mut installed = false;
    BUDGET.get_or_init(|| {
        installed = true;
        WorkerBudget::new(capacity)
    });
    installed
}

/// The installed process-wide budget, if any.
pub fn worker_budget() -> Option<&'static WorkerBudget> {
    BUDGET.get()
}

/// Check out up to `want` workers from the process-wide budget. Without
/// an installed budget the grant is simply `want` — the zero-overhead
/// CLI fast path.
pub fn acquire_workers(want: usize) -> WorkerGrant<'static> {
    match BUDGET.get() {
        Some(budget) => budget.acquire(want),
        None => WorkerGrant { budget: None, granted: want.max(1) },
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn explicit_request_wins_over_everything() {
        assert_eq!(resolve_from(Some(3), Some("8"), 16), 3);
        assert_eq!(resolve_from(Some(1), Some("8"), 16), 1);
    }

    #[test]
    fn env_wins_over_detection() {
        assert_eq!(resolve_from(None, Some("8"), 16), 8);
        assert_eq!(resolve_from(None, Some(" 2 "), 16), 2);
    }

    #[test]
    fn detection_is_the_fallback() {
        assert_eq!(resolve_from(None, None, 6), 6);
        assert_eq!(resolve_from(None, None, 0), 1);
    }

    #[test]
    fn zero_and_garbage_fall_through() {
        // Requested 0 = auto -> env.
        assert_eq!(resolve_from(Some(0), Some("4"), 16), 4);
        // Env 0 or unparsable = auto -> detected.
        assert_eq!(resolve_from(None, Some("0"), 5), 5);
        assert_eq!(resolve_from(None, Some("lots"), 5), 5);
        assert_eq!(resolve_from(Some(0), Some("nope"), 7), 7);
    }

    #[test]
    fn real_resolver_returns_at_least_one() {
        assert!(resolve_worker_count(None) >= 1);
        assert_eq!(resolve_worker_count(Some(5)), 5);
    }

    // The budget is exercised on instances only: installing the global
    // OnceLock here would leak into every other unit test in this binary.

    #[test]
    fn budget_caps_grants_at_capacity() {
        let budget = WorkerBudget::new(4);
        let a = budget.acquire(3);
        assert_eq!(a.workers(), 3);
        let b = budget.acquire(3);
        assert_eq!(b.workers(), 1, "only one worker left under the cap");
        assert_eq!(budget.active(), 4);
    }

    #[test]
    fn exhausted_budget_still_grants_one_worker() {
        let budget = WorkerBudget::new(2);
        let a = budget.acquire(2);
        assert_eq!(a.workers(), 2);
        // Over-committed rather than blocked: progress beats fairness.
        let b = budget.acquire(8);
        assert_eq!(b.workers(), 1);
        assert_eq!(budget.active(), 3);
    }

    #[test]
    fn dropping_a_grant_returns_its_workers() {
        let budget = WorkerBudget::new(4);
        let a = budget.acquire(4);
        assert_eq!(budget.active(), 4);
        drop(a);
        assert_eq!(budget.active(), 0);
        assert_eq!(budget.acquire(4).workers(), 4);
    }

    #[test]
    fn zero_inputs_are_clamped_to_one() {
        let budget = WorkerBudget::new(0);
        assert_eq!(budget.capacity(), 1);
        assert_eq!(budget.acquire(0).workers(), 1);
    }
}
