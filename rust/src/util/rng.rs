//! Deterministic PRNG (substrate: the `rand` crate is unavailable offline).
//!
//! SplitMix64 core with helpers for uniform/normal/categorical sampling.
//! Used by the synthetic data generator, the property-based tests, and the
//! workload generators in the benches.

/// SplitMix64: tiny, fast, good equidistribution, trivially seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller output.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare_normal.take() {
            return s;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut rng = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(5);
        let mut hits = [0u32; 3];
        for _ in 0..30_000 {
            hits[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "{hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }
}
