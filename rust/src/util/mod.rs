//! Shared utilities: JSON, RNG, CLI parsing, tables, and a bench harness.
//!
//! These are substrates we implement ourselves because the image's offline
//! crate cache only contains the `xla` dependency closure (no serde_json,
//! clap, rand, or criterion) — see DESIGN.md §2.

pub mod bench;
pub mod cli;
pub mod diag;
pub mod json;
pub mod parallelism;
pub mod rng;
pub mod table;

/// Bytes in one mebibyte / gibibyte, as f64 for cost arithmetic.
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Format a byte count for human output.
pub fn fmt_bytes(b: f64) -> String {
    if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.1} MiB", b / MIB)
    } else {
        format!("{:.0} B", b)
    }
}

/// True iff n is a power of two (and nonzero).
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// Powers of two from 1 up to and including `n` (n must be a power of two).
pub fn pow2_divisors(n: usize) -> Vec<usize> {
    assert!(is_pow2(n), "{n} is not a power of two");
    let mut out = Vec::new();
    let mut d = 1;
    while d <= n {
        out.push(d);
        d *= 2;
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn pow2_helpers() {
        assert!(is_pow2(1) && is_pow2(8) && !is_pow2(6) && !is_pow2(0));
        assert_eq!(pow2_divisors(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(3.0 * MIB), "3.0 MiB");
        assert_eq!(fmt_bytes(2.5 * GIB), "2.50 GiB");
    }
}
