//! Per-request warning sink.
//!
//! Library code emits operational warnings (ignored cache files, IO
//! hiccups, provenance mismatches) through [`warn`]. By default they go
//! to stderr as `warning: <msg>` — byte-identical to the historical
//! `eprintln!` behavior of the plain CLI paths. A caller that owns a
//! request boundary (the serve daemon, `check --json`) installs a
//! collector with [`capture`], which gathers every warning emitted on
//! the current thread for the closure's duration and returns them
//! alongside the closure's result, so they can be surfaced as a
//! structured `warnings` array instead of interleaving with protocol
//! output on a shared stderr.
//!
//! The sink is thread-local: a collector never sees warnings from other
//! threads. Every current [`warn`] call site runs on the thread that
//! initiated the request (the engine's wave workers do not warn), so a
//! per-request `capture` around the planner entry point is complete.

use std::cell::RefCell;

thread_local! {
    /// Stack of active collectors on this thread; [`warn`] appends to the
    /// innermost one, falling back to stderr when the stack is empty.
    static COLLECTORS: RefCell<Vec<Vec<String>>> = const { RefCell::new(Vec::new()) };
}

/// Emit an operational warning. Captured by the innermost active
/// [`capture`] on this thread; otherwise printed to stderr as
/// `warning: <msg>` (the plain-CLI behavior).
pub fn warn(msg: &str) {
    let captured = COLLECTORS.with(|c| {
        let mut stack = c.borrow_mut();
        match stack.last_mut() {
            Some(frame) => {
                frame.push(msg.to_string());
                true
            }
            None => false,
        }
    });
    if !captured {
        eprintln!("warning: {msg}");
    }
}

/// Run `f` with a warning collector installed on this thread, returning
/// its result together with every warning emitted while it ran. Nests:
/// an inner `capture` shadows the outer one for its duration.
pub fn capture<T>(f: impl FnOnce() -> T) -> (T, Vec<String>) {
    struct Frame;
    impl Drop for Frame {
        fn drop(&mut self) {
            COLLECTORS.with(|c| {
                c.borrow_mut().pop();
            });
        }
    }
    COLLECTORS.with(|c| c.borrow_mut().push(Vec::new()));
    let frame = Frame;
    let out = f();
    let warnings = COLLECTORS.with(|c| {
        c.borrow_mut()
            .last_mut()
            .map(std::mem::take)
            .unwrap_or_default()
    });
    drop(frame);
    (out, warnings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_collects_warnings_and_returns_result() {
        let (value, warnings) = capture(|| {
            warn("first");
            warn("second");
            42
        });
        assert_eq!(value, 42);
        assert_eq!(warnings, vec!["first".to_string(), "second".to_string()]);
    }

    #[test]
    fn capture_is_empty_when_nothing_warned() {
        let ((), warnings) = capture(|| {});
        assert!(warnings.is_empty());
    }

    #[test]
    fn nested_capture_shadows_the_outer_collector() {
        let ((inner_warnings, ()), outer_warnings) = capture(|| {
            warn("outer-before");
            let ((), inner) = capture(|| warn("inner"));
            warn("outer-after");
            (inner, ())
        });
        assert_eq!(inner_warnings, vec!["inner".to_string()]);
        assert_eq!(
            outer_warnings,
            vec!["outer-before".to_string(), "outer-after".to_string()]
        );
    }

    #[test]
    fn collector_is_removed_after_capture() {
        let ((), warnings) = capture(|| warn("kept"));
        assert_eq!(warnings.len(), 1);
        // With no active collector this must not panic (routes to stderr).
        warn("stderr-bound");
    }
}
