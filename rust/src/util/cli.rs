//! Tiny CLI argument parser (substrate: clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (not including argv[0]). `flag_names` lists options
    /// that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(value) = iter.next() {
                    // Any option not declared as a flag takes the next token
                    // as its value — even one that itself starts with "--"
                    // (e.g. `--models --foo`); the old lookahead silently
                    // turned such options into flags and re-parsed their
                    // value as a separate option.
                    out.options.insert(stripped.to_string(), value);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn mixed_forms() {
        let a = parse("table2 --memory 16 --model=bert-huge-32 --verbose", &["verbose"]);
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.get("memory"), Some("16"));
        assert_eq!(a.get("model"), Some("bert-huge-32"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast", &[]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("--dry-run --n 4", &["dry-run"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.usize("n", 0).unwrap(), 4);
    }

    #[test]
    fn option_value_may_start_with_dashes() {
        // Regression: `--models --foo` used to silently become two flags.
        let a = parse("table2 --models --foo --memory 16", &[]);
        assert_eq!(a.get("models"), Some("--foo"));
        assert_eq!(a.get("memory"), Some("16"));
        assert!(a.flags.is_empty());
        // Declared flags still win over value consumption.
        let a = parse("--verbose --models m1", &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("models"), Some("m1"));
    }

    #[test]
    fn typed_errors() {
        let a = parse("--n x", &[]);
        assert!(a.usize("n", 0).is_err());
        assert_eq!(a.usize("m", 7).unwrap(), 7);
    }
}
