//! Microbatch schedules: the per-device task orders of 1F1B-Flush
//! (PipeDream-Flush) and GPipe.
//!
//! 1F1B-Flush for stage s of P with m microbatches:
//!   warmup:  min(P - s, m) forwards
//!   steady:  alternate (backward, forward) while forwards remain
//!   flush:   remaining backwards
//! GPipe: all m forwards, then all m backwards.

use crate::cost::pipeline::Schedule;

/// Task phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Forward,
    Backward,
}

/// One schedulable unit on a stage device group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Task {
    pub microbatch: usize,
    pub phase: Phase,
}

/// The fixed task order device `stage` (0-based) executes.
pub fn device_task_order(schedule: Schedule, stage: usize, p: usize, m: usize) -> Vec<Task> {
    assert!(stage < p && m >= 1);
    let mut out = Vec::with_capacity(2 * m);
    match schedule {
        Schedule::GPipe => {
            for j in 0..m {
                out.push(Task { microbatch: j, phase: Phase::Forward });
            }
            for j in (0..m).rev() {
                out.push(Task { microbatch: j, phase: Phase::Backward });
            }
        }
        Schedule::OneFOneB => {
            let warmup = (p - stage).min(m);
            let mut next_fwd = 0usize;
            let mut next_bwd = 0usize;
            for _ in 0..warmup {
                out.push(Task { microbatch: next_fwd, phase: Phase::Forward });
                next_fwd += 1;
            }
            // Steady 1F1B.
            while next_fwd < m {
                out.push(Task { microbatch: next_bwd, phase: Phase::Backward });
                next_bwd += 1;
                out.push(Task { microbatch: next_fwd, phase: Phase::Forward });
                next_fwd += 1;
            }
            // Flush.
            while next_bwd < m {
                out.push(Task { microbatch: next_bwd, phase: Phase::Backward });
                next_bwd += 1;
            }
        }
    }
    out
}

/// Max microbatches simultaneously holding forward state under the order
/// (sanity tool for tests: live = #fwd issued - #bwd completed).
pub fn max_live(order: &[Task]) -> usize {
    let mut live = 0usize;
    let mut peak = 0usize;
    for t in order {
        match t.phase {
            Phase::Forward => {
                live += 1;
                peak = peak.max(live);
            }
            Phase::Backward => live -= 1,
        }
    }
    peak
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_order() {
        let o = device_task_order(Schedule::GPipe, 0, 4, 3);
        assert_eq!(o.len(), 6);
        assert!(o[..3].iter().all(|t| t.phase == Phase::Forward));
        assert!(o[3..].iter().all(|t| t.phase == Phase::Backward));
        // GPipe backwards run in reverse microbatch order.
        assert_eq!(o[3].microbatch, 2);
        assert_eq!(max_live(&o), 3);
    }

    #[test]
    fn onefoneb_live_counts_match_theory() {
        // Paper §II-B: stage s of P keeps P-s microbatches live.
        let (p, m) = (4, 8);
        for s in 0..p {
            let o = device_task_order(Schedule::OneFOneB, s, p, m);
            assert_eq!(o.len(), 2 * m);
            assert_eq!(max_live(&o), p - s, "stage {s}");
        }
    }

    #[test]
    fn onefoneb_all_microbatches_covered() {
        let o = device_task_order(Schedule::OneFOneB, 1, 4, 6);
        for j in 0..6 {
            assert!(o.iter().any(|t| t.microbatch == j && t.phase == Phase::Forward));
            assert!(o.iter().any(|t| t.microbatch == j && t.phase == Phase::Backward));
        }
    }

    #[test]
    fn onefoneb_bwd_follows_own_fwd() {
        // A device never backwards a microbatch it hasn't forwarded.
        for s in 0..4 {
            let o = device_task_order(Schedule::OneFOneB, s, 4, 8);
            let mut fwd_seen = vec![false; 8];
            for t in o {
                match t.phase {
                    Phase::Forward => fwd_seen[t.microbatch] = true,
                    Phase::Backward => assert!(fwd_seen[t.microbatch]),
                }
            }
        }
    }

    #[test]
    fn fewer_microbatches_than_stages() {
        let o = device_task_order(Schedule::OneFOneB, 0, 8, 2);
        assert_eq!(o.len(), 4);
        assert_eq!(max_live(&o), 2);
    }

    #[test]
    fn last_stage_strict_alternation() {
        // Stage P-1 warms up exactly 1 forward, then strictly alternates.
        let o = device_task_order(Schedule::OneFOneB, 3, 4, 6);
        assert_eq!(o[0].phase, Phase::Forward);
        assert_eq!(o[1].phase, Phase::Backward);
        assert_eq!(o[1].microbatch, 0);
        assert_eq!(max_live(&o), 1);
    }
}
