//! Discrete-event simulator of distributed pipeline training — the
//! "testbed" that substitutes the paper's GPU clusters (DESIGN.md §2).
//!
//! The simulator executes a [`ParallelPlan`] at (stage, microbatch, phase)
//! task granularity with explicit scheduling:
//!
//!   * per-stage device groups follow the real 1F1B-Flush (or GPipe)
//!     microbatch order, including warmup / steady / flush phases;
//!   * stage-boundary activations and gradients ride point-to-point links
//!     that serialize transfers (FIFO per link);
//!   * task durations come from the same physical primitives as the cost
//!     estimator (FLOPs / bandwidths / contention) but the *schedule* is
//!     simulated, not summed — so Eq. 9 is an approximation of this ground
//!     truth, which is exactly the relationship Fig. 7 measures;
//!   * per-stage memory is tracked as an allocation timeline
//!     (model states + live forward stashes + backward spikes) and the
//!     high-water mark is reported.

pub mod schedule;

use crate::cluster::ClusterSpec;
use crate::cost::estimator::CostEstimator;
use crate::cost::model::CostModel;
use crate::cost::pipeline::Schedule;
use crate::model::{ModelProfile, TrainConfig};
use crate::parallel::memory::LayerMemory;
use crate::parallel::ParallelPlan;

pub use schedule::{device_task_order, Phase, Task};

/// One simulated execution record (for Gantt-style visualization).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub stage: usize,
    pub microbatch: usize,
    pub phase: Phase,
    pub start: f64,
    pub end: f64,
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end iteration time, seconds.
    pub iter_time: f64,
    /// Samples per second.
    pub throughput: f64,
    /// Per-stage peak memory, bytes.
    pub stage_peak_mem: Vec<f64>,
    /// Per-stage device memory capacity (the assigned island's budget),
    /// bytes — the ceiling the allocation timeline is checked against.
    pub stage_capacity: Vec<f64>,
    /// Per-stage busy (non-idle) time, seconds.
    pub stage_busy: Vec<f64>,
    /// Per-stage bubble fraction: 1 - busy/iter_time.
    pub bubble_fraction: Vec<f64>,
    /// Per-stage execution time of one microbatch (fwd+bwd, no sync).
    pub stage_mb_time: Vec<f64>,
    /// Full task trace.
    pub trace: Vec<TraceEvent>,
}

impl SimReport {
    /// Time balance degree alpha_t over simulated stage times (Eq. 6).
    pub fn alpha_t(&self) -> f64 {
        let max = self.stage_mb_time.iter().cloned().fold(0.0, f64::max);
        let sum: f64 = self.stage_mb_time.iter().sum();
        if sum > 0.0 {
            1.0 - max / sum
        } else {
            0.0
        }
    }

    /// Whether every stage's simulated high-water mark fits its assigned
    /// island's memory capacity.
    pub fn fits_capacity(&self) -> bool {
        self.stage_peak_mem
            .iter()
            .zip(&self.stage_capacity)
            .all(|(peak, cap)| peak <= cap)
    }

    /// Memory balance degree alpha_m over simulated peaks (Eq. 6).
    pub fn alpha_m(&self) -> f64 {
        let max = self.stage_peak_mem.iter().cloned().fold(0.0, f64::max);
        let sum: f64 = self.stage_peak_mem.iter().sum();
        if sum > 0.0 {
            1.0 - max / sum
        } else {
            0.0
        }
    }
}

/// Per-stage precomputed durations and memory quantities.
struct StageModel {
    fwd: f64,
    bwd: f64,
    bwd_sync: f64,
    /// Forward stash bytes per microbatch (sum of O_f).
    f_bytes: f64,
    /// Backward spike peak within one microbatch (Eq. 2 walk minus stash).
    b_spike: f64,
    /// Static model-state bytes.
    ms_bytes: f64,
    /// p2p payload to the next stage, bytes.
    p2p_bytes: f64,
}

fn build_stage_models(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    overlap_slowdown: f64,
    train: TrainConfig,
    cost_model: &CostModel,
    sites: &[crate::cluster::StageSite],
) -> Vec<StageModel> {
    // Task durations come from each stage's assigned island (FLOP rate and
    // bus); identical to a single shared estimator on homogeneous clusters.
    // One estimator per distinct site class (not per stage) — see the
    // matching note in `cost::pipeline::plan_cost`.
    let n_classes = sites.iter().map(|s| s.class).max().map(|c| c as usize + 1).unwrap_or(1);
    let ests: Vec<CostEstimator> = (0..n_classes)
        .map(|c| {
            let site = sites
                .iter()
                .find(|s| s.class == c as u32)
                .unwrap_or_else(|| unreachable!("contiguous site class ids"))
                .clone();
            CostEstimator::with_site(cluster, plan.pp, overlap_slowdown, site)
                .with_train(train)
                .with_cost_model(cost_model.clone())
        })
        .collect();
    let b_m = plan.microbatch_size();
    let mut out = Vec::with_capacity(plan.pp);
    for s in 0..plan.pp {
        let est = &ests[sites[plan.slot_of(s)].class as usize];
        let range = plan.stage_layers(s);
        let mut fwd = 0.0;
        let mut bwd = 0.0;
        let mut bwd_sync = 0.0;
        let mut mems: Vec<LayerMemory> = Vec::new();
        let mut prev: Option<&crate::parallel::Strategy> = None;
        for li in range.clone() {
            let layer = &model.layers[li];
            let strat = &plan.strategies[li];
            let c = est.layer_cost(layer, strat, b_m, model.extra_params(li));
            fwd += c.fwd;
            bwd += c.bwd;
            bwd_sync += c.bwd_sync;
            if let Some(p) = prev {
                let r = est.transform_cost(layer, p, strat, b_m);
                fwd += r; // redistribution happens on the forward path
            }
            mems.push(c.mem);
            prev = Some(strat);
        }
        let ms_bytes: f64 = mems.iter().map(|m| m.o_ms).sum();
        let f_bytes: f64 = mems.iter().map(|m| m.o_f).sum();
        // Backward spike: Eq. 2 walk peak minus the plain stash.
        let mut prefix = 0.0;
        let mut walk: f64 = 0.0;
        for m in &mems {
            prefix += m.o_f;
            walk = walk.max(prefix + m.o_b);
        }
        let b_spike = (walk - f_bytes).max(0.0);
        let p2p_bytes = if s + 1 < plan.pp {
            let li = range.end - 1;
            let strat = &plan.strategies[li];
            model.layers[li].bnd_bytes * b_m / strat.batch_split() as f64
        } else {
            0.0
        };
        out.push(StageModel { fwd, bwd, bwd_sync, f_bytes, b_spike, ms_bytes, p2p_bytes });
    }
    out
}

/// Simulate one training iteration of `plan` under the default training
/// numerics (fp32 + Adam, no ZeRO).
pub fn simulate(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
) -> SimReport {
    simulate_with(model, cluster, plan, schedule, overlap_slowdown, TrainConfig::default())
}

/// [`simulate`] under explicit training numerics: the per-stage memory
/// timeline (and the capacity check in [`SimReport::fits_capacity`]) and
/// the parameter-collective wire bytes follow the dtype/optimizer/ZeRO
/// configuration. The default `train` reproduces [`simulate`]
/// bit-for-bit.
pub fn simulate_with(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
    train: TrainConfig,
) -> SimReport {
    simulate_costed(model, cluster, plan, schedule, overlap_slowdown, train, &CostModel::Analytic)
}

/// [`simulate_with`] under an explicit cost-model backend: task durations
/// come from the backend's compute efficiencies and link model, so a
/// calibrated plan can be cross-checked against the same cost theory that
/// produced it. The analytic backend reproduces [`simulate_with`]
/// bit-for-bit.
pub fn simulate_costed(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    plan: &ParallelPlan,
    schedule: Schedule,
    overlap_slowdown: f64,
    train: TrainConfig,
    cost_model: &CostModel,
) -> SimReport {
    let p = plan.pp;
    let m = plan.microbatches;
    let sites = cluster.stage_sites(p);
    let stages =
        build_stage_models(model, cluster, plan, overlap_slowdown, train, cost_model, &sites);
    let link_bw = cluster.pipeline_link_bw(p);

    // Fixed per-device task order (the real schedule).
    let orders: Vec<Vec<Task>> = (0..p).map(|s| device_task_order(schedule, s, p, m)).collect();

    // Completion times; f64::NAN = not done.
    let mut fwd_done = vec![vec![f64::NAN; m]; p];
    let mut bwd_done = vec![vec![f64::NAN; m]; p];
    // Arrival of inputs across links (serialized per link, FIFO).
    let mut fwd_arrival = vec![vec![f64::NAN; m]; p]; // activation into stage s
    let mut bwd_arrival = vec![vec![f64::NAN; m]; p]; // grad into stage s
    let mut link_fwd_clock = vec![0.0f64; p]; // link s -> s+1
    let mut link_bwd_clock = vec![0.0f64; p]; // link s+1 -> s
    for j in 0..m {
        fwd_arrival[0][j] = 0.0; // data loader feeds stage 0
    }

    let mut device_clock = vec![0.0f64; p];
    let mut next_idx = vec![0usize; p];
    let mut trace: Vec<TraceEvent> = Vec::with_capacity(2 * p * m);
    let mut busy = vec![0.0f64; p];
    // Memory timeline: (time, delta_bytes) per stage.
    let mut mem_events: Vec<Vec<(f64, f64)>> = vec![Vec::new(); p];

    let mut progressed = true;
    while progressed {
        progressed = false;
        for s in 0..p {
            while next_idx[s] < orders[s].len() {
                let task = orders[s][next_idx[s]];
                let (ready, dur) = match task.phase {
                    Phase::Forward => {
                        let arr = fwd_arrival[s][task.microbatch];
                        if arr.is_nan() {
                            break;
                        }
                        (arr, stages[s].fwd)
                    }
                    Phase::Backward => {
                        let arr = if s + 1 == p {
                            // Loss gradient is local once fwd finished.
                            fwd_done[s][task.microbatch]
                        } else {
                            bwd_arrival[s][task.microbatch]
                        };
                        if arr.is_nan() {
                            break;
                        }
                        let dur = if task.microbatch + 1 == m {
                            stages[s].bwd_sync
                        } else {
                            stages[s].bwd
                        };
                        (arr, dur)
                    }
                };
                let start = device_clock[s].max(ready);
                let end = start + dur;
                device_clock[s] = end;
                busy[s] += dur;
                trace.push(TraceEvent {
                    stage: s,
                    microbatch: task.microbatch,
                    phase: task.phase,
                    start,
                    end,
                });
                match task.phase {
                    Phase::Forward => {
                        fwd_done[s][task.microbatch] = end;
                        // Allocate the stash for this microbatch.
                        mem_events[s].push((start, stages[s].f_bytes));
                        if s + 1 < p {
                            let t = stages[s].p2p_bytes / link_bw;
                            let depart = link_fwd_clock[s].max(end);
                            link_fwd_clock[s] = depart + t;
                            fwd_arrival[s + 1][task.microbatch] = depart + t;
                        }
                    }
                    Phase::Backward => {
                        bwd_done[s][task.microbatch] = end;
                        // Spike during bwd, then free the stash.
                        mem_events[s].push((start, stages[s].b_spike));
                        mem_events[s].push((end, -stages[s].b_spike - stages[s].f_bytes));
                        if s > 0 {
                            let t = stages[s - 1].p2p_bytes / link_bw;
                            let depart = link_bwd_clock[s - 1].max(end);
                            link_bwd_clock[s - 1] = depart + t;
                            bwd_arrival[s - 1][task.microbatch] = depart + t;
                        }
                    }
                }
                next_idx[s] += 1;
                progressed = true;
            }
        }
    }
    assert!(
        next_idx.iter().enumerate().all(|(s, &i)| i == orders[s].len()),
        "simulation deadlocked: {next_idx:?}"
    );

    let iter_time = device_clock.iter().cloned().fold(0.0, f64::max);

    // Memory high-water per stage.
    let mut stage_peak_mem = Vec::with_capacity(p);
    for s in 0..p {
        let mut evs = std::mem::take(&mut mem_events[s]);
        // Ascending time; at equal timestamps apply frees before allocs
        // (a bwd ending exactly when the next fwd starts must not
        // double-count the stash).
        evs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        let mut cur = stages[s].ms_bytes;
        let mut peak = cur;
        for (_, d) in evs {
            cur += d;
            peak = peak.max(cur);
        }
        stage_peak_mem.push(peak);
    }

    let bubble_fraction: Vec<f64> = busy.iter().map(|b| 1.0 - b / iter_time).collect();
    let stage_mb_time: Vec<f64> = stages.iter().map(|st| st.fwd + st.bwd).collect();
    let stage_capacity: Vec<f64> =
        (0..p).map(|s| sites[plan.slot_of(s)].gpu.mem_bytes).collect();

    SimReport {
        iter_time,
        throughput: plan.batch as f64 / iter_time,
        stage_peak_mem,
        stage_capacity,
        stage_busy: busy,
        bubble_fraction,
        stage_mb_time,
        trace,
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::cluster::cluster_by_name;
    use crate::cost::pipeline::plan_cost;
    use crate::model::model_by_name;
    use crate::parallel::{Dim, Strategy};

    fn plan(pp: usize, batch: usize, m: usize, strat: Strategy, layers: usize) -> ParallelPlan {
        let base = layers / pp;
        let mut partition = vec![base; pp];
        let rem = layers - base * pp;
        for i in 0..rem {
            partition[i] += 1;
        }
        ParallelPlan {
            pp,
            partition,
            strategies: vec![strat; layers],
            batch,
            microbatches: m,
            stage_slots: None,
        }
    }

    #[test]
    fn every_microbatch_runs_once() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let r = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        // 2 phases x 4 stages x 8 microbatches.
        assert_eq!(r.trace.len(), 2 * 4 * 8);
        for s in 0..4 {
            for j in 0..8 {
                let f = r.trace.iter().filter(|e| e.stage == s && e.microbatch == j && e.phase == Phase::Forward).count();
                let b = r.trace.iter().filter(|e| e.stage == s && e.microbatch == j && e.phase == Phase::Backward).count();
                assert_eq!((f, b), (1, 1));
            }
        }
    }

    #[test]
    fn dependencies_respected() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 16, 4, Strategy::single(Dim::Dp, 2, false), 32);
        let r = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let find = |s: usize, j: usize, ph: Phase| {
            r.trace.iter().find(|e| e.stage == s && e.microbatch == j && e.phase == ph).unwrap()
        };
        for j in 0..4 {
            for s in 1..4 {
                assert!(find(s, j, Phase::Forward).start >= find(s - 1, j, Phase::Forward).end);
            }
            for s in 0..3 {
                assert!(find(s, j, Phase::Backward).start >= find(s + 1, j, Phase::Backward).end);
            }
            assert!(find(3, j, Phase::Backward).start >= find(3, j, Phase::Forward).end);
        }
    }

    #[test]
    fn estimator_close_to_simulator() {
        // Eq. 9 approximates the DES for homogeneous stages (<12%).
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let sim = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let est = plan_cost(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let rel = (est.iter_time - sim.iter_time).abs() / sim.iter_time;
        assert!(rel < 0.12, "estimator {} vs sim {} ({:.1}%)", est.iter_time, sim.iter_time, rel * 100.0);
    }

    #[test]
    fn ignoring_slowdown_underestimates() {
        // Fig. 7: estimation without the overlap slowdown is biased low.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(1, 8, 1, Strategy::single(Dim::Dp, 8, false), 32);
        let sim = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let est_no = plan_cost(&model, &cluster, &pl, Schedule::OneFOneB, 1.0);
        assert!(est_no.iter_time < sim.iter_time);
    }

    #[test]
    fn onefoneb_stage0_holds_more_memory() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let r = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        assert!(r.stage_peak_mem[0] > r.stage_peak_mem[3]);
    }

    #[test]
    fn gpipe_uses_more_memory_than_1f1b() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let g = simulate(&model, &cluster, &pl, Schedule::GPipe, 1.3);
        let f = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        assert!(g.stage_peak_mem[3] > f.stage_peak_mem[3]);
    }

    #[test]
    fn more_microbatches_less_bubble() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let p2 = plan(4, 32, 4, Strategy::single(Dim::Dp, 2, false), 32);
        let p8 = plan(4, 32, 16, Strategy::single(Dim::Dp, 2, false), 32);
        let r2 = simulate(&model, &cluster, &p2, Schedule::OneFOneB, 1.3);
        let r8 = simulate(&model, &cluster, &p8, Schedule::OneFOneB, 1.3);
        // Last stage bubble dominated by warmup: (P-1)/(m+P-1).
        assert!(r8.bubble_fraction[3] < r2.bubble_fraction[3]);
    }

    #[test]
    fn sim_matches_estimator_memory() {
        // The DES memory tracker and Eq. 2 accounting must agree.
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, true), 32);
        let sim = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let est = plan_cost(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        for s in 0..4 {
            let rel = (sim.stage_peak_mem[s] - est.stages[s].peak_mem).abs() / est.stages[s].peak_mem;
            assert!(rel < 0.05, "stage {s}: sim {} est {}", sim.stage_peak_mem[s], est.stages[s].peak_mem);
        }
    }

    #[test]
    fn stage_capacity_tracks_assigned_islands() {
        use crate::util::GIB;
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("hetero4").unwrap();
        let mut pl = plan(2, 8, 2, Strategy::single(Dim::Dp, 2, false), 32);
        // Place stage 0 (memory-heavy under 1F1B) on the A100-80G island.
        pl.stage_slots = Some(vec![1, 0]);
        let r = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        assert_eq!(r.stage_capacity, vec![80.0 * GIB, 24.0 * GIB]);
        // Homogeneous cluster: uniform capacity.
        let hom = cluster_by_name("titan8").unwrap();
        let pl = plan(2, 8, 2, Strategy::single(Dim::Dp, 4, false), 32);
        let r = simulate(&model, &hom, &pl, Schedule::OneFOneB, 1.3);
        assert_eq!(r.stage_capacity, vec![24.0 * GIB, 24.0 * GIB]);
    }

    #[test]
    fn lean_train_config_shrinks_sim_memory_only() {
        use crate::model::{Dtype, TrainConfig};
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let fp32 = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let lean = TrainConfig { dtype: Dtype::Bf16, zero: true, ..Default::default() };
        let bf16 = simulate_with(&model, &cluster, &pl, Schedule::OneFOneB, 1.3, lean);
        for s in 0..4 {
            assert!(
                bf16.stage_peak_mem[s] < fp32.stage_peak_mem[s],
                "stage {s}: {} !< {}",
                bf16.stage_peak_mem[s],
                fp32.stage_peak_mem[s]
            );
        }
        // Capacity is the device's, not the workload's.
        assert_eq!(bf16.stage_capacity, fp32.stage_capacity);
        // Compute stays fp32-calibrated, but the DP gradient all-reduce
        // rides the wire in bf16 — never slower, possibly faster.
        assert!(bf16.iter_time <= fp32.iter_time);
        // The default config delegates bit-for-bit.
        let dflt = simulate_with(
            &model,
            &cluster,
            &pl,
            Schedule::OneFOneB,
            1.3,
            TrainConfig::default(),
        );
        assert_eq!(dflt.stage_peak_mem, fp32.stage_peak_mem);
        assert_eq!(dflt.iter_time, fp32.iter_time);
    }

    #[test]
    fn synthetic_backend_simulates_bit_identically() {
        use crate::cost::{CostModel, ProfileDb};
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(4, 32, 8, Strategy::single(Dim::Dp, 2, false), 32);
        let analytic = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        let synthetic = CostModel::calibrated(ProfileDb::synthetic(&cluster));
        let cal = simulate_costed(
            &model,
            &cluster,
            &pl,
            Schedule::OneFOneB,
            1.3,
            TrainConfig::default(),
            &synthetic,
        );
        assert_eq!(cal.iter_time.to_bits(), analytic.iter_time.to_bits());
        assert_eq!(cal.stage_peak_mem, analytic.stage_peak_mem);
        // A derated backend slows the simulated schedule down.
        let mut db = ProfileDb::synthetic(&cluster);
        let half = db.ref_flops / 2.0;
        for s in &mut db.layers {
            s.effective_flops = half;
        }
        let slow = simulate_costed(
            &model,
            &cluster,
            &pl,
            Schedule::OneFOneB,
            1.3,
            TrainConfig::default(),
            &CostModel::calibrated(db),
        );
        assert!(slow.iter_time > analytic.iter_time);
    }

    #[test]
    fn single_stage_no_bubble() {
        let model = model_by_name("bert-huge-32").unwrap();
        let cluster = cluster_by_name("titan8").unwrap();
        let pl = plan(1, 8, 1, Strategy::single(Dim::Dp, 8, false), 32);
        let r = simulate(&model, &cluster, &pl, Schedule::OneFOneB, 1.3);
        assert!(r.bubble_fraction[0].abs() < 1e-9);
    }
}
