//! Spec and cluster lints: `ModelSpec` smells flagged on the raw JSON
//! (so each finding carries a precise `$.blocks[i]` path even when
//! `ModelSpec::from_json` rejects the document wholesale), plus island
//! configurations that can never host the model.

use crate::model::ModelSpec;
use crate::util::json::Json;
use crate::util::GIB;

use super::{CheckContext, Checker, Diagnostic};

struct Rule {
    code: &'static str,
    name: &'static str,
    description: &'static str,
    cheap: bool,
    check: fn(&CheckContext, &mut Vec<Diagnostic>),
}

impl Checker for Rule {
    fn code(&self) -> &'static str {
        self.code
    }
    fn name(&self) -> &'static str {
        self.name
    }
    fn description(&self) -> &'static str {
        self.description
    }
    fn cheap(&self) -> bool {
        self.cheap
    }
    fn check(&self, ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
        (self.check)(ctx, out);
    }
}

pub fn rules() -> Vec<Box<dyn Checker>> {
    vec![
        Box::new(Rule {
            code: "GAL0020",
            name: "spec-invalid",
            description: "model spec compiles under ModelSpec::from_json",
            cheap: false,
            check: spec_invalid,
        }),
        Box::new(Rule {
            code: "GAL0021",
            name: "moe-routing",
            description: "MoE routing is satisfiable: 1 <= top_k <= experts, experts >= 2",
            cheap: false,
            check: moe_routing,
        }),
        Box::new(Rule {
            code: "GAL0022",
            name: "gqa-heads",
            description: "grouped-query attention: kv_heads divides heads",
            cheap: false,
            check: gqa_heads,
        }),
        Box::new(Rule {
            code: "GAL0023",
            name: "attention-window",
            description: "attention window is positive and no wider than seq",
            cheap: false,
            check: attention_window,
        }),
        Box::new(Rule {
            code: "GAL0024",
            name: "window-redundant",
            description: "window == seq is full attention spelled the long way",
            cheap: false,
            check: window_redundant,
        }),
        Box::new(Rule {
            code: "GAL0030",
            name: "model-never-fits",
            description: "cluster's total memory can hold the model weights at all",
            cheap: true,
            check: model_never_fits,
        }),
        Box::new(Rule {
            code: "GAL0031",
            name: "island-share",
            description: "every island can hold its uniform share of the model weights",
            cheap: false,
            check: island_share,
        }),
    ]
}

// ---- ModelSpec smells (raw JSON) ----------------------------------------

fn spec_invalid(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_spec else { return };
    if let Err(e) = ModelSpec::from_json(raw) {
        out.push(Diagnostic::error(
            "GAL0020",
            "$",
            format!("model spec does not compile: {}", e.reason),
        ));
    }
}

/// Visit each block object in a raw spec, tolerating shapes
/// `ModelSpec::from_json` would reject — lints point at what they can.
fn each_block(raw: &Json, mut f: impl FnMut(usize, &Json)) {
    let Some(blocks) = raw.get("blocks").and_then(Json::as_arr) else { return };
    for (i, b) in blocks.iter().enumerate() {
        if matches!(b, Json::Obj(_)) {
            f(i, b);
        }
    }
}

fn field(b: &Json, key: &str) -> Option<usize> {
    b.get(key).and_then(Json::as_usize)
}

fn moe_routing(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_spec else { return };
    each_block(raw, |i, b| {
        let Some(moe) = b.get("moe") else { return };
        let (Some(experts), Some(top_k)) = (field(moe, "experts"), field(moe, "top_k"))
        else {
            return; // malformed moe object is GAL0020's finding
        };
        if top_k == 0 || top_k > experts {
            out.push(
                Diagnostic::error(
                    "GAL0021",
                    format!("$.blocks[{i}].moe"),
                    format!("top_k {top_k} cannot route over {experts} experts"),
                )
                .suggest(format!("pick top_k in 1..={experts}")),
            );
        }
        if experts < 2 {
            out.push(
                Diagnostic::error(
                    "GAL0021",
                    format!("$.blocks[{i}].moe"),
                    format!("{experts} expert(s) is not a mixture"),
                )
                .suggest("drop the moe section for a dense FFN, or use >= 2 experts"),
            );
        }
    });
}

fn gqa_heads(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_spec else { return };
    each_block(raw, |i, b| {
        let Some(kv) = field(b, "kv_heads") else { return };
        let Some(heads) = field(b, "heads") else { return };
        if kv == 0 || kv > heads || heads % kv != 0 {
            out.push(
                Diagnostic::error(
                    "GAL0022",
                    format!("$.blocks[{i}].kv_heads"),
                    format!("kv_heads {kv} must divide heads {heads}"),
                )
                .suggest(format!("use a divisor of {heads} (kv_heads == heads is dense MHA)")),
            );
        }
    });
}

fn attention_window(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_spec else { return };
    each_block(raw, |i, b| {
        let Some(w) = field(b, "window") else { return };
        let Some(seq) = field(b, "seq") else { return };
        if w == 0 || w > seq {
            out.push(
                Diagnostic::error(
                    "GAL0023",
                    format!("$.blocks[{i}].window"),
                    format!("attention window {w} must be in 1..=seq ({seq})"),
                )
                .suggest("widen seq or shrink the window; omit window for full attention"),
            );
        }
    });
}

fn window_redundant(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_spec else { return };
    each_block(raw, |i, b| {
        let (Some(w), Some(seq)) = (field(b, "window"), field(b, "seq")) else { return };
        if w == seq {
            out.push(
                Diagnostic::note(
                    "GAL0024",
                    format!("$.blocks[{i}].window"),
                    format!("window {w} equals seq: this is full attention spelled the long way"),
                )
                .suggest("drop the window key"),
            );
        }
    });
}

// ---- cluster fit ---------------------------------------------------------

/// fp32 weights alone — the loosest possible necessary condition; optimizer
/// state, gradients and activations only add to it.
const WEIGHT_BYTES_PER_PARAM: f64 = 4.0;

fn model_never_fits(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(m) = ctx.model else { return };
    let Some(c) = ctx.cluster else { return };
    let weight_bytes = m.total_params() * WEIGHT_BYTES_PER_PARAM;
    let capacity: f64 = c.islands.iter().map(|i| i.count as f64 * i.gpu.mem_bytes).sum();
    if weight_bytes > capacity {
        out.push(
            Diagnostic::error(
                "GAL0030",
                "$.cluster",
                format!(
                    "{} needs {:.1} GiB for fp32 weights alone but {} totals {:.1} GiB: \
                     no parallel plan can ever fit",
                    m.name,
                    weight_bytes / GIB,
                    c.name,
                    capacity / GIB
                ),
            )
            .suggest("use a larger cluster or a smaller model"),
        );
    }
}

fn island_share(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(m) = ctx.model else { return };
    let Some(c) = ctx.cluster else { return };
    let weight_bytes = m.total_params() * WEIGHT_BYTES_PER_PARAM;
    if weight_bytes
        > c.islands.iter().map(|i| i.count as f64 * i.gpu.mem_bytes).sum::<f64>()
    {
        return; // GAL0030 already says it can never fit anywhere.
    }
    let share = weight_bytes / c.n_devices() as f64;
    for (i, isl) in c.islands.iter().enumerate() {
        if isl.gpu.mem_bytes < share {
            out.push(Diagnostic::warn(
                "GAL0031",
                "$.cluster",
                format!(
                    "island {i} ({}x{}) holds {:.1} GiB/device but a uniform weight shard \
                     is {:.1} GiB: stages placed there will need aggressive offload or \
                     skewed partitions",
                    isl.count,
                    isl.gpu.name,
                    isl.gpu.mem_bytes / GIB,
                    share / GIB
                ),
            ));
        }
    }
}
