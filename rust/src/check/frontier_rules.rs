//! Frontier-artifact rules (the `check --frontier` form): a
//! `FrontierReport` must parse, its points must actually be mutually
//! non-dominated, every embedded plan must pass the plan gate against the
//! model and cluster it names, and each point's headline objectives must
//! agree with the plan it embeds.

use crate::advise::{dominates, fleet_cost_per_hour};
use crate::api::PlanError;

use super::{CheckContext, Checker, Diagnostic};

struct Rule {
    code: &'static str,
    name: &'static str,
    description: &'static str,
    cheap: bool,
    check: fn(&CheckContext, &mut Vec<Diagnostic>),
}

impl Checker for Rule {
    fn code(&self) -> &'static str {
        self.code
    }
    fn name(&self) -> &'static str {
        self.name
    }
    fn description(&self) -> &'static str {
        self.description
    }
    fn cheap(&self) -> bool {
        self.cheap
    }
    fn check(&self, ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
        (self.check)(ctx, out);
    }
}

pub fn rules() -> Vec<Box<dyn Checker>> {
    vec![
        Box::new(Rule {
            code: "GAL0040",
            name: "frontier-invalid",
            description: "frontier artifact parses under FrontierReport::from_json",
            cheap: false,
            check: frontier_invalid,
        }),
        Box::new(Rule {
            code: "GAL0041",
            name: "frontier-dominated",
            description: "no frontier point is Pareto-dominated by another",
            cheap: false,
            check: frontier_dominated,
        }),
        Box::new(Rule {
            code: "GAL0042",
            name: "frontier-embedded-plan",
            description: "every embedded plan passes the plan gate for its model/cluster",
            cheap: false,
            check: frontier_embedded_plan,
        }),
        Box::new(Rule {
            code: "GAL0043",
            name: "frontier-point-consistency",
            description: "point objectives agree with the embedded plan and price table",
            cheap: false,
            check: frontier_point_consistency,
        }),
    ]
}

fn frontier_invalid(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(err) = &ctx.frontier_error else { return };
    out.push(
        Diagnostic::error("GAL0040", "$", format!("frontier artifact rejected: {err}")).suggest(
            "regenerate with `galvatron advise --out frontier.json`; artifacts use a strict \
             key schema",
        ),
    );
}

fn frontier_dominated(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(frontier) = ctx.frontier else { return };
    for (j, b) in frontier.points.iter().enumerate() {
        if let Some((i, a)) =
            frontier.points.iter().enumerate().find(|&(i, a)| i != j && dominates(a, b))
        {
            out.push(Diagnostic::error(
                "GAL0041",
                format!("$.points[{j}]"),
                format!(
                    "point '{}' is dominated by points[{i}] ('{}'): \
                     {:.2} vs {:.2} samples/s, {:.0} vs {:.0} headroom bytes, \
                     ${:.2}/hr vs ${:.2}/hr",
                    b.cluster,
                    a.cluster,
                    b.throughput,
                    a.throughput,
                    b.headroom_bytes,
                    a.headroom_bytes,
                    b.cost_per_hour,
                    a.cost_per_hour
                ),
            ));
        }
    }
}

fn frontier_embedded_plan(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(frontier) = ctx.frontier else { return };
    for (i, p) in frontier.points.iter().enumerate() {
        let path = format!("$.points[{i}].report");
        let model = match super::resolve_report_model(&p.report) {
            Ok(m) => m,
            Err(e) => {
                out.push(Diagnostic::error(
                    "GAL0042",
                    &path,
                    format!("embedded plan's model does not resolve: {e}"),
                ));
                continue;
            }
        };
        let cluster = match super::resolve_report_cluster(&p.report) {
            Ok(c) => c,
            Err(e) => {
                out.push(Diagnostic::error(
                    "GAL0042",
                    &path,
                    format!("embedded plan's cluster does not resolve: {e}"),
                ));
                continue;
            }
        };
        match super::gate(&model, &cluster, &p.report) {
            Ok(()) => {}
            Err(PlanError::InvalidArtifact { diagnostics }) => {
                for d in diagnostics {
                    // Re-anchor the gate's finding inside this point.
                    let sub = d.path.trim_start_matches('$');
                    out.push(Diagnostic::error(
                        "GAL0042",
                        format!("{path}{sub}"),
                        format!("embedded plan fails the gate: {}[{}] {}", d.severity, d.code, d.message),
                    ));
                }
            }
            Err(e) => {
                out.push(Diagnostic::error(
                    "GAL0042",
                    &path,
                    format!("embedded plan gate could not run: {e}"),
                ));
            }
        }
    }
}

fn frontier_point_consistency(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(frontier) = ctx.frontier else { return };
    for (i, p) in frontier.points.iter().enumerate() {
        if p.cluster != p.report.cluster {
            out.push(Diagnostic::error(
                "GAL0043",
                format!("$.points[{i}].cluster"),
                format!(
                    "point names cluster '{}' but its embedded plan names '{}'",
                    p.cluster, p.report.cluster
                ),
            ));
        }
        // Bit-exact: both numbers were serialized from the same f64.
        if p.throughput != p.report.throughput {
            out.push(Diagnostic::error(
                "GAL0043",
                format!("$.points[{i}].throughput"),
                format!(
                    "point claims {} samples/s but its embedded plan estimates {}",
                    p.throughput, p.report.throughput
                ),
            ));
        }
        // The price table is deterministic, so a resolvable cluster must
        // price to exactly the recorded $/hr.
        if let Ok(cluster) = super::resolve_report_cluster(&p.report) {
            let expected = fleet_cost_per_hour(&cluster);
            if p.cost_per_hour != expected {
                out.push(Diagnostic::error(
                    "GAL0043",
                    format!("$.points[{i}].cost_per_hour"),
                    format!(
                        "point prices '{}' at ${}/hr but the catalog prices it at ${}/hr",
                        p.cluster, p.cost_per_hour, expected
                    ),
                ));
            }
        }
        if !p.headroom_bytes.is_finite() {
            out.push(Diagnostic::error(
                "GAL0043",
                format!("$.points[{i}].headroom_bytes"),
                "headroom is not a finite number".to_string(),
            ));
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use crate::check::check_frontier_text;

    #[test]
    fn unparseable_frontier_is_gal0040() {
        let report = check_frontier_text("{\"not\": \"a frontier\"}");
        assert!(report.has_errors());
        assert!(report.errors().any(|d| d.code == "GAL0040"), "{}", report.render());
        // Not even JSON.
        let report = check_frontier_text("nonsense");
        assert!(report.errors().any(|d| d.code == "GAL0040"));
    }
}
