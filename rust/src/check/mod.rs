//! Static analysis over planner artifacts: a compiler-style rule engine
//! that verifies `PlanReport` and `ModelSpec` JSON before it flows into
//! `simulate --plan` (or, per the ROADMAP, a planning-as-a-service
//! daemon). The search enforces the paper's invariants implicitly while
//! it runs; this pass re-proves them on the *artifact*, so a hand-edited,
//! stale, or corrupted plan is rejected with a typed diagnostic instead
//! of silently simulating something else.
//!
//! The pieces:
//!   * [`Diagnostic`] — one finding: a stable `GAL0xxx` code, a
//!     [`Severity`], a message, a JSON-path span into the artifact, and
//!     an optional suggestion.
//!   * [`Checker`] — one rule; [`registry`] lists every rule across the
//!     three artifact classes (plan legality, artifact consistency,
//!     spec/cluster lints).
//!   * [`CheckReport`] — the findings of a run, renderable as a human
//!     table ([`CheckReport::render`]) or machine JSON
//!     ([`CheckReport::to_json`]).
//!   * [`gate`] — the cheap Error-severity subset that
//!     `PlanRequest::plan()` and `simulate --plan` run on every artifact,
//!     surfacing failures as [`PlanError::InvalidArtifact`].
//!
//! The CLI surface is `galvatron check` (see the README's "Verifying
//! plans and specs" section for the diagnostic-code table and the
//! exit-code contract).

pub mod frontier_rules;
pub mod plan_rules;
pub mod spec_rules;

use std::fmt;

use crate::api::{PlanError, PlanReport};
use crate::cluster::ClusterSpec;
use crate::model::{ModelProfile, ModelSpec};
use crate::util::json::Json;
use crate::util::GIB;

/// How bad a finding is. `Error` findings make `galvatron check` exit
/// non-zero and [`gate`] reject the artifact; `Warn`/`Note` are advisory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warn,
    Error,
}

impl Severity {
    /// Stable machine name ("error" / "warning" / "note").
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warning",
            Severity::Note => "note",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_str())
    }
}

/// One finding of a [`Checker`] rule.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable rule code, e.g. `"GAL0004"`. Codes never change meaning;
    /// retired codes are not reused.
    pub code: &'static str,
    pub severity: Severity,
    pub message: String,
    /// JSON-path span into the checked artifact, e.g.
    /// `"$.plan.microbatches"` (`"$"` for whole-artifact findings).
    pub path: String,
    /// Optional actionable hint.
    pub suggestion: Option<String>,
}

impl Diagnostic {
    pub fn new(
        code: &'static str,
        severity: Severity,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity,
            message: message.into(),
            path: path.into(),
            suggestion: None,
        }
    }

    pub fn error(code: &'static str, path: impl Into<String>, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Error, path, message)
    }

    pub fn warn(code: &'static str, path: impl Into<String>, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Warn, path, message)
    }

    pub fn note(code: &'static str, path: impl Into<String>, message: impl Into<String>) -> Self {
        Self::new(code, Severity::Note, path, message)
    }

    /// Attach an actionable suggestion.
    pub fn suggest(mut self, s: impl Into<String>) -> Self {
        self.suggestion = Some(s.into());
        self
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]: {} (at {})", self.severity, self.code, self.message, self.path)
    }
}

/// Everything a rule may look at. Fields are optional because different
/// entry points hold different artifacts (a plan check has no raw spec;
/// a spec check has no report); every rule skips silently when the data
/// it needs is absent.
#[derive(Default)]
pub struct CheckContext<'a> {
    /// Raw artifact text (OOM-marker rules look at exact bytes).
    pub plan_text: Option<&'a str>,
    /// Parsed artifact JSON (`None` when the text is not JSON at all).
    pub raw_plan: Option<&'a Json>,
    /// Typed report, when `PlanReport::from_json` accepted the artifact.
    pub report: Option<&'a PlanReport>,
    /// Error text of a failed `PlanReport` parse.
    pub parse_error: Option<String>,
    /// The resolved model the report refers to, or why it did not resolve.
    pub model: Option<&'a ModelProfile>,
    pub model_error: Option<String>,
    /// The resolved cluster (memory budget applied), or why not.
    pub cluster: Option<&'a ClusterSpec>,
    pub cluster_error: Option<String>,
    /// Raw model-spec JSON (the `check --model-file` form).
    pub raw_spec: Option<&'a Json>,
    /// Raw frontier-artifact JSON (the `check --frontier` form).
    pub raw_frontier: Option<&'a Json>,
    /// Typed frontier report, when `FrontierReport::from_json` accepted it.
    pub frontier: Option<&'a crate::advise::FrontierReport>,
    /// Error text of a failed `FrontierReport` parse.
    pub frontier_error: Option<String>,
}

/// One static-analysis rule.
pub trait Checker {
    /// Stable diagnostic code this rule emits (e.g. `"GAL0004"`).
    fn code(&self) -> &'static str;
    /// Short kebab-case rule name (e.g. `"microbatch-divisibility"`).
    fn name(&self) -> &'static str;
    /// One-line description for the rule catalog.
    fn description(&self) -> &'static str;
    /// Cheap rules additionally run inside the planner / `simulate --plan`
    /// gate on every artifact (no cost-model re-derivation allowed here).
    fn cheap(&self) -> bool {
        false
    }
    fn check(&self, ctx: &CheckContext, out: &mut Vec<Diagnostic>);
}

/// The findings of one [`run`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CheckReport {
    /// All findings, most severe first (then by code, then by path).
    pub diagnostics: Vec<Diagnostic>,
}

impl CheckReport {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn has_errors(&self) -> bool {
        self.errors().next().is_some()
    }

    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    /// Fold another report's findings in (the CLI checks several artifacts
    /// into one `--json` report).
    pub fn merge(&mut self, other: CheckReport) {
        self.diagnostics.extend(other.diagnostics);
        sort_diagnostics(&mut self.diagnostics);
    }

    /// Machine-readable form (`galvatron check --json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::num(self.count(Severity::Error) as f64)),
            ("warnings", Json::num(self.count(Severity::Warn) as f64)),
            ("notes", Json::num(self.count(Severity::Note) as f64)),
            (
                "diagnostics",
                Json::arr(self.diagnostics.iter().map(|d| {
                    let mut fields = vec![
                        ("code", Json::str(d.code)),
                        ("severity", Json::str(d.severity.as_str())),
                        ("message", Json::str(&d.message)),
                        ("path", Json::str(&d.path)),
                    ];
                    if let Some(s) = &d.suggestion {
                        fields.push(("suggestion", Json::str(s)));
                    }
                    Json::obj(fields)
                })),
            ),
        ])
    }

    /// Human rendering: one block per finding plus a severity tally.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{d}\n"));
            if let Some(s) = &d.suggestion {
                out.push_str(&format!("  help: {s}\n"));
            }
        }
        out.push_str(&format!(
            "{} error(s), {} warning(s), {} note(s)\n",
            self.count(Severity::Error),
            self.count(Severity::Warn),
            self.count(Severity::Note)
        ));
        out
    }
}

fn sort_diagnostics(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        b.severity
            .cmp(&a.severity)
            .then_with(|| a.code.cmp(b.code))
            .then_with(|| a.path.cmp(&b.path))
    });
}

/// Every rule, across all three artifact classes.
pub fn registry() -> Vec<Box<dyn Checker>> {
    let mut rules = plan_rules::rules();
    rules.extend(spec_rules::rules());
    rules.extend(frontier_rules::rules());
    rules
}

/// Run the full registry over a context.
pub fn run(ctx: &CheckContext) -> CheckReport {
    let mut diagnostics = Vec::new();
    for rule in registry() {
        rule.check(ctx, &mut diagnostics);
    }
    sort_diagnostics(&mut diagnostics);
    CheckReport { diagnostics }
}

/// Resolve the model a report refers to, exactly as `simulate --plan`
/// would: the embedded spec when present, else the zoo by name.
pub fn resolve_report_model(report: &PlanReport) -> Result<ModelProfile, PlanError> {
    match &report.model_spec {
        Some(spec) => Ok(spec.compile()?),
        None => crate::api::resolve_model_name(&report.model),
    }
}

/// Resolve the cluster a report refers to, with the recorded memory
/// budget applied on homogeneous clusters (heterogeneous clusters fix
/// per-island budgets via their GPU classes). A non-positive or
/// non-finite recorded budget is left unapplied — GAL0014 flags it.
pub fn resolve_report_cluster(report: &PlanReport) -> Result<ClusterSpec, PlanError> {
    let mut cluster = crate::api::resolve_cluster_name(&report.cluster)?;
    let gb = report.memory_budget_gb;
    if cluster.is_homogeneous() && gb.is_finite() && gb > 0.0 {
        cluster = cluster.with_memory_budget(gb * GIB);
    }
    Ok(cluster)
}

/// Check one plan-artifact text end to end: parse, resolve the model and
/// cluster it names, and run the full registry. Resolution failures are
/// findings (GAL0012/GAL0013/GAL0014), not panics or early returns.
pub fn check_plan_text(text: &str) -> CheckReport {
    let raw = Json::parse(text).ok();
    let mut parse_error = None;
    let report = match PlanReport::from_json_str(text) {
        Ok(r) => Some(r),
        Err(e) => {
            parse_error = Some(e.to_string());
            None
        }
    };
    let mut model = None;
    let mut model_error = None;
    let mut cluster = None;
    let mut cluster_error = None;
    if let Some(r) = &report {
        match resolve_report_model(r) {
            Ok(m) => model = Some(m),
            Err(e) => model_error = Some(e.to_string()),
        }
        match resolve_report_cluster(r) {
            Ok(c) => cluster = Some(c),
            Err(e) => cluster_error = Some(e.to_string()),
        }
    }
    let ctx = CheckContext {
        plan_text: Some(text),
        raw_plan: raw.as_ref(),
        report: report.as_ref(),
        parse_error,
        model: model.as_ref(),
        model_error,
        cluster: cluster.as_ref(),
        cluster_error,
        raw_spec: None,
    };
    run(&ctx)
}

/// Check one model-spec JSON document (the `check --model-file` form).
/// With a cluster, the never-fits lints (GAL0030/GAL0031) run too.
pub fn check_model_json(v: &Json, cluster: Option<&ClusterSpec>) -> CheckReport {
    let model = ModelSpec::from_json(v).ok().and_then(|s| s.compile().ok());
    let ctx = CheckContext {
        raw_spec: Some(v),
        model: model.as_ref(),
        cluster,
        ..Default::default()
    };
    run(&ctx)
}

/// Check one frontier-artifact text (the `check --frontier` form): parse
/// it and run the registry's frontier rules — non-domination, embedded
/// plans passing the plan gate, point/plan consistency.
pub fn check_frontier_text(text: &str) -> CheckReport {
    let raw = Json::parse(text).ok();
    let mut frontier_error = None;
    let frontier = match crate::advise::FrontierReport::from_json_str(text) {
        Ok(f) => Some(f),
        Err(e) => {
            frontier_error = Some(e.to_string());
            None
        }
    };
    let ctx = CheckContext {
        raw_frontier: raw.as_ref(),
        frontier: frontier.as_ref(),
        frontier_error,
        ..Default::default()
    };
    run(&ctx)
}

/// The cheap Error-severity gate `PlanRequest::plan()` and
/// `simulate --plan` run on every artifact before acting on it: plan
/// legality against the resolved model and cluster, no re-derivation.
pub fn gate(
    model: &ModelProfile,
    cluster: &ClusterSpec,
    report: &PlanReport,
) -> Result<(), PlanError> {
    let ctx = CheckContext {
        report: Some(report),
        model: Some(model),
        cluster: Some(cluster),
        ..Default::default()
    };
    let mut diagnostics = Vec::new();
    for rule in registry() {
        if rule.cheap() {
            rule.check(&ctx, &mut diagnostics);
        }
    }
    diagnostics.retain(|d| d.severity == Severity::Error);
    if diagnostics.is_empty() {
        Ok(())
    } else {
        sort_diagnostics(&mut diagnostics);
        Err(PlanError::InvalidArtifact { diagnostics })
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_names() {
        assert!(Severity::Error > Severity::Warn);
        assert!(Severity::Warn > Severity::Note);
        assert_eq!(Severity::Warn.as_str(), "warning");
    }

    #[test]
    fn registry_codes_are_unique_per_rule_name() {
        let rules = registry();
        assert!(rules.len() >= 12, "expected a full rule catalog, got {}", rules.len());
        let mut names: Vec<&str> = rules.iter().map(|r| r.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), rules.len(), "duplicate rule names");
        for r in &rules {
            assert!(r.code().starts_with("GAL0"), "{}", r.code());
            assert!(!r.description().is_empty());
        }
    }

    #[test]
    fn report_renders_and_serializes() {
        let mut rep = CheckReport::default();
        rep.merge(CheckReport {
            diagnostics: vec![
                Diagnostic::note("GAL0011", "$", "an OOM marker"),
                Diagnostic::error("GAL0004", "$.plan.microbatches", "7 does not divide 8")
                    .suggest("use a divisor of the batch"),
            ],
        });
        // Errors sort first.
        assert_eq!(rep.diagnostics[0].code, "GAL0004");
        assert!(rep.has_errors());
        assert_eq!(rep.count(Severity::Error), 1);
        let text = rep.render();
        assert!(text.contains("error[GAL0004]"), "{text}");
        assert!(text.contains("help: use a divisor"), "{text}");
        assert!(text.contains("1 error(s), 0 warning(s), 1 note(s)"), "{text}");
        let json = rep.to_json().to_string();
        assert!(json.contains("\"code\":\"GAL0004\""), "{json}");
        assert!(json.contains("\"suggestion\""), "{json}");
    }
}
