//! Plan-artifact rules: plan legality over the device cube (paper §III),
//! the Eq. 7/8 memory-sandwich conditions (§IV-B), per-stage capacity
//! re-derivation, and `PlanReport` cross-field coherence.

use crate::api::report::PLAN_ARTIFACT_KEYS;
use crate::api::suggest;
use crate::cost::pipeline::plan_cost_full;
use crate::cost::CostModel;
use crate::parallel::memory::STATE_BYTES_PER_PARAM;
use crate::search::bmw::memory_balanced_partition;
use crate::search::partition::balanced_partition;
use crate::util::json::Json;
use crate::util::{pow2_divisors, GIB};

use super::{CheckContext, Checker, Diagnostic};

/// A rule as data: stable code, catalog strings, gate eligibility, and
/// the check function itself.
struct Rule {
    code: &'static str,
    name: &'static str,
    description: &'static str,
    cheap: bool,
    check: fn(&CheckContext, &mut Vec<Diagnostic>),
}

impl Checker for Rule {
    fn code(&self) -> &'static str {
        self.code
    }
    fn name(&self) -> &'static str {
        self.name
    }
    fn description(&self) -> &'static str {
        self.description
    }
    fn cheap(&self) -> bool {
        self.cheap
    }
    fn check(&self, ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
        (self.check)(ctx, out);
    }
}

pub fn rules() -> Vec<Box<dyn Checker>> {
    vec![
        Box::new(Rule {
            code: "GAL0001",
            name: "partition-shape",
            description: "partition arity matches pp, covers every model layer, no empty stage",
            cheap: true,
            check: partition_shape,
        }),
        Box::new(Rule {
            code: "GAL0002",
            name: "device-divisibility",
            description: "pipeline degree divides the cluster's device count",
            cheap: true,
            check: device_divisibility,
        }),
        Box::new(Rule {
            code: "GAL0003",
            name: "strategy-degree",
            description: "every layer strategy covers exactly its stage's device group",
            cheap: true,
            check: strategy_degree,
        }),
        Box::new(Rule {
            code: "GAL0004",
            name: "microbatch-divisibility",
            description: "microbatch count divides the global batch",
            cheap: true,
            check: microbatch_divisibility,
        }),
        Box::new(Rule {
            code: "GAL0005",
            name: "stage-slots",
            description: "stage_slots is a permutation of the cluster's pipeline slots",
            cheap: true,
            check: stage_slots,
        }),
        Box::new(Rule {
            code: "GAL0006",
            name: "stage-memory",
            description: "re-derived per-stage peak memory fits each slot's island budget",
            cheap: false,
            check: stage_memory,
        }),
        Box::new(Rule {
            code: "GAL0007",
            name: "memory-sandwich",
            description: "partition honors the Eq. 7/8 balance sandwich between p_m and p_t",
            cheap: false,
            check: memory_sandwich,
        }),
        Box::new(Rule {
            code: "GAL0010",
            name: "unknown-artifact-key",
            description: "plan artifact carries only known top-level keys",
            cheap: false,
            check: unknown_artifact_key,
        }),
        Box::new(Rule {
            code: "GAL0011",
            name: "oom-marker",
            description: "OOM marker files are well-formed (exactly \"OOM\\n\")",
            cheap: false,
            check: oom_marker,
        }),
        Box::new(Rule {
            code: "GAL0012",
            name: "artifact-parse",
            description: "artifact parses as a PlanReport",
            cheap: false,
            check: artifact_parse,
        }),
        Box::new(Rule {
            code: "GAL0013",
            name: "model-resolution",
            description: "the artifact's model resolves and matches its embedded spec",
            cheap: false,
            check: model_resolution,
        }),
        Box::new(Rule {
            code: "GAL0014",
            name: "cluster-budget",
            description: "the artifact's cluster resolves and its memory budget is coherent",
            cheap: false,
            check: cluster_budget,
        }),
        Box::new(Rule {
            code: "GAL0015",
            name: "cost-provenance",
            description: "recorded cost-model provenance names a known backend and a hex hash",
            cheap: false,
            check: cost_provenance,
        }),
        Box::new(Rule {
            code: "GAL0016",
            name: "cost-drift",
            description: "recorded cost figures match an analytic re-derivation",
            cheap: false,
            check: cost_drift,
        }),
        Box::new(Rule {
            code: "GAL0017",
            name: "trace-consistency",
            description: "search_trace cell counts and best cell are internally consistent",
            cheap: false,
            check: trace_consistency,
        }),
        Box::new(Rule {
            code: "GAL0018",
            name: "batch-exceeds-max",
            description: "the plan's global batch stays within the request's max_batch",
            cheap: true,
            check: batch_exceeds_max,
        }),
        Box::new(Rule {
            code: "GAL0019",
            name: "rederivation-skipped",
            description: "notes when calibrated provenance disables analytic re-derivation",
            cheap: false,
            check: rederivation_skipped,
        }),
        Box::new(Rule {
            code: "GAL0025",
            name: "cache-hit-rate",
            description: "notes when a large search saw an unusually low cost-cache hit rate",
            cheap: false,
            check: cache_hit_rate,
        }),
    ]
}

// ---- plan legality ------------------------------------------------------

fn partition_shape(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let p = &r.plan;
    if p.partition.len() != p.pp {
        out.push(Diagnostic::error(
            "GAL0001",
            "$.plan.partition",
            format!("partition has {} entries but pp = {}", p.partition.len(), p.pp),
        ));
    }
    for (i, &c) in p.partition.iter().enumerate() {
        if c == 0 {
            out.push(Diagnostic::error(
                "GAL0001",
                format!("$.plan.partition[{i}]"),
                format!("stage {i} is empty (zero layers)"),
            ));
        }
    }
    if let Some(m) = ctx.model {
        let covered: usize = p.partition.iter().sum();
        if covered != m.n_layers() {
            out.push(Diagnostic::error(
                "GAL0001",
                "$.plan.partition",
                format!(
                    "partition covers {covered} layers but {} has {}",
                    r.model,
                    m.n_layers()
                ),
            ));
        }
        if p.strategies.len() != m.n_layers() {
            out.push(Diagnostic::error(
                "GAL0001",
                "$.plan.strategies",
                format!(
                    "plan records {} layer strategies for a {}-layer model",
                    p.strategies.len(),
                    m.n_layers()
                ),
            ));
        }
    }
}

fn device_divisibility(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(c) = ctx.cluster else { return };
    let n = c.n_devices();
    let pp = r.plan.pp;
    if pp == 0 || n % pp != 0 {
        let degrees = pow2_divisors(n)
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        out.push(
            Diagnostic::error(
                "GAL0002",
                "$.plan.pp",
                format!("pipeline degree {pp} does not divide the {n} devices of {}", r.cluster),
            )
            .suggest(format!("searchable degrees on {}: {degrees}", r.cluster)),
        );
    }
}

fn strategy_degree(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(c) = ctx.cluster else { return };
    let p = &r.plan;
    let n = c.n_devices();
    if p.pp == 0 || n % p.pp != 0 {
        return; // GAL0002 owns the divisibility failure.
    }
    let group = n / p.pp;
    let offenders: Vec<usize> =
        (0..p.strategies.len()).filter(|&i| p.strategies[i].degree() != group).collect();
    if let Some(&first) = offenders.first() {
        let mut msg = format!(
            "layer {first} strategy {} covers {} devices but the stage group size is {group}",
            p.strategies[first].label(),
            p.strategies[first].degree()
        );
        if offenders.len() > 1 {
            msg.push_str(&format!(" ({} more layers affected)", offenders.len() - 1));
        }
        out.push(Diagnostic::error("GAL0003", format!("$.plan.strategies[{first}]"), msg));
    }
}

fn microbatch_divisibility(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let p = &r.plan;
    if p.microbatches == 0 || p.batch == 0 {
        out.push(Diagnostic::error(
            "GAL0004",
            "$.plan.microbatches",
            format!("batch {} / microbatches {} must both be >= 1", p.batch, p.microbatches),
        ));
    } else if p.batch % p.microbatches != 0 {
        out.push(
            Diagnostic::error(
                "GAL0004",
                "$.plan.microbatches",
                format!(
                    "global batch {} is not divisible into {} microbatches",
                    p.batch, p.microbatches
                ),
            )
            .suggest(format!("use a microbatch count dividing {}", p.batch)),
        );
    }
}

fn stage_slots(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let p = &r.plan;
    let Some(slots) = &p.stage_slots else { return };
    if slots.len() != p.pp {
        out.push(Diagnostic::error(
            "GAL0005",
            "$.plan.stage_slots",
            format!("stage_slots has {} entries but pp = {}", slots.len(), p.pp),
        ));
    } else {
        let mut seen = vec![false; p.pp];
        for (s, &slot) in slots.iter().enumerate() {
            if slot >= p.pp {
                out.push(Diagnostic::error(
                    "GAL0005",
                    format!("$.plan.stage_slots[{s}]"),
                    format!("stage {s} assigned to slot {slot}, outside 0..{}", p.pp),
                ));
            } else if seen[slot] {
                out.push(Diagnostic::error(
                    "GAL0005",
                    format!("$.plan.stage_slots[{s}]"),
                    format!("slot {slot} assigned to more than one stage"),
                ));
            } else {
                seen[slot] = true;
            }
        }
    }
    if let Some(c) = ctx.cluster {
        if c.is_homogeneous() {
            out.push(Diagnostic::note(
                "GAL0005",
                "$.plan.stage_slots",
                format!(
                    "stage_slots recorded on homogeneous cluster {}: placement is the \
                     identity there and the planner never records it",
                    r.cluster
                ),
            ));
        }
    }
}

fn stage_memory(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(m) = ctx.model else { return };
    let Some(c) = ctx.cluster else { return };
    if r.cost_model.is_some() {
        return; // GAL0019 notes the skip: analytic re-derivation would lie.
    }
    if r.plan.validate(m.n_layers(), c.n_devices()).is_err() {
        return; // structural rules own that failure; re-derivation would panic
    }
    let cost = plan_cost_full(
        m,
        c,
        &r.plan,
        r.schedule,
        r.overlap_slowdown,
        r.train,
        &CostModel::Analytic,
    );
    let sites = c.stage_sites(r.plan.pp);
    for (s, st) in cost.stages.iter().enumerate() {
        let slot = r.plan.slot_of(s);
        let cap = sites[slot].gpu.mem_bytes;
        if st.peak_mem > cap {
            out.push(
                Diagnostic::error(
                    "GAL0006",
                    format!("$.stages[{s}]"),
                    format!(
                        "stage {s} needs {:.2} GiB but slot {slot} ({}) offers {:.2} GiB",
                        st.peak_mem / GIB,
                        sites[slot].gpu.name,
                        cap / GIB
                    ),
                )
                .suggest(
                    "re-plan with a larger memory budget, more microbatches, or checkpointing",
                ),
            );
        }
    }
}

fn memory_sandwich(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(m) = ctx.model else { return };
    let p = &r.plan;
    let n = m.n_layers();
    // Structural preconditions are GAL0001/GAL0004's findings; the
    // sandwich is only meaningful on a well-formed multi-stage partition.
    if p.pp < 2
        || p.pp > n
        || p.partition.len() != p.pp
        || p.partition.iter().sum::<usize>() != n
        || p.partition.iter().any(|&c| c == 0)
        || p.microbatches == 0
        || p.batch == 0
    {
        return;
    }
    let flops: Vec<f64> = m.layers.iter().map(|l| l.flops_fwd).collect();
    let act: Vec<f64> = m.layers.iter().map(|l| l.act_bytes).collect();
    let ms: Vec<f64> = m.layers.iter().map(|l| l.params * STATE_BYTES_PER_PARAM).collect();
    let p_t = balanced_partition(&flops, p.pp);
    let p_m = memory_balanced_partition(&act, &ms, p.pp, p.microbatches, r.schedule);
    let b_m = p.microbatch_size();
    let time_alpha = |counts: &[usize]| alpha(&stage_sums(&flops, counts));
    let mem_alpha = |counts: &[usize]| {
        let act_s = stage_sums(&act, counts);
        let ms_s = stage_sums(&ms, counts);
        let per: Vec<f64> = (0..counts.len())
            .map(|s| {
                let live = r.schedule.live_microbatches(s, counts.len(), p.microbatches) as f64;
                ms_s[s] + live * b_m * act_s[s]
            })
            .collect();
        alpha(&per)
    };
    // Eq. 7/8: the accepted partition p' sits between p_m and p_t on both
    // balance degrees, so alpha_t(p') >= alpha_t(p_m) and alpha_m(p') >=
    // alpha_m(p_t). Proxy weights + slack keep legitimate plans clear.
    const SLACK: f64 = 0.05;
    let a_t = time_alpha(&p.partition);
    let a_t_floor = time_alpha(&p_m);
    if a_t + SLACK < a_t_floor {
        out.push(
            Diagnostic::warn(
                "GAL0007",
                "$.plan.partition",
                format!(
                    "Eq. 7 sandwich violated: time balance alpha_t≈{a_t:.3} falls below even \
                     the memory-balanced partition's {a_t_floor:.3}"
                ),
            )
            .suggest("BMW accepts only partitions at least as time-balanced as p_m"),
        );
    }
    let a_m = mem_alpha(&p.partition);
    let a_m_floor = mem_alpha(&p_t);
    if a_m + SLACK < a_m_floor {
        out.push(
            Diagnostic::warn(
                "GAL0007",
                "$.plan.partition",
                format!(
                    "Eq. 8 sandwich violated: memory balance alpha_m≈{a_m:.3} falls below even \
                     the time-balanced partition's {a_m_floor:.3}"
                ),
            )
            .suggest("BMW accepts only partitions at least as memory-balanced as p_t"),
        );
    }
}

fn stage_sums(weights: &[f64], counts: &[usize]) -> Vec<f64> {
    let mut out = Vec::with_capacity(counts.len());
    let mut i = 0usize;
    for &c in counts {
        out.push(weights[i..i + c].iter().sum());
        i += c;
    }
    out
}

fn alpha(per_stage: &[f64]) -> f64 {
    let max = per_stage.iter().cloned().fold(0.0, f64::max);
    let sum: f64 = per_stage.iter().sum();
    if sum > 0.0 {
        1.0 - max / sum
    } else {
        0.0
    }
}

// ---- artifact consistency -----------------------------------------------

fn raw_unknown_keys(raw: &Json) -> Vec<&str> {
    match raw {
        Json::Obj(m) => m
            .keys()
            .map(String::as_str)
            .filter(|k| !PLAN_ARTIFACT_KEYS.contains(k))
            .collect(),
        _ => Vec::new(),
    }
}

fn unknown_artifact_key(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(raw) = ctx.raw_plan else { return };
    for k in raw_unknown_keys(raw) {
        let mut d = Diagnostic::error(
            "GAL0010",
            "$",
            format!("unknown top-level key {k:?} in plan artifact"),
        );
        if let Some(s) = suggest(k, PLAN_ARTIFACT_KEYS.iter().copied()) {
            d = d.suggest(format!("did you mean {s:?}?"));
        }
        out.push(d);
    }
}

fn oom_marker(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(text) = ctx.plan_text else { return };
    if text == "OOM\n" {
        out.push(
            Diagnostic::note(
                "GAL0011",
                "$",
                "artifact is an OOM marker: the planning run found no feasible plan",
            )
            .suggest("re-plan with a larger memory budget or different knobs"),
        );
    } else if text.trim() == "OOM" {
        out.push(Diagnostic::warn(
            "GAL0011",
            "$",
            "malformed OOM marker: expected exactly \"OOM\\n\"",
        ));
    }
}

fn artifact_parse(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(e) = &ctx.parse_error else { return };
    if ctx.plan_text.is_some_and(|t| t.trim() == "OOM") {
        return; // GAL0011 owns marker files.
    }
    if ctx.raw_plan.is_some_and(|raw| !raw_unknown_keys(raw).is_empty()) {
        return; // GAL0010 carries the precise unknown-key finding.
    }
    out.push(Diagnostic::error(
        "GAL0012",
        "$",
        format!("artifact does not parse as a PlanReport: {e}"),
    ));
}

fn model_resolution(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    if let Some(e) = &ctx.model_error {
        out.push(Diagnostic::error(
            "GAL0013",
            "$.model",
            format!("the artifact's model does not resolve: {e}"),
        ));
    }
    let Some(r) = ctx.report else { return };
    if let Some(spec) = &r.model_spec {
        if spec.name != r.model {
            out.push(Diagnostic::error(
                "GAL0013",
                "$.model",
                format!(
                    "embedded model_spec is named {:?} but the artifact says {:?}",
                    spec.name, r.model
                ),
            ));
        }
    }
}

fn cluster_budget(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    if let Some(e) = &ctx.cluster_error {
        out.push(Diagnostic::error(
            "GAL0014",
            "$.cluster",
            format!("the artifact's cluster does not resolve: {e}"),
        ));
    }
    let Some(r) = ctx.report else { return };
    let gb = r.memory_budget_gb;
    if !(gb.is_finite() && gb > 0.0) {
        out.push(Diagnostic::error(
            "GAL0014",
            "$.memory_budget_gb",
            format!("memory budget must be a positive finite number of GB, got {gb}"),
        ));
    } else if let Some(c) = ctx.cluster {
        if !c.is_homogeneous() {
            let floor = c.gpu().mem_bytes / GIB;
            if (gb - floor).abs() > 1e-9 {
                out.push(Diagnostic::error(
                    "GAL0014",
                    "$.memory_budget_gb",
                    format!(
                        "heterogeneous cluster {}: memory_budget_gb must record the floor \
                         island's {floor} GB, got {gb}",
                        r.cluster
                    ),
                ));
            }
        }
    }
}

fn cost_provenance(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(prov) = &r.cost_model else { return };
    if prov.backend != "calibrated" {
        out.push(Diagnostic::error(
            "GAL0015",
            "$.cost_model",
            format!(
                "unknown cost-model backend {:?} (known non-default backends: \"calibrated\")",
                prov.backend
            ),
        ));
    }
    if prov.db_hash.len() != 16 || !prov.db_hash.chars().all(|c| c.is_ascii_hexdigit()) {
        out.push(Diagnostic::error(
            "GAL0015",
            "$.cost_model",
            format!(
                "db_hash {:?} is not a 16-digit hex content hash of a profile DB",
                prov.db_hash
            ),
        ));
    }
}

fn drifted(recorded: f64, recomputed: f64) -> bool {
    let scale = recorded.abs().max(recomputed.abs()).max(1e-12);
    (recorded - recomputed).abs() / scale > 1e-9
}

fn cost_drift(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(m) = ctx.model else { return };
    let Some(c) = ctx.cluster else { return };
    if r.cost_model.is_some() {
        return; // GAL0019 notes the skip.
    }
    if r.plan.validate(m.n_layers(), c.n_devices()).is_err() {
        return;
    }
    let cost = plan_cost_full(
        m,
        c,
        &r.plan,
        r.schedule,
        r.overlap_slowdown,
        r.train,
        &CostModel::Analytic,
    );
    // Serialized f64s round-trip exactly, so untampered artifacts match
    // the re-derivation bit-for-bit; the tolerance only absorbs noise.
    for (field, recorded, recomputed) in [
        ("throughput", r.throughput, cost.throughput),
        ("iter_time", r.iter_time, cost.iter_time),
        ("alpha_t", r.alpha_t, cost.alpha_t),
        ("alpha_m", r.alpha_m, cost.alpha_m),
    ] {
        if drifted(recorded, recomputed) {
            out.push(Diagnostic::warn(
                "GAL0016",
                format!("$.{field}"),
                format!(
                    "recorded {field} {recorded} disagrees with the analytic \
                     re-derivation {recomputed}"
                ),
            ));
        }
    }
    if r.stages.len() != cost.stages.len() {
        out.push(Diagnostic::warn(
            "GAL0016",
            "$.stages",
            format!(
                "artifact records {} stage entries but the plan has {} stages",
                r.stages.len(),
                cost.stages.len()
            ),
        ));
        return;
    }
    for (s, (rec, com)) in r.stages.iter().zip(&cost.stages).enumerate() {
        if drifted(rec.peak_mem_bytes, com.peak_mem)
            || drifted(rec.time_nosync, com.time_nosync)
            || drifted(rec.time_sync, com.time_sync)
        {
            out.push(Diagnostic::warn(
                "GAL0016",
                format!("$.stages[{s}]"),
                format!(
                    "stage {s} diagnostics drifted from the re-derivation \
                     (peak {:.4}/{:.4} GiB, mb time {:.6}/{:.6}s)",
                    rec.peak_mem_bytes / GIB,
                    com.peak_mem / GIB,
                    rec.time_nosync,
                    com.time_nosync
                ),
            ));
            break;
        }
    }
}

fn trace_consistency(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(t) = &r.search_trace else { return };
    if t.cells.len() != t.cells_explored + t.cells_discarded {
        out.push(Diagnostic::warn(
            "GAL0017",
            "$.search_trace",
            format!(
                "trace records {} cells but cells_explored + cells_discarded = {}",
                t.cells.len(),
                t.cells_explored + t.cells_discarded
            ),
        ));
    }
    if t.cells_oom > t.cells_explored {
        out.push(Diagnostic::warn(
            "GAL0017",
            "$.search_trace.cells_oom",
            format!("cells_oom {} exceeds cells_explored {}", t.cells_oom, t.cells_explored),
        ));
    }
    let evaluations: usize =
        t.cells.iter().filter(|c| !c.discarded).map(|c| c.evaluations).sum();
    if evaluations != t.evaluations {
        out.push(Diagnostic::warn(
            "GAL0017",
            "$.search_trace.evaluations",
            format!(
                "trace claims {} evaluations but its explored cells sum to {evaluations}",
                t.evaluations
            ),
        ));
    }
    if let Some((batch, pp)) = t.best_cell {
        if !t.cells.iter().any(|c| c.batch == batch && c.pp == pp) {
            out.push(Diagnostic::warn(
                "GAL0017",
                "$.search_trace.best_cell",
                format!("best_cell ({batch}, {pp}) is not among the recorded cells"),
            ));
        } else if r.plan.batch != batch || r.plan.pp != pp {
            out.push(Diagnostic::warn(
                "GAL0017",
                "$.search_trace.best_cell",
                format!(
                    "best_cell ({batch}, {pp}) disagrees with the plan's (batch {}, pp {})",
                    r.plan.batch, r.plan.pp
                ),
            ));
        }
    }
}

fn batch_exceeds_max(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    if r.plan.batch > r.max_batch {
        out.push(Diagnostic::error(
            "GAL0018",
            "$.plan.batch",
            format!(
                "plan batch {} exceeds the request's max_batch {}",
                r.plan.batch, r.max_batch
            ),
        ));
    }
}

/// Below this many cost-cache lookups the hit rate is dominated by the
/// unavoidable first-touch misses of a small search and says nothing.
const CACHE_RATE_MIN_LOOKUPS: u64 = 10_000;
/// Large searches re-price the same (site, layer, strategy) keys across
/// many (batch, pp) cells; a rate under this suggests the memoization
/// (or a warm-started cache) is not being shared the way it should be.
const CACHE_RATE_FLOOR: f64 = 0.5;

fn cache_hit_rate(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(t) = &r.search_trace else { return };
    if t.cache_lookups < CACHE_RATE_MIN_LOOKUPS || t.cache_entries > t.cache_lookups {
        return; // Too small to judge, or incoherent (GAL0017 territory).
    }
    let rate = 1.0 - (t.cache_entries as f64 / t.cache_lookups as f64);
    if rate < CACHE_RATE_FLOOR {
        out.push(Diagnostic::note(
            "GAL0025",
            "$.search_trace",
            format!(
                "cost-cache hit rate {:.0}% over {} lookups is below the expected {:.0}%: \
                 the run repriced most keys instead of reusing them (cold cache on a \
                 cache-unfriendly sweep, or a --cache-dir miss)",
                rate * 100.0,
                t.cache_lookups,
                CACHE_RATE_FLOOR * 100.0
            ),
        ));
    }
}

fn rederivation_skipped(ctx: &CheckContext, out: &mut Vec<Diagnostic>) {
    let Some(r) = ctx.report else { return };
    let Some(prov) = &r.cost_model else { return };
    out.push(Diagnostic::note(
        "GAL0019",
        "$.cost_model",
        format!(
            "stage-memory and cost-drift re-derivation skipped: the plan was priced by the \
             {} backend and the analytic model would disagree by design",
            prov.label()
        ),
    ));
}
