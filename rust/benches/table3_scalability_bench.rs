//! Bench: Table III/IV scalability — planning cost as the cluster grows
//! (16 low-perf, 16 high-perf, 64 GPUs). The paper reports search time
//! grows 2.2x (16 GPUs) and 9.2x (64 GPUs) vs 8 GPUs; this bench measures
//! our planner's scaling on the same model.
//!
//! Run: `cargo bench --bench table3_scalability_bench`

use std::time::Duration;

use galvatron::api::MethodSpec;
use galvatron::experiments::{cluster, model};
use galvatron::util::bench::bench;

fn main() {
    let method = MethodSpec::Bmw { ckpt: true };
    let mut base = None;
    for (cl_name, budget) in [("titan8", 16.0), ("titan16", 16.0), ("a100x16", 16.0), ("a100x64", 16.0)] {
        let mp = model("bert-huge-32");
        let cl = cluster(cl_name, budget);
        let r = bench(
            &format!("scalability/{cl_name}/{}", method.canonical_name()),
            Duration::from_secs(3),
            || {
                let _ = method.run(&mp, &cl, 64);
            },
        );
        match base {
            None => base = Some(r.mean),
            Some(b) => println!(
                "  -> {:.1}x the 8-GPU search time (paper: 2.2x @16, 9.2x @64)",
                r.mean.as_secs_f64() / b.as_secs_f64()
            ),
        }
    }
}
