//! Bench: Table III/IV scalability — planning cost as the cluster grows
//! (16 low-perf, 16 high-perf, 64 GPUs). The paper reports search time
//! grows 2.2x (16 GPUs) and 9.2x (64 GPUs) vs 8 GPUs; this bench measures
//! our planner's scaling on the same model, at 1 worker thread and at the
//! machine's full parallelism, and reports the engine's cache hit rate.
//!
//! Run: `cargo bench --bench table3_scalability_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::api::{MethodSpec, SearchOverrides};
use galvatron::experiments::{cluster, model};
use galvatron::util::bench::bench;
use galvatron::util::parallelism::resolve_worker_count;

fn main() {
    let method = MethodSpec::Bmw { ckpt: true };
    let auto = resolve_worker_count(None);
    let mut base = None;
    for (cl_name, budget) in [("titan8", 16.0), ("titan16", 16.0), ("a100x16", 16.0), ("a100x64", 16.0)] {
        let mp = model("bert-huge-32");
        let cl = cluster(cl_name, budget);

        let mut ov1 = SearchOverrides::new(64);
        ov1.threads = Some(1);
        let r1 = bench(
            &format!("scalability/{cl_name}/threads=1"),
            Duration::from_secs(3),
            || {
                let _ = method.run_with(&mp, &cl, &ov1);
            },
        );
        let mut ovn = SearchOverrides::new(64);
        ovn.threads = Some(auto);
        // On a single-core machine threads=auto IS threads=1: skip the
        // redundant pass instead of benchmarking a config against itself.
        let rn = if auto > 1 {
            bench(
                &format!("scalability/{cl_name}/threads={auto}"),
                Duration::from_secs(3),
                || {
                    let _ = method.run_with(&mp, &cl, &ovn);
                },
            )
        } else {
            r1.clone()
        };
        let (_, trace) = method.run_traced_with(&mp, &cl, &ovn);
        println!(
            "  -> {:.2}x speedup from {auto} workers; cache hit rate {:.1}% ({} lookups)",
            r1.mean.as_secs_f64() / rn.mean.as_secs_f64(),
            trace.cache_hit_rate() * 100.0,
            trace.cache_lookups
        );
        match base {
            None => base = Some(rn.mean),
            Some(b) => println!(
                "  -> {:.1}x the 8-GPU search time (paper: 2.2x @16, 9.2x @64)",
                rn.mean.as_secs_f64() / b.as_secs_f64()
            ),
        }
    }
}
