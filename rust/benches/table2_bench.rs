//! Bench: Table II end-to-end — one full (model, budget) planning cell per
//! method on titan8. Measures the planner's wallclock (the paper's Fig. 5
//! concern) while regenerating a Table II slice.
//!
//! Run: `cargo bench --bench table2_bench`

use std::time::Duration;

use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::run_method;
use galvatron::util::bench::bench;

fn main() {
    let budget = 16.0;
    for mname in ["bert-huge-32", "vit-huge-32"] {
        for method in ["FSDP/ZeRO-3 (SDP)", "Galvatron (DP+PP)", "Galvatron-Base", "Galvatron-BMW"] {
            let mp = model(mname);
            let cl = cluster("titan8", budget);
            bench(
                &format!("table2/{mname}/{method}"),
                Duration::from_secs(3),
                || {
                    let _ = run_method(method, &mp, &cl, 128);
                },
            );
        }
    }
}
