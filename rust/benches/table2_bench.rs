//! Bench: Table II end-to-end — one full (model, budget) planning cell per
//! method on titan8. Measures the planner's wallclock (the paper's Fig. 5
//! concern) while regenerating a Table II slice, through the typed
//! `MethodSpec` catalog.
//!
//! Run: `cargo bench --bench table2_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::api::MethodSpec;
use galvatron::experiments::{cluster, model};
use galvatron::parallel::Dim;
use galvatron::util::bench::bench;

fn main() {
    let budget = 16.0;
    for mname in ["bert-huge-32", "vit-huge-32"] {
        for method in [
            MethodSpec::Pure(Dim::Sdp),
            MethodSpec::Limited { dims: vec![Dim::Dp], pp: true },
            MethodSpec::Base { ckpt: true },
            MethodSpec::Bmw { ckpt: true },
        ] {
            let mp = model(mname);
            let cl = cluster("titan8", budget);
            bench(
                &format!("table2/{mname}/{}", method.canonical_name()),
                Duration::from_secs(3),
                || {
                    let _ = method.run(&mp, &cl, 128);
                },
            );
        }
    }
}
