//! Bench: Fig. 5 — dynamic-programming search scaling in layers, memory
//! budget and strategy-space size. Verifies the paper's "linear in L and
//! E" claim on the hot path itself (dp_search).
//!
//! Run: `cargo bench --bench fig5_search_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::cluster::cluster_by_name;
use galvatron::cost::CostEstimator;
use galvatron::model::LayerProfile;
use galvatron::search::decision_tree::{candidate_strategies, SpaceOptions};
use galvatron::search::dp::{dp_search, DpInput};
use galvatron::util::bench::bench;
use galvatron::util::{GIB, MIB};

fn main() {
    let strategies = candidate_strategies(8, &SpaceOptions::default());
    let cluster = cluster_by_name("titan8").unwrap();
    let est = CostEstimator::new(&cluster, 1, 1.3);

    // Scaling in L.
    for layers in [8usize, 16, 32, 64] {
        let ls: Vec<LayerProfile> =
            (0..layers).map(|i| LayerProfile::encoder(&format!("l{i}"), 1280, 512, 20)).collect();
        let extra = vec![0.0; layers];
        bench(&format!("dp_search/L={layers}/E=16G"), Duration::from_secs(3), || {
            let _ = dp_search(&DpInput {
                layers: &ls,
                extra_params: &extra,
                strategies: &strategies,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 1,
                live_mb: 1,
                mem_budget: 16.0 * GIB,
                granularity: 64.0 * MIB,
            });
        });
    }

    // Scaling in E.
    let ls: Vec<LayerProfile> =
        (0..32).map(|i| LayerProfile::encoder(&format!("l{i}"), 1280, 512, 20)).collect();
    let extra = vec![0.0; 32];
    for budget in [8.0f64, 16.0, 24.0] {
        bench(&format!("dp_search/L=32/E={budget}G"), Duration::from_secs(3), || {
            let _ = dp_search(&DpInput {
                layers: &ls,
                extra_params: &extra,
                strategies: &strategies,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 1,
                live_mb: 1,
                mem_budget: budget * GIB,
                granularity: 64.0 * MIB,
            });
        });
    }

    // Scaling in |S|.
    for (name, opts) in [
        ("DP+TP(no ckpt)", SpaceOptions::default().with_dims(&[galvatron::parallel::Dim::Dp, galvatron::parallel::Dim::Tp]).no_ckpt()),
        ("Galvatron(no ckpt)", SpaceOptions::default().no_ckpt()),
        ("Galvatron-BMW(full)", SpaceOptions::default()),
    ] {
        let s = candidate_strategies(8, &opts);
        bench(&format!("dp_search/L=32/|S|={} ({name})", s.len()), Duration::from_secs(3), || {
            let _ = dp_search(&DpInput {
                layers: &ls,
                extra_params: &extra,
                strategies: &s,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 1,
                live_mb: 1,
                mem_budget: 16.0 * GIB,
                granularity: 64.0 * MIB,
            });
        });
    }
}
