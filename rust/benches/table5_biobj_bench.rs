//! Bench: Table V — bi-objective partition optimization cost vs the fixed
//! memory-/time-balanced ablations on the imbalanced T5-512/4 model,
//! through the typed `MethodSpec` catalog.
//!
//! Run: `cargo bench --bench table5_biobj_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::api::{MethodSpec, PartitionPolicy};
use galvatron::experiments::{cluster, model};
use galvatron::util::bench::bench;

fn main() {
    let mp = model("t5-512/4-32");
    let cl = cluster("a100x16", 16.0);
    for (label, method) in [
        ("table5/1F1B+Mem", MethodSpec::Partition(PartitionPolicy::Memory)),
        ("table5/1F1B+Time", MethodSpec::Partition(PartitionPolicy::Time)),
        ("table5/1F1B+Bi-obj", MethodSpec::Bmw { ckpt: false }),
    ] {
        bench(label, Duration::from_secs(3), || {
            let _ = method.run(&mp, &cl, 64);
        });
    }
}
