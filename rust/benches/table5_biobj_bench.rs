//! Bench: Table V — bi-objective partition optimization cost vs the fixed
//! memory-/time-balanced ablations on the imbalanced T5-512/4 model.
//!
//! Run: `cargo bench --bench table5_biobj_bench`

use std::time::Duration;

use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::{run_method, run_partition_ablation};
use galvatron::util::bench::bench;

fn main() {
    let mp = model("t5-512/4-32");
    let cl = cluster("a100x16", 16.0);
    bench("table5/1F1B+Mem", Duration::from_secs(3), || {
        let _ = run_partition_ablation("mem", &mp, &cl, 64);
    });
    bench("table5/1F1B+Time", Duration::from_secs(3), || {
        let _ = run_partition_ablation("time", &mp, &cl, 64);
    });
    bench("table5/1F1B+Bi-obj", Duration::from_secs(3), || {
        let _ = run_method("Galvatron (1F1B+Bi-obj)", &mp, &cl, 64);
    });
}
