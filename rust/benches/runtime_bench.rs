//! Bench: L3 hot path — PJRT artifact execution + coordinator step costs
//! (needs `make artifacts`; skips gracefully otherwise).
//!
//! Run: `cargo bench --bench runtime_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;
use std::time::Duration;

use galvatron::coordinator::{Trainer, TrainerConfig};
use galvatron::runtime::{HostTensor, Runtime};
use galvatron::util::bench::bench;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping runtime bench: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest().unwrap();

    // Stage-0 forward execution latency.
    let sm = &man.stages[0];
    let fwd = rt
        .load("fwd0", &sm.fwd.file, sm.fwd.inputs.clone(), sm.fwd.outputs.clone())
        .unwrap();
    let mut args = rt.load_params(&sm.param_file, &sm.param_shapes).unwrap();
    let (b, s) = (man.config.microbatch, man.config.seq);
    args.push(HostTensor::I32 { shape: vec![b, s], data: vec![1; b * s] });
    bench("runtime/stage0_fwd (copy params)", Duration::from_secs(3), || {
        let _ = fwd.run(&args).unwrap();
    });

    // §Perf: cached-literal path (what the trainer now uses) vs the
    // copy-per-call path above.
    let lits: Vec<_> = args.iter().map(|t| t.to_literal().unwrap()).collect();
    bench("runtime/stage0_fwd (cached literals)", Duration::from_secs(3), || {
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let _ = fwd.run_literals(&refs).unwrap();
    });

    // Full coordinator training step (fwd+bwd chains + collectives + adam).
    let mut trainer = Trainer::new(TrainerConfig {
        artifacts_dir: dir,
        steps: 1,
        dp: 1,
        microbatches: 1,
        log_every: 0,
        seed: 0,
        repeat_batch: true,
    })
    .unwrap();
    let r = bench("coordinator/train_step dp=1 m=1", Duration::from_secs(10), || {
        let _ = trainer.train_step().unwrap();
    });
    println!(
        "  -> {:.1} samples/s real execution",
        trainer.samples_per_step() as f64 / r.mean.as_secs_f64()
    );
}
