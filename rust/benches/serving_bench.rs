//! Bench: `galvatron serve` throughput in plans/second under concurrent
//! clients, cold (empty persistent cache) and warm (freshly started
//! daemon over a primed `--cache-dir`), emitted as JSON lines.
//!
//! Each row is one client count:
//!   {"bench":"serving","clients":N,"requests":...,
//!    "plans_per_sec_cold":...,"plans_per_sec_warm":...,"warm_speedup":...,
//!    "dedup_hit_rate_cold":...,"dedup_hit_rate_warm":...,
//!    "searched_cold":...,"searched_warm":...}
//!
//! Every served artifact is asserted byte-identical to the CLI artifact
//! for the same request (`PlanRequest::plan()` at threads=1) — serving
//! may only remove work, never change a plan. The warm daemon must beat
//! the cold one by >= 10x for the single-client repeat workload, the
//! same floor the planning-speed bench holds the planner cache to.
//!
//! All rows are additionally written to `BENCH_serving.json` at the
//! repository root, which CI uploads as an artifact.
//!
//! Run: `cargo bench --bench serving_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use galvatron::api::{MethodSpec, PlanRequest};
use galvatron::serve::ServeState;
use galvatron::util::json::Json;
use galvatron::util::parallelism::{install_worker_budget, resolve_worker_count};

/// Eight distinct requests (by max batch) over the same model/cluster —
/// the pool every client cycles through, at a per-client phase offset so
/// concurrent clients collide on in-flight fingerprints.
const BATCHES: [usize; 8] = [40, 44, 48, 52, 56, 60, 64, 68];

fn request_line(max_batch: usize) -> String {
    format!(
        r#"{{"cluster":"titan8","max_batch":{max_batch},"memory_gb":16,"model":"bert-huge-32"}}"#
    )
}

/// The CLI ground truth for one pool entry: same knobs, single thread.
fn expected_artifact(max_batch: usize) -> String {
    PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(max_batch)
        .method(MethodSpec::Bmw { ckpt: true })
        .threads(1)
        .plan()
        .expect("bench request plans")
        .to_json_string()
}

/// Drive `clients` concurrent request streams, each issuing the whole
/// pool once, asserting byte-identity for every response. Returns the
/// wall-clock seconds for the phase.
fn run_phase(state: &Arc<ServeState>, clients: usize, expected: &[String]) -> f64 {
    let start = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let state = Arc::clone(state);
            scope.spawn(move || {
                for k in 0..BATCHES.len() {
                    let idx = (c + k) % BATCHES.len();
                    let outcome = state.handle_line(&request_line(BATCHES[idx]));
                    assert!(outcome.ok, "serve request failed: {}", outcome.envelope);
                    let artifact = outcome.artifact.expect("ok outcome carries the artifact");
                    assert_eq!(
                        artifact.as_str(),
                        expected[idx],
                        "served artifact for max_batch={} differs from the CLI artifact",
                        BATCHES[idx]
                    );
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

fn main() {
    // Exactly what the daemon does at startup: one machine-wide worker
    // budget that concurrent searches draw from.
    install_worker_budget(resolve_worker_count(None));
    let expected: Vec<String> = BATCHES.iter().map(|&b| expected_artifact(b)).collect();
    let mut results: Vec<Json> = Vec::new();
    for &clients in &[1usize, 4, 16] {
        let cache_dir = std::env::temp_dir().join(format!(
            "galvatron-serving-bench-{}-{clients}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&cache_dir).ok();
        let requests = (clients * BATCHES.len()) as f64;

        // ---- cold: fresh daemon, no persistent cache — every distinct
        // request is a full search (identical concurrent requests still
        // dedup/memo: that IS the daemon under load).
        let cold_state = Arc::new(ServeState::new(None));
        let cold_secs = run_phase(&cold_state, clients, &expected);
        let cold = cold_state.stats();

        // ---- prime the persistent store (untimed, single client).
        let prime_state = Arc::new(ServeState::new(Some(cache_dir.clone())));
        run_phase(&prime_state, 1, &expected);

        // ---- warm: fresh daemon over the primed cache — the "restart
        // the service" case the persistent store exists for.
        let warm_state = Arc::new(ServeState::new(Some(cache_dir.clone())));
        let warm_secs = run_phase(&warm_state, clients, &expected);
        let warm = warm_state.stats();
        std::fs::remove_dir_all(&cache_dir).ok();

        let plans_per_sec_cold = requests / cold_secs;
        let plans_per_sec_warm = requests / warm_secs;
        let warm_speedup = plans_per_sec_warm / plans_per_sec_cold;
        if clients == 1 {
            assert!(
                warm_speedup >= 10.0,
                "warm serving speedup {warm_speedup:.2}x is below the 10x floor \
                 (cold {plans_per_sec_cold:.2} plans/s, warm {plans_per_sec_warm:.2} plans/s)"
            );
        }
        assert_eq!(
            warm.searched, 0,
            "a warm daemon re-searched {} requests the store already holds",
            warm.searched
        );
        let row = Json::obj(vec![
            ("bench", Json::str("serving")),
            ("model", Json::str("bert-huge-32")),
            ("cluster", Json::str("titan8")),
            ("clients", Json::num(clients as f64)),
            ("requests", Json::num(requests)),
            ("plans_per_sec_cold", Json::num(plans_per_sec_cold)),
            ("plans_per_sec_warm", Json::num(plans_per_sec_warm)),
            ("warm_speedup", Json::num(warm_speedup)),
            ("dedup_hit_rate_cold", Json::num(cold.dedup_hits as f64 / requests)),
            ("dedup_hit_rate_warm", Json::num(warm.dedup_hits as f64 / requests)),
            ("searched_cold", Json::num(cold.searched as f64)),
            ("searched_warm", Json::num(warm.searched as f64)),
            ("store_hits_warm", Json::num(warm.store_hits as f64)),
        ]);
        println!("{row}");
        results.push(row);
    }
    // Persist next to BENCH_planning.json at the repository root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf);
    let out = root
        .unwrap_or_else(|| Path::new(".").to_path_buf())
        .join("BENCH_serving.json");
    let doc = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("results", Json::arr(results)),
    ]);
    match std::fs::write(&out, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
