//! Bench: Table VI — planning cost for the GPT-3-scale models (15B/39B/
//! 65B on 32x A100-80G), including the Alpa-like restricted search.
//!
//! Run: `cargo bench --bench table6_llm_bench`

use std::time::Duration;

use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::run_method;
use galvatron::util::bench::bench;

fn main() {
    for mname in ["gpt3-15b"] {
        for method in ["Alpa", "Galvatron-BMW"] {
            let mp = model(mname);
            let cl = cluster("a100-80g-x32", 80.0);
            bench(&format!("table6/{mname}/{method}"), Duration::from_secs(3), || {
                let _ = run_method(method, &mp, &cl, 128);
            });
        }
    }
}
