//! Bench: Table VI — planning cost for the GPT-3-scale models (15B/39B/
//! 65B on 32x A100-80G), including the Alpa-like restricted search,
//! through the typed `MethodSpec` catalog.
//!
//! Run: `cargo bench --bench table6_llm_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::api::MethodSpec;
use galvatron::experiments::{cluster, model};
use galvatron::util::bench::bench;

fn main() {
    for mname in ["gpt3-15b"] {
        for method in [MethodSpec::Alpa, MethodSpec::Bmw { ckpt: true }] {
            let mp = model(mname);
            let cl = cluster("a100-80g-x32", 80.0);
            bench(
                &format!("table6/{mname}/{}", method.canonical_name()),
                Duration::from_secs(3),
                || {
                    let _ = method.run(&mp, &cl, 128);
                },
            );
        }
    }
}
