//! Bench: Fig. 4/7 substrate — discrete-event simulator and closed-form
//! estimator throughput (events/s and plans/s), plus the estimation-error
//! numbers themselves.
//!
//! Run: `cargo bench --bench fig7_sim_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::time::Duration;

use galvatron::cost::pipeline::{plan_cost, Schedule};
use galvatron::experiments::{cluster, model};
use galvatron::parallel::{Dim, ParallelPlan, Strategy};
use galvatron::sim::simulate;
use galvatron::util::bench::bench;

fn main() {
    let mp = model("bert-huge-32");
    let cl = cluster("titan8", 16.0);
    let plan = ParallelPlan {
        pp: 4,
        partition: vec![8, 8, 8, 8],
        strategies: vec![Strategy::single(Dim::Dp, 2, false); 32],
        batch: 64,
        microbatches: 16,
        stage_slots: None,
    };
    let tasks = 2 * plan.pp * plan.microbatches;

    let r = bench("simulate/4-stage x 16 microbatches", Duration::from_secs(3), || {
        let _ = simulate(&mp, &cl, &plan, Schedule::OneFOneB, 1.3);
    });
    println!(
        "  -> {:.0} scheduled tasks/s",
        tasks as f64 / r.mean.as_secs_f64()
    );

    bench("plan_cost/same plan", Duration::from_secs(3), || {
        let _ = plan_cost(&mp, &cl, &plan, Schedule::OneFOneB, 1.3);
    });

    // The Fig. 7 numbers on this plan.
    let sim = simulate(&mp, &cl, &plan, Schedule::OneFOneB, 1.3);
    let with = plan_cost(&mp, &cl, &plan, Schedule::OneFOneB, 1.3).iter_time;
    let without = plan_cost(&mp, &cl, &plan, Schedule::OneFOneB, 1.0).iter_time;
    println!(
        "estimation error vs DES: with slowdown {:+.1}%, without {:+.1}%",
        (with - sim.iter_time) / sim.iter_time * 100.0,
        (without - sim.iter_time) / sim.iter_time * 100.0
    );
}
