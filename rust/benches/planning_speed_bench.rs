//! Bench: planner throughput in plans/second, cold and warm, emitted as
//! JSON lines so CI and future PRs can track planning speed as a
//! first-class metric.
//!
//! Each line is one case:
//!   {"bench":"planning_speed","model":...,"cluster":...,"backend":...,
//!    "threads":N,"plans_per_sec":...,"plans_per_sec_warm":...,
//!    "warm_speedup":...,"cache_hit_rate":...,"cells_explored":...}
//!
//! `plans_per_sec` is the cold number (no `--cache-dir`), the metric the
//! regression gate tracks; `plans_per_sec_warm` re-plans the identical
//! request against a primed persistent cache, where the planner answers
//! from its stored artifact without searching. The warm artifact is
//! asserted byte-identical to the cold one — the cache may only remove
//! work, never change a plan.
//!
//! Each case also measures the cold path with pruning disabled
//! (`.prune(false)`, the `GALVATRON_NO_PRUNE=1` path): `cold_speedup` is
//! what dominance pruning, the lower-bound skip, the DP reachability
//! bounds and the stage-DP memo buy together. The pruned artifact is
//! asserted byte-identical to the unpruned one on every case — pruning
//! may only remove work, never change a plan — and the homogeneous
//! titan8 cases gate `cold_speedup >= 3` at threads=1.
//!
//! All cases are additionally written to `BENCH_planning.json` at the
//! repository root (canonical pretty JSON) — the persistent planning-speed
//! trajectory CI runs in release mode, gates against the best cold rate
//! recorded in `BENCH_history.jsonl` (`scripts/bench_gate.py`), and
//! uploads as an artifact.
//!
//! Run: `cargo bench --bench planning_speed_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;
use std::time::Duration;

use galvatron::api::{resolve_cluster_name, CostModel, MethodSpec, PlanRequest, ProfileDb};
use galvatron::util::bench::bench;
use galvatron::util::json::Json;
use galvatron::util::parallelism::resolve_worker_count;

struct Case {
    model: &'static str,
    cluster: &'static str,
    /// `None` keeps the preset's physical budget (heterogeneous clusters
    /// reject uniform overrides).
    memory_gb: Option<f64>,
    /// Cost-model backend: analytic, or calibrated from a synthetic
    /// profile DB (prices differ; cache keys must therefore differ too).
    backend: &'static str,
}

fn cases() -> Vec<Case> {
    vec![
        Case { model: "bert-huge-32", cluster: "titan8", memory_gb: Some(16.0), backend: "analytic" },
        Case { model: "t5-512/4-32", cluster: "titan8", memory_gb: Some(8.0), backend: "analytic" },
        Case { model: "bert-huge-32", cluster: "hetero4", memory_gb: None, backend: "analytic" },
        Case {
            model: "bert-huge-32",
            cluster: "titan8",
            memory_gb: Some(16.0),
            backend: "calibrated",
        },
    ]
}

fn main() {
    let auto = resolve_worker_count(None);
    let mut thread_counts = vec![1usize];
    if auto > 1 {
        thread_counts.push(auto);
    }
    let mut results: Vec<Json> = Vec::new();
    for case in cases() {
        let Case { model, cluster, memory_gb, backend } = case;
        let cost_model = match backend {
            "calibrated" => {
                let c = resolve_cluster_name(cluster).expect("bench cluster resolves");
                Some(CostModel::calibrated(ProfileDb::synthetic(&c)))
            }
            _ => None,
        };
        for &threads in &thread_counts {
            let request = || {
                let mut req = PlanRequest::new(model, cluster)
                    .max_batch(64)
                    .method(MethodSpec::Bmw { ckpt: true })
                    .threads(threads);
                if let Some(gb) = memory_gb {
                    req = req.memory_gb(gb);
                }
                if let Some(m) = &cost_model {
                    req = req.cost_model(m.clone());
                }
                req
            };
            let label = format!("planning_speed/{model}/{cluster}/{backend}/threads={threads}");
            // ---- cold: no cache directory, full search every iteration.
            let r = bench(&format!("{label}/cold"), Duration::from_secs(3), || {
                let _ = request().plan();
            });
            let plans_per_sec = 1.0 / r.mean.as_secs_f64();
            // One traced run for the engine diagnostics. The produced
            // artifact must also check clean: a planner that speeds up by
            // emitting illegal plans is not faster, it is broken.
            let cold = request().plan().expect("bench case plans");
            let cold_text = cold.to_json_string();
            let check = galvatron::check::check_plan_text(&cold_text);
            assert!(
                !check.has_errors(),
                "benched plan for {model} fails `galvatron check`:\n{}",
                check.render()
            );
            let (hit_rate, cells) = match &cold.search_trace {
                Some(t) => (t.cache_hit_rate(), t.cells_explored),
                None => (0.0, 0),
            };
            // Pruning diagnostics from the live trace (timing counters are
            // never serialized, so they must come from a fresh run).
            let timing =
                cold.search_trace.as_ref().map(|t| t.timing.clone()).unwrap_or_default();
            // ---- no-prune: the pre-pruning cold path, for the speedup
            // gate. Must produce the byte-identical artifact first.
            let noprune_text =
                request().prune(false).plan().expect("no-prune run plans").to_json_string();
            assert_eq!(
                cold_text, noprune_text,
                "{label}: pruned and unpruned artifacts differ — pruning changed a plan"
            );
            let r = bench(&format!("{label}/cold-noprune"), Duration::from_secs(3), || {
                let _ = request().prune(false).plan();
            });
            let plans_per_sec_noprune = 1.0 / r.mean.as_secs_f64();
            let cold_speedup = plans_per_sec / plans_per_sec_noprune;
            // Gate the tentpole on the homogeneous cases at threads=1 (the
            // least noisy rows); the other rows just report their ratio.
            if cluster == "titan8" && backend == "analytic" && threads == 1 {
                assert!(
                    cold_speedup >= 3.0,
                    "{label}: pruning speedup {cold_speedup:.2}x below the 3x floor \
                     ({plans_per_sec:.2} vs {plans_per_sec_noprune:.2} plans/s)"
                );
            }
            // ---- warm: prime a fresh cache directory once, then re-plan
            // the identical request against it.
            let cache_dir = std::env::temp_dir().join(format!(
                "galvatron-bench-{}-{}",
                std::process::id(),
                results.len()
            ));
            let warm_text =
                request().cache_dir(&cache_dir).plan().expect("priming run plans").to_json_string();
            assert_eq!(
                cold_text, warm_text,
                "{label}: priming (cold, cache-dir) artifact differs from the cache-less one"
            );
            let r = bench(&format!("{label}/warm"), Duration::from_secs(3), || {
                let _ = request().cache_dir(&cache_dir).plan();
            });
            let plans_per_sec_warm = 1.0 / r.mean.as_secs_f64();
            let warm_text =
                request().cache_dir(&cache_dir).plan().expect("warm run plans").to_json_string();
            assert_eq!(
                cold_text, warm_text,
                "{label}: warm artifact differs from cold — the cache changed the plan"
            );
            std::fs::remove_dir_all(&cache_dir).ok();
            let row = Json::obj(vec![
                ("bench", Json::str("planning_speed")),
                ("model", Json::str(model)),
                ("cluster", Json::str(cluster)),
                ("memory_gb", Json::num(memory_gb.unwrap_or(0.0))),
                ("backend", Json::str(backend)),
                ("threads", Json::num(threads as f64)),
                ("plans_per_sec", Json::num(plans_per_sec)),
                ("plans_per_sec_warm", Json::num(plans_per_sec_warm)),
                ("warm_speedup", Json::num(plans_per_sec_warm / plans_per_sec)),
                ("plans_per_sec_noprune", Json::num(plans_per_sec_noprune)),
                ("cold_speedup", Json::num(cold_speedup)),
                ("cache_hit_rate", Json::num(hit_rate)),
                ("cells_explored", Json::num(cells as f64)),
                ("candidates_pruned", Json::num(timing.candidates_pruned as f64)),
                ("lb_skips", Json::num(timing.lb_skips as f64)),
                ("dp_states_visited", Json::num(timing.dp_states_visited as f64)),
                ("matrix_builds", Json::num(timing.matrix_builds as f64)),
                ("dp_memo_entries", Json::num(timing.dp_memo_entries as f64)),
            ]);
            println!("{row}");
            results.push(row);
        }
    }
    // Persist the trajectory at the repository root (the crate lives in
    // rust/, so the root is the manifest dir's parent).
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf);
    let out = root
        .unwrap_or_else(|| Path::new(".").to_path_buf())
        .join("BENCH_planning.json");
    let doc = Json::obj(vec![
        ("bench", Json::str("planning_speed")),
        ("results", Json::arr(results)),
    ]);
    match std::fs::write(&out, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
