//! Bench: planner throughput in plans/second, emitted as JSON lines so CI
//! and future PRs can track planning speed as a first-class metric.
//!
//! Each line is one case:
//!   {"bench":"planning_speed","model":...,"cluster":...,"threads":N,
//!    "plans_per_sec":...,"cache_hit_rate":...,"cells_explored":...}
//!
//! All cases are additionally written to `BENCH_planning.json` at the
//! repository root (canonical pretty JSON) — the persistent planning-speed
//! trajectory CI runs in release mode and uploads as an artifact.
//!
//! Run: `cargo bench --bench planning_speed_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;
use std::time::Duration;

use galvatron::api::{MethodSpec, PlanRequest};
use galvatron::util::bench::bench;
use galvatron::util::json::Json;
use galvatron::util::parallelism::resolve_worker_count;

fn main() {
    let auto = resolve_worker_count(None);
    let mut thread_counts = vec![1usize];
    if auto > 1 {
        thread_counts.push(auto);
    }
    let mut results: Vec<Json> = Vec::new();
    for (model, cluster, budget) in
        [("bert-huge-32", "titan8", 16.0), ("t5-512/4-32", "titan8", 8.0)]
    {
        for &threads in &thread_counts {
            let request = || {
                PlanRequest::new(model, cluster)
                    .memory_gb(budget)
                    .max_batch(64)
                    .method(MethodSpec::Bmw { ckpt: true })
                    .threads(threads)
            };
            let r = bench(
                &format!("planning_speed/{model}/threads={threads}"),
                Duration::from_secs(3),
                || {
                    let _ = request().plan();
                },
            );
            let plans_per_sec = 1.0 / r.mean.as_secs_f64();
            // One traced run for the engine diagnostics. The produced
            // artifact must also check clean: a planner that speeds up by
            // emitting illegal plans is not faster, it is broken.
            let (hit_rate, cells) = match request().plan() {
                Ok(report) => {
                    let check = galvatron::check::check_plan_text(&report.to_json_string());
                    assert!(
                        !check.has_errors(),
                        "benched plan for {model} fails `galvatron check`:\n{}",
                        check.render()
                    );
                    match report.search_trace {
                        Some(t) => (t.cache_hit_rate(), t.cells_explored),
                        None => (0.0, 0),
                    }
                }
                Err(_) => (0.0, 0),
            };
            let row = Json::obj(vec![
                ("bench", Json::str("planning_speed")),
                ("model", Json::str(model)),
                ("cluster", Json::str(cluster)),
                ("memory_gb", Json::num(budget)),
                ("threads", Json::num(threads as f64)),
                ("plans_per_sec", Json::num(plans_per_sec)),
                ("cache_hit_rate", Json::num(hit_rate)),
                ("cells_explored", Json::num(cells as f64)),
            ]);
            println!("{row}");
            results.push(row);
        }
    }
    // Persist the trajectory at the repository root (the crate lives in
    // rust/, so the root is the manifest dir's parent).
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf);
    let out = root
        .unwrap_or_else(|| Path::new(".").to_path_buf())
        .join("BENCH_planning.json");
    let doc = Json::obj(vec![
        ("bench", Json::str("planning_speed")),
        ("results", Json::arr(results)),
    ]);
    match std::fs::write(&out, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
