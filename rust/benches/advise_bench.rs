//! Bench: `galvatron advise` fleet-sweep throughput in fleets/second,
//! cold (fresh `--cache-dir`) and warm (repeat sweep over the same
//! store), emitted as one JSON row:
//!
//!   {"bench":"advise","model":...,"gpus":...,"fleets_considered":...,
//!    "fleets_planned":...,"frontier_size":...,"fleets_per_sec_cold":...,
//!    "fleets_per_sec_warm":...,"warm_speedup":...}
//!
//! The warm sweep must be byte-identical to the cold one and at least 5x
//! faster: every fleet shares one cost-table context (the relaxed
//! context fingerprint) and repeat sweeps answer from the plan store.
//!
//! The row is additionally written to `BENCH_advise.json` at the
//! repository root, which CI uploads as an artifact.
//!
//! Run: `cargo bench --bench advise_bench`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::Path;
use std::time::Instant;

use galvatron::advise::{advise, parse_fleet_spec, AdviseRequest};
use galvatron::util::json::Json;
use galvatron::util::parallelism::{install_worker_budget, resolve_worker_count};

/// Nine fleets: 1x/2x/4x of each class alone plus the balanced mixes.
const GPUS: &str = "RTX-TITAN-24G:0..4,A100-40G:0..4";
const MODEL: &str = "bert-huge-32";

fn main() {
    install_worker_budget(resolve_worker_count(None));
    let cache_dir = std::env::temp_dir()
        .join(format!("galvatron-advise-bench-{}", std::process::id()));
    std::fs::remove_dir_all(&cache_dir).ok();
    let request = AdviseRequest::new(MODEL, parse_fleet_spec(GPUS, 3).unwrap())
        .max_batch(8)
        .cache_dir(&cache_dir);

    // ---- cold: every viable fleet is a full search.
    let start = Instant::now();
    let cold = advise(&request).expect("cold sweep");
    let cold_secs = start.elapsed().as_secs_f64();

    // ---- warm: same sweep over the primed store.
    let start = Instant::now();
    let warm = advise(&request).expect("warm sweep");
    let warm_secs = start.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&cache_dir).ok();

    assert_eq!(
        warm.to_pretty_string(),
        cold.to_pretty_string(),
        "warm sweep changed the frontier artifact bytes"
    );
    let fleets = cold.fleets_considered as f64;
    let fleets_per_sec_cold = fleets / cold_secs;
    let fleets_per_sec_warm = fleets / warm_secs;
    let warm_speedup = fleets_per_sec_warm / fleets_per_sec_cold;
    assert!(
        warm_speedup >= 5.0,
        "warm sweep speedup {warm_speedup:.2}x is below the 5x floor \
         (cold {fleets_per_sec_cold:.2} fleets/s, warm {fleets_per_sec_warm:.2} fleets/s)"
    );

    let row = Json::obj(vec![
        ("bench", Json::str("advise")),
        ("model", Json::str(MODEL)),
        ("gpus", Json::str(GPUS)),
        ("fleets_considered", Json::num(cold.fleets_considered as f64)),
        ("fleets_planned", Json::num(cold.fleets_planned as f64)),
        ("frontier_size", Json::num(cold.points.len() as f64)),
        ("fleets_per_sec_cold", Json::num(fleets_per_sec_cold)),
        ("fleets_per_sec_warm", Json::num(fleets_per_sec_warm)),
        ("warm_speedup", Json::num(warm_speedup)),
    ]);
    println!("{row}");

    // Persist next to BENCH_serving.json at the repository root.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(Path::to_path_buf);
    let out = root
        .unwrap_or_else(|| Path::new(".").to_path_buf())
        .join("BENCH_advise.json");
    let doc = Json::obj(vec![
        ("bench", Json::str("advise")),
        ("results", Json::arr(vec![row])),
    ]);
    match std::fs::write(&out, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
