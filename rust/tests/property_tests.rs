//! Property-based tests over the planner/simulator invariants (DESIGN.md
//! §7), driven by the in-tree SplitMix64 RNG (proptest is unavailable in
//! the offline crate cache — same discipline, explicit generators).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::cluster::{cluster_by_name, ClusterSpec};
use galvatron::cost::pipeline::{plan_cost, Schedule};
use galvatron::cost::CostEstimator;
use galvatron::model::{LayerProfile, ModelProfile};
use galvatron::parallel::memory::LayerMemory;
use galvatron::parallel::{ParallelPlan, Strategy};
use galvatron::search::base::LayerDiag;
use galvatron::search::bmw::{
    adjust_candidates, memory_balanced_partition, memory_balanced_partition_budgeted,
    proxy_stage_stats,
};
use galvatron::search::decision_tree::{candidate_strategies, SpaceOptions};
use galvatron::search::dp::{dp_search, DpInput};
use galvatron::search::partition::{balance_degree, balanced_partition};
use galvatron::sim::{simulate, Phase};
use galvatron::util::rng::Rng;
use galvatron::util::{GIB, MIB};

/// Random heterogeneous model with `layers` transformer layers.
fn random_model(rng: &mut Rng, layers: usize) -> ModelProfile {
    let hiddens = [512usize, 768, 1024, 1280];
    let seqs = [128usize, 256, 512];
    ModelProfile {
        name: "random".into(),
        layers: (0..layers)
            .map(|i| {
                let h = *rng.choice(&hiddens);
                let s = *rng.choice(&seqs);
                LayerProfile::encoder(&format!("l{i}"), h, s, h / 64)
            })
            .collect(),
        pre_params: rng.f64() * 50e6,
        post_params: rng.f64() * 5e6,
    }
}

fn random_uniform_plan(rng: &mut Rng, layers: usize, n_devices: usize) -> ParallelPlan {
    let pps: Vec<usize> = galvatron::util::pow2_divisors(n_devices)
        .into_iter()
        .filter(|&p| p <= layers)
        .collect();
    let pp = *rng.choice(&pps);
    let group = n_devices / pp;
    let cands = candidate_strategies(group, &SpaceOptions::default());
    let strat = rng.choice(&cands).clone();
    let base = layers / pp;
    let mut partition = vec![base; pp];
    for i in 0..layers - base * pp {
        partition[i] += 1;
    }
    let m = [1usize, 2, 4, 8][rng.below(4) as usize].min(8);
    let batch = m * (1 + rng.below(8) as usize) * 4;
    ParallelPlan {
        pp,
        partition,
        strategies: vec![strat; layers],
        batch,
        microbatches: m,
        stage_slots: None,
    }
}

fn titan8(budget_gb: f64) -> ClusterSpec {
    cluster_by_name("titan8").unwrap().with_memory_budget(budget_gb * GIB)
}

#[test]
fn prop_dp_search_never_exceeds_budget() {
    let mut rng = Rng::new(1);
    for trial in 0..25 {
        let layers = 2 + rng.below(10) as usize;
        let model = random_model(&mut rng, layers);
        let budget = (2.0 + rng.f64() * 20.0) * GIB;
        let strategies = candidate_strategies(8, &SpaceOptions::default());
        let cluster = titan8(budget / GIB);
        let est = CostEstimator::new(&cluster, 1, 1.3);
        let extra: Vec<f64> = (0..layers).map(|i| model.extra_params(i)).collect();
        let input = DpInput {
            layers: &model.layers,
            extra_params: &extra,
            strategies: &strategies,
            costs: &est,
            layer_offset: 0,
            b_m: (1 + rng.below(16)) as f64,
            microbatches: 1 + rng.below(8) as usize,
            live_mb: 1 + rng.below(4) as usize,
            mem_budget: budget,
            granularity: 32.0 * MIB,
        };
        if let Some(res) = dp_search(&input) {
            assert!(
                res.peak_mem <= budget * 1.000001,
                "trial {trial}: peak {} > budget {}",
                res.peak_mem / GIB,
                budget / GIB
            );
            assert!(res.cost_per_batch.is_finite() && res.cost_per_batch > 0.0);
            assert_eq!(res.strategies.len(), layers);
        }
    }
}

#[test]
fn prop_dp_search_cost_monotone_in_budget() {
    let mut rng = Rng::new(2);
    for _ in 0..10 {
        let layers = 4 + rng.below(8) as usize;
        let model = random_model(&mut rng, layers);
        let strategies = candidate_strategies(8, &SpaceOptions::default());
        let extra: Vec<f64> = (0..layers).map(|i| model.extra_params(i)).collect();
        let mut prev_cost = f64::INFINITY;
        for budget_gb in [4.0, 8.0, 16.0, 24.0] {
            let cluster = titan8(budget_gb);
            let est = CostEstimator::new(&cluster, 1, 1.3);
            let res = dp_search(&DpInput {
                layers: &model.layers,
                extra_params: &extra,
                strategies: &strategies,
                costs: &est,
                layer_offset: 0,
                b_m: 8.0,
                microbatches: 2,
                live_mb: 1,
                mem_budget: budget_gb * GIB,
                granularity: 32.0 * MIB,
            });
            if let Some(r) = res {
                assert!(
                    r.cost_per_batch <= prev_cost * 1.001,
                    "cost increased with budget: {} -> {}",
                    prev_cost,
                    r.cost_per_batch
                );
                prev_cost = r.cost_per_batch;
            }
        }
    }
}

#[test]
fn prop_simulator_conservation() {
    // Every (stage, microbatch) runs fwd and bwd exactly once; dependency
    // edges never violated; iter_time >= any single stage's busy time.
    let mut rng = Rng::new(3);
    for _ in 0..20 {
        let layers = 4 + rng.below(12) as usize;
        let model = random_model(&mut rng, layers);
        let cluster = titan8(24.0);
        let plan = random_uniform_plan(&mut rng, layers, 8);
        let r = simulate(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        assert_eq!(r.trace.len(), 2 * plan.pp * plan.microbatches);
        for s in 0..plan.pp {
            for j in 0..plan.microbatches {
                let f: Vec<_> = r
                    .trace
                    .iter()
                    .filter(|e| e.stage == s && e.microbatch == j && e.phase == Phase::Forward)
                    .collect();
                let b: Vec<_> = r
                    .trace
                    .iter()
                    .filter(|e| e.stage == s && e.microbatch == j && e.phase == Phase::Backward)
                    .collect();
                assert_eq!((f.len(), b.len()), (1, 1));
                assert!(b[0].start >= f[0].end - 1e-12);
            }
        }
        for (busy, _) in r.stage_busy.iter().zip(&r.bubble_fraction) {
            assert!(*busy <= r.iter_time * (1.0 + 1e-9));
        }
        assert!(r.throughput > 0.0);
    }
}

#[test]
fn prop_estimator_tracks_simulator_for_uniform_plans() {
    // Eq. 9 must stay within 15% of the DES for homogeneous-stage plans.
    let mut rng = Rng::new(4);
    let mut checked = 0;
    for _ in 0..30 {
        let layers = 8usize;
        let model = ModelProfile {
            name: "uniform".into(),
            layers: (0..layers)
                .map(|i| LayerProfile::encoder(&format!("l{i}"), 1024, 256, 16))
                .collect(),
            pre_params: 0.0,
            post_params: 0.0,
        };
        let cluster = titan8(24.0);
        let plan = random_uniform_plan(&mut rng, layers, 8);
        if layers % plan.pp != 0 {
            continue;
        }
        let est = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        let sim = simulate(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        let rel = (est.iter_time - sim.iter_time).abs() / sim.iter_time;
        assert!(rel < 0.15, "plan pp={} m={} strat={} rel {:.3}", plan.pp, plan.microbatches, plan.strategies[0], rel);
        checked += 1;
    }
    assert!(checked >= 10);
}

#[test]
fn prop_sim_memory_matches_eq2_accounting() {
    let mut rng = Rng::new(5);
    for _ in 0..15 {
        let layers = 8usize;
        let model = random_model(&mut rng, layers);
        let cluster = titan8(24.0);
        let plan = random_uniform_plan(&mut rng, layers, 8);
        let est = plan_cost(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        let sim = simulate(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        for s in 0..plan.pp {
            let rel = (sim.stage_peak_mem[s] - est.stages[s].peak_mem).abs()
                / est.stages[s].peak_mem.max(1.0);
            assert!(
                rel < 0.05,
                "stage {s}: sim {} vs est {} (pp={} m={})",
                sim.stage_peak_mem[s],
                est.stages[s].peak_mem,
                plan.pp,
                plan.microbatches
            );
        }
    }
}

#[test]
fn prop_strategy_enumeration_covers_group_exactly() {
    for group in [1usize, 2, 4, 8, 16, 32, 64] {
        for s in candidate_strategies(group, &SpaceOptions::default()) {
            assert!(s.is_valid());
            assert_eq!(s.degree(), group);
            assert!(!(s.dp() > 1 && s.sdp() > 1), "Takeaway #3 violated: {s}");
        }
    }
}

#[test]
fn prop_gpipe_memory_dominates_1f1b() {
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let layers = 8usize;
        let model = random_model(&mut rng, layers);
        let cluster = titan8(24.0);
        let mut plan = random_uniform_plan(&mut rng, layers, 8);
        plan.microbatches = plan.microbatches.max(2);
        plan.batch = plan.microbatches * 4;
        let g = simulate(&model, &cluster, &plan, Schedule::GPipe, 1.3);
        let f = simulate(&model, &cluster, &plan, Schedule::OneFOneB, 1.3);
        for s in 0..plan.pp {
            assert!(
                g.stage_peak_mem[s] >= f.stage_peak_mem[s] - 1.0,
                "stage {s}: gpipe {} < 1f1b {}",
                g.stage_peak_mem[s],
                f.stage_peak_mem[s]
            );
        }
        // Same theoretical bubble ratio; the DES's link-FIFO contention can
        // introduce small schedule-dependent differences.
        assert!(
            (g.iter_time - f.iter_time).abs() / f.iter_time < 0.25,
            "gpipe {} vs 1f1b {}",
            g.iter_time,
            f.iter_time
        );
    }
}

#[test]
fn prop_ckpt_never_increases_forward_stash() {
    use galvatron::parallel::memory::layer_memory;
    let mut rng = Rng::new(7);
    for _ in 0..50 {
        let model = random_model(&mut rng, 1);
        let layer = &model.layers[0];
        let cands = candidate_strategies(8, &SpaceOptions::default());
        let strat = rng.choice(&cands).clone();
        let mut with = strat.clone();
        with.ckpt = true;
        let mut without = strat;
        without.ckpt = false;
        let b_m = (1 + rng.below(16)) as f64;
        let m_with = layer_memory(layer, &with, b_m, 0.0);
        let m_without = layer_memory(layer, &without, b_m, 0.0);
        assert!(m_with.o_f <= m_without.o_f + 1.0);
        // Conservation: moved bytes show up as backward spike.
        assert!((m_with.o_f + m_with.o_b - m_without.o_f).abs() < 1.0);
        assert_eq!(m_with.o_ms, m_without.o_ms);
    }
}

/// Random per-layer diagnostics with no backward spike, so the proxy stage
/// memory is exactly `ms_total + live·f_total` — the weighting
/// `memory_balanced_partition` optimizes.
fn random_diags(rng: &mut Rng, n: usize) -> Vec<LayerDiag> {
    (0..n)
        .map(|_| LayerDiag {
            time: 0.5 + rng.f64() * 2.0,
            mem: LayerMemory {
                o_ms: (0.1 + rng.f64()) * 1e9,
                o_f: (0.1 + rng.f64() * 2.0) * 1e9,
                o_b: 0.0,
            },
        })
        .collect()
}

fn max_of(v: &[f64]) -> f64 {
    v.iter().cloned().fold(0.0, f64::max)
}

/// The Eq. 7/8 sandwich on randomized layer weights, stage counts,
/// schedules and microbatch counts: replay Algorithm 2's boundary
/// adjustment from the memory-balanced partition p_m under its acceptance
/// conditions and check, at every accepted partition p',
///   max_time(p_m) >= max_time(p') >= max_time(p_t)   (alpha_t sandwich:
///   the total stage time is partition-invariant, so 1 - max/sum orders
///   identically), and
///   max_mem(p_m)  <= max_mem(p')  <= max_mem(p_t)    (its memory dual).
/// The reference endpoints are computed by exhaustive enumeration (n is
/// kept small), so the inequalities are exact — not conditional on the
/// production partitioners' approximation quality.
#[test]
fn prop_bmw_sandwich_invariant() {
    let mut rng = Rng::new(41);
    for trial in 0..60 {
        let n = 6 + rng.below(7) as usize; // small: exhaustive references
        let p = *rng.choice(&[2usize, 4]);
        let m = 1 + rng.below(8) as usize;
        let schedule = *rng.choice(&[Schedule::OneFOneB, Schedule::GPipe]);
        let diags = random_diags(&mut rng, n);
        let times: Vec<f64> = diags.iter().map(|d| d.time).collect();

        // Exhaustive endpoints: p_m* minimizes the proxy memory
        // bottleneck, p_t* the proxy time bottleneck.
        let all = partitions_of(n, p);
        let mem_bot = |q: &[usize]| max_of(&proxy_stage_stats(&diags, q, m, schedule).1);
        let time_bot = |q: &[usize]| max_of(&proxy_stage_stats(&diags, q, m, schedule).0);
        let p_m = all
            .iter()
            .min_by(|a, b| mem_bot(a.as_slice()).total_cmp(&mem_bot(b.as_slice())))
            .unwrap()
            .clone();
        let p_t = all
            .iter()
            .min_by(|a, b| time_bot(a.as_slice()).total_cmp(&time_bot(b.as_slice())))
            .unwrap()
            .clone();
        let (time_m, mem_m) = proxy_stage_stats(&diags, &p_m, m, schedule);
        let (time_t, mem_t) = proxy_stage_stats(&diags, &p_t, m, schedule);
        let eps = 1e-9;

        // Endpoint ordering.
        assert!(max_of(&mem_m) <= max_of(&mem_t) * (1.0 + eps), "trial {trial}");
        assert!(max_of(&time_t) <= max_of(&time_m) * (1.0 + eps), "trial {trial}");
        assert!(
            balance_degree(&times, &p_m) <= balance_degree(&times, &p_t) + eps,
            "trial {trial}"
        );

        // Replay the adjustment loop with Algorithm 2's acceptance rules.
        let mut cur = p_m.clone();
        for _ in 0..4 * n {
            let (t_cur, _) = proxy_stage_stats(&diags, &cur, m, schedule);
            let c_max = max_of(&t_cur);
            let slowest = t_cur
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap();
            let mem_cap_pt = max_of(&mem_t);
            let mut accepted = None;
            for cand in adjust_candidates(&cur, slowest) {
                if cand == cur {
                    continue;
                }
                let (t2, m2) = proxy_stage_stats(&diags, &cand, m, schedule);
                let cond1 = max_of(&t2) <= c_max * (1.0 + 1e-12);
                let cond3 = m2.iter().all(|&x| x <= mem_cap_pt * (1.0 + 1e-12));
                if cond1 && cond3 {
                    accepted = Some(cand);
                    break;
                }
            }
            let Some(next) = accepted else { break };
            let (t_n, m_n) = proxy_stage_stats(&diags, &next, m, schedule);
            // Sandwich at every accepted step.
            assert!(max_of(&t_n) <= max_of(&time_m) * (1.0 + eps), "trial {trial}");
            assert!(max_of(&t_n) >= max_of(&time_t) * (1.0 - eps), "trial {trial}");
            assert!(max_of(&m_n) >= max_of(&mem_m) * (1.0 - eps), "trial {trial}");
            assert!(max_of(&m_n) <= max_of(&mem_t) * (1.0 + eps), "trial {trial}");
            assert!(
                balance_degree(&times, &p_m) <= balance_degree(&times, &next) + eps
                    && balance_degree(&times, &next) <= balance_degree(&times, &p_t) + eps,
                "trial {trial}: alpha_t sandwich violated"
            );
            cur = next;
        }

        // The production seeds stay inside the brute-force envelope: the
        // homogeneous greedy is a bounded approximation of p_m*, and the
        // heterogeneous DP (exercised below with budgets) is exact.
        let p_m_impl = memory_balanced_partition(
            &diags.iter().map(|d| d.mem.o_f).collect::<Vec<_>>(),
            &diags.iter().map(|d| d.mem.o_ms).collect::<Vec<_>>(),
            p,
            m,
            schedule,
        );
        assert_eq!(p_m_impl.iter().sum::<usize>(), n);
        assert!(
            mem_bot(&p_m_impl) <= mem_bot(&p_m) * 2.0,
            "trial {trial}: greedy p_m strayed far from optimal"
        );
        assert!(time_bot(&balanced_partition(&times, p)) <= time_bot(&p_t) * (1.0 + 1e-6));
    }
}

/// Stage memory of a contiguous partition under live-microbatch weighting
/// (the quantity both p_m variants balance).
fn stage_mems(
    act_w: &[f64],
    ms_w: &[f64],
    counts: &[usize],
    m: usize,
    schedule: Schedule,
) -> Vec<f64> {
    let p = counts.len();
    let mut out = Vec::with_capacity(p);
    let mut i = 0usize;
    for (s, &c) in counts.iter().enumerate() {
        let live = schedule.live_microbatches(s, p, m) as f64;
        out.push((i..i + c).map(|k| act_w[k] * live + ms_w[k]).sum());
        i += c;
    }
    out
}

fn partitions_of(n: usize, p: usize) -> Vec<Vec<usize>> {
    fn rec(n: usize, p: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if p == 1 {
            cur.push(n);
            out.push(cur.clone());
            cur.pop();
            return;
        }
        for first in 1..=(n - p + 1) {
            cur.push(first);
            rec(n - first, p - 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(n, p, &mut Vec::new(), &mut out);
    out
}

/// The heterogeneous-budget p_m (`memory_balanced_partition_budgeted`,
/// an exact interval DP) matches exhaustive enumeration on small
/// instances: it minimizes the bottleneck *utilization* exactly, and in
/// particular always returns a feasible partition (every stage within its
/// island's budget) whenever one exists. The homogeneous greedy is a
/// bounded approximation — pinned here so it cannot silently degrade.
#[test]
fn prop_memory_balanced_partition_budgeted_optimal_vs_bruteforce() {
    let mut rng = Rng::new(42);
    for trial in 0..60 {
        let n = 4 + rng.below(8) as usize;
        let p = 2 + rng.below(3.min(n as u64 - 1)) as usize;
        let m = 1 + rng.below(6) as usize;
        let schedule = *rng.choice(&[Schedule::OneFOneB, Schedule::GPipe]);
        let act_w: Vec<f64> = (0..n).map(|_| rng.f64() * 4.0 + 0.1).collect();
        let ms_w: Vec<f64> = (0..n).map(|_| rng.f64() * 2.0 + 0.1).collect();

        // Heterogeneous budgets (forced non-uniform so the exact DP path
        // runs; the uniform delegation is covered by the bmw unit tests).
        let mut budgets: Vec<f64> =
            (0..p).map(|_| *rng.choice(&[24.0, 40.0, 80.0]) * 1e9).collect();
        if budgets.windows(2).all(|w| w[0] == w[1]) {
            budgets[0] = if budgets[0] == 80.0 * 1e9 { 24.0 * 1e9 } else { 80.0 * 1e9 };
        }
        let got_b = memory_balanced_partition_budgeted(&act_w, &ms_w, p, m, schedule, &budgets);
        assert_eq!(got_b.iter().sum::<usize>(), n);
        assert!(got_b.iter().all(|&c| c >= 1));
        let util = |c: &[usize]| {
            stage_mems(&act_w, &ms_w, c, m, schedule)
                .iter()
                .zip(&budgets)
                .map(|(w, b)| w / b)
                .fold(0.0, f64::max)
        };
        let best_u = partitions_of(n, p)
            .iter()
            .map(|c| util(c.as_slice()))
            .fold(f64::INFINITY, f64::min);
        assert!(
            util(&got_b) <= best_u * (1.0 + 1e-9),
            "trial {trial}: util {} best {} budgets {budgets:?}",
            util(&got_b),
            best_u
        );
        // Feasibility whenever any partition fits the budget vector.
        if best_u <= 1.0 {
            assert!(util(&got_b) <= 1.0 + 1e-9, "trial {trial}: missed a feasible partition");
        }

        // The homogeneous greedy stays a bounded approximation of the
        // uniform-budget bottleneck (it trades exactness for the bisection
        // the paper describes; the DP above is the exact reference).
        let got = memory_balanced_partition(&act_w, &ms_w, p, m, schedule);
        let bytes = |c: &[usize]| {
            stage_mems(&act_w, &ms_w, c, m, schedule).iter().cloned().fold(0.0, f64::max)
        };
        let best = partitions_of(n, p)
            .iter()
            .map(|c| bytes(c.as_slice()))
            .fold(f64::INFINITY, f64::min);
        assert!(
            bytes(&got) <= best * 2.0,
            "trial {trial}: greedy bottleneck {} vs optimal {best}",
            bytes(&got)
        );
    }
}

#[test]
fn prop_plan_validate_catches_mutations() {
    let mut rng = Rng::new(8);
    for _ in 0..20 {
        let layers = 8usize;
        let plan = random_uniform_plan(&mut rng, layers, 8);
        plan.validate(layers, 8).unwrap();
        // Break the partition.
        let mut bad = plan.clone();
        bad.partition[0] += 1;
        assert!(bad.validate(layers, 8).is_err());
        // Break a strategy degree.
        let mut bad = plan.clone();
        if bad.pp < 8 {
            bad.strategies[0] = Strategy::serial(false);
            if 8 / bad.pp != 1 {
                assert!(bad.validate(layers, 8).is_err());
            }
        }
    }
}
