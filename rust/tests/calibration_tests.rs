//! The cost-model backend seam (ISSUE 5 acceptance):
//!
//!   * a `Calibrated` backend fed a DB synthesized from the analytic model
//!     (alpha = 0, exact zoo sample coverage) produces byte-identical plans
//!     to `Analytic` — for two zoo models on both a homogeneous (titan8)
//!     and a mixed-island (hetero4) cluster;
//!   * malformed and insufficient-coverage DBs surface as their own typed
//!     `PlanError` variants through the `--profile-db` path;
//!   * `PlanReport` round-trips the recorded cost-model provenance, and
//!     artifacts without the field (every pre-backend artifact) still load.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{
    resolve_cluster_name, CostModel, MethodSpec, PlanError, PlanReport, PlanRequest, Planner,
    ProfileDb,
};

fn request(model: &str, cluster: &str) -> PlanRequest {
    let mut req = PlanRequest::new(model, cluster)
        .method(MethodSpec::Bmw { ckpt: true })
        .max_batch(if cluster == "hetero4" { 32 } else { 64 });
    if cluster != "hetero4" {
        req = req.memory_gb(16.0);
    }
    req
}

#[test]
fn synthetic_calibration_reproduces_analytic_plans_bitwise() {
    for model in ["bert-huge-32", "t5-512/4-32"] {
        for cluster in ["titan8", "hetero4"] {
            let analytic = request(model, cluster).plan();
            let db = ProfileDb::synthetic(&resolve_cluster_name(cluster).unwrap());
            let calibrated =
                request(model, cluster).cost_model(CostModel::calibrated(db.clone())).plan();
            let (a, mut c) = match (analytic, calibrated) {
                (Ok(a), Ok(c)) => (a, c),
                (Err(PlanError::Infeasible { .. }), Err(PlanError::Infeasible { .. })) => continue,
                (a, c) => panic!("{model}/{cluster}: feasibility diverged: {a:?} vs {c:?}"),
            };
            // The calibrated run records its provenance...
            let prov = c.cost_model.clone().expect("calibrated plans record provenance");
            assert_eq!(prov.backend, "calibrated");
            assert_eq!(prov.db_hash, db.content_hash_hex());
            // ...and modulo that record, the artifact is byte-identical:
            // same plan, same costs, same stages, same search trace.
            c.cost_model = None;
            assert_eq!(
                c.to_json_string(),
                a.to_json_string(),
                "{model}/{cluster}: synthetic calibration must not move the plan"
            );
        }
    }
}

#[test]
fn synthetic_calibration_simulates_bitwise_too() {
    let report = request("bert-huge-32", "titan8").plan().expect("feasible");
    let planner = Planner::new();
    let analytic = planner.simulate_report(&report).unwrap();
    let db = ProfileDb::synthetic(&resolve_cluster_name("titan8").unwrap());
    let calibrated = planner
        .simulate_report_costed(&report, &CostModel::calibrated(db))
        .unwrap();
    assert_eq!(calibrated.iter_time.to_bits(), analytic.iter_time.to_bits());
    assert_eq!(calibrated.stage_peak_mem, analytic.stage_peak_mem);
}

#[test]
fn derated_calibration_changes_estimates_but_stays_feasible_valid() {
    // A DB claiming 50% compute efficiency and a lossy link must produce a
    // valid plan with strictly worse estimated throughput than analytic.
    let mut db = ProfileDb::synthetic(&resolve_cluster_name("titan8").unwrap());
    let half = db.ref_flops / 2.0;
    for s in &mut db.layers {
        s.effective_flops = half;
    }
    db.alpha = 5e-5;
    db.beta = db.ref_bw * 0.7;
    let analytic = request("bert-huge-32", "titan8").plan().expect("feasible");
    let derated = request("bert-huge-32", "titan8")
        .cost_model(CostModel::calibrated(db))
        .plan()
        .expect("derated backend still finds a plan");
    derated.plan.validate(32, 8).unwrap();
    assert!(
        derated.throughput < analytic.throughput,
        "derated {} must trail analytic {}",
        derated.throughput,
        analytic.throughput
    );
}

#[test]
fn malformed_profile_db_paths_error_typed() {
    let dir = std::env::temp_dir();
    let pid = std::process::id();

    // Not JSON at all.
    let garbage = dir.join(format!("galvatron-cal-garbage-{pid}.json"));
    std::fs::write(&garbage, "not json {").unwrap();
    let err = request("bert-huge-32", "titan8").profile_db(&garbage).plan().unwrap_err();
    std::fs::remove_file(&garbage).ok();
    assert!(matches!(err, PlanError::InvalidProfileDb { .. }), "{err:?}");

    // Valid JSON, unknown key.
    let wrong = dir.join(format!("galvatron-cal-wrong-{pid}.json"));
    std::fs::write(&wrong, r#"{"version":1,"sauce":"typo"}"#).unwrap();
    let err = request("bert-huge-32", "titan8").profile_db(&wrong).plan().unwrap_err();
    std::fs::remove_file(&wrong).ok();
    match &err {
        PlanError::InvalidProfileDb { reason } => {
            assert!(reason.contains("sauce"), "diagnostic names the bad key: {reason}")
        }
        other => panic!("wrong error: {other:?}"),
    }

    // Structurally valid but empty layer table: a coverage error.
    let mut db = ProfileDb::synthetic(&resolve_cluster_name("titan8").unwrap());
    db.layers.clear();
    let thin = dir.join(format!("galvatron-cal-thin-{pid}.json"));
    std::fs::write(&thin, db.to_pretty_string()).unwrap();
    let err = request("bert-huge-32", "titan8").profile_db(&thin).plan().unwrap_err();
    std::fs::remove_file(&thin).ok();
    assert!(matches!(err, PlanError::ProfileDbCoverage { .. }), "{err:?}");

    // A single collective point cannot pin the alpha-beta fit.
    let mut db = ProfileDb::synthetic(&resolve_cluster_name("titan8").unwrap());
    db.collectives.truncate(1);
    let one = dir.join(format!("galvatron-cal-one-{pid}.json"));
    std::fs::write(&one, db.to_pretty_string()).unwrap();
    let err = request("bert-huge-32", "titan8").profile_db(&one).plan().unwrap_err();
    std::fs::remove_file(&one).ok();
    assert!(matches!(err, PlanError::ProfileDbCoverage { .. }), "{err:?}");
}

#[test]
fn provenance_round_trips_and_legacy_artifacts_load() {
    // Analytic plans do not serialize the field at all.
    let analytic = request("bert-huge-32", "titan8").plan().expect("feasible");
    let text = analytic.to_json_string();
    assert!(!text.contains("cost_model"), "analytic artifacts stay provenance-free");
    let back = PlanReport::from_json_str(&text).unwrap();
    assert_eq!(back.cost_model, None);
    assert_eq!(back, analytic);

    // Calibrated plans round-trip the provenance record bit-for-bit.
    let db = ProfileDb::synthetic(&resolve_cluster_name("titan8").unwrap());
    let calibrated = request("bert-huge-32", "titan8")
        .cost_model(CostModel::calibrated(db.clone()))
        .plan()
        .expect("feasible");
    let text = calibrated.to_json_string();
    assert!(text.contains("\"cost_model\""), "{text:.200}");
    assert!(text.contains(&db.content_hash_hex()));
    let back = PlanReport::from_json_str(&text).unwrap();
    assert_eq!(back, calibrated);
    assert_eq!(back.to_json_string(), text);
    // The human rendering names the backend.
    assert!(back.render().contains("calibrated"));

    // Mistyped provenance is rejected, not silently dropped.
    let bad = text.replace(
        &format!("\"db_hash\":\"{}\"", db.content_hash_hex()),
        "\"db_hash\":42",
    );
    assert!(matches!(
        PlanReport::from_json_str(&bad),
        Err(PlanError::Artifact { .. })
    ));
}

#[test]
fn profile_db_file_round_trips_through_the_cli_format() {
    // save → load preserves content and hash (the canonical pretty form).
    let db = ProfileDb::synthetic(&resolve_cluster_name("hetero4").unwrap());
    let path =
        std::env::temp_dir().join(format!("galvatron-cal-rt-{}.json", std::process::id()));
    db.save(&path).unwrap();
    let back = ProfileDb::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back, db);
    assert_eq!(back.content_hash_hex(), db.content_hash_hex());
}
